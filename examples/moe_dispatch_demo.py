"""MoE dispatch/combine demo (paper §6): both halves of the reproduction.

1. Host-proxy protocol over the simulated fabric: routes scatter ->
   speculative private buffers -> contiguous placement -> grouped compute ->
   single-scatter combine, validated against a dense oracle.
2. TPU-native path: the same dispatch/combine as shard_map all_to_all with
   the Pallas pack/combine kernels (run with
   XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it sharded).

    PYTHONPATH=src python examples/moe_dispatch_demo.py
"""

import numpy as np

from repro.core import Fabric
from repro.moekit import MoEConfig, make_endpoints, oracle, run_moe_layer

# -- 1. fabric protocol -----------------------------------------------------
N, E, R, T, elems = 8, 32, 4, 32, 64
cfg = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                token_bytes=elems * 4, t_priv=8)
fab = Fabric(seed=0)
eps = make_endpoints(fab, cfg, nic="efa", gpus_per_node=4)

rng = np.random.default_rng(0)
tokens, eids, gates = [], [], []
for r in range(N):
    tokens.append(rng.normal(size=(T, elems)).astype(np.float32))
    ei = np.stack([rng.choice(E, R, replace=False) for _ in range(T)]).astype(np.int32)
    eids.append(ei)
    g = np.zeros((T, E), np.float32)
    for t in range(T):
        w = rng.random(R)
        g[t, ei[t]] = w / w.sum()
    gates.append(g)

expert_fn = lambda e, x: np.tanh(x) * (1 + 0.1 * e)
res, stats = run_moe_layer(fab, eps, tokens, eids, gates, expert_fn)
ref = oracle(tokens, eids, gates, expert_fn, E)
for r in range(N):
    np.testing.assert_allclose(res[r], ref[r], rtol=1e-4, atol=1e-4)
print(f"fabric protocol == oracle across {N} ranks, {E} experts, top-{R}")
print(f"  dispatch p50 {np.median(stats['dispatch_us']):.1f}us  "
      f"combine p50 {np.median(stats['combine_us']):.1f}us "
      f"(EFA, 4 GPUs/node, NVLink intra-node)")

# -- 2. TPU-native shard_map path ----------------------------------------------
import jax
import jax.numpy as jnp

from repro.comm import moe_a2a, use_mesh
from repro.configs import get_config
from repro.models.moe import init_moe, moe_dense

mcfg = get_config("qwen3-moe-30b-a3b").reduced()
n_dev = jax.device_count()
if n_dev >= 4:
    from repro.compat import make_mesh
    mesh = make_mesh((n_dev // 4, 4), ("data", "model"))
    p = init_moe(jax.random.PRNGKey(0), mcfg, jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (64, mcfg.d_model)) * 0.5
    y_ref, _ = moe_dense(p, h, mcfg)
    with use_mesh(mesh):
        y, _ = jax.jit(lambda p, h: moe_a2a(p, h, mcfg, "model"))(p, h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    print(f"shard_map all_to_all path == dense oracle on {n_dev} devices")
else:
    print(f"({n_dev} device(s): run with "
          f"XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
          f"sharded path)")
print("moe demo OK")
