"""RL rollout weight-update demo (paper §5).

Part 1: small cluster with REAL bytes — plan a static routing schedule,
execute the staged P2P pipeline (watermark-bounded chunked staging,
window-coalesced WrBatches, two-phase commit) and the rank0
gather/broadcast baseline, verify bit-exactness and compare virtual time.

Part 2: async fine-tuning — a DELTA update moves only the dirty layers
through the same pipeline; clean regions are never touched and the
inference fleet still flips atomically.

Part 3: Kimi-K2 scale (1T params, 256 -> 128 GPUs) with synthetic writes —
reproduces the paper's 1.3 s claim and the ~100x gap to rank0.

    PYTHONPATH=src python examples/rl_weight_update.py
"""

import numpy as np

from repro.rlweights import (ParamMeta, compute_routing, make_cluster,
                             p2p_transfer, rank0_transfer, schedule_stats,
                             verify_contents)

# -- Part 1: real bytes, staged pipeline --------------------------------------
params = [ParamMeta(f"layer{i}", (1024, 512), 2) for i in range(24)]  # 24 MB
routes, sizes = compute_routing(params, n_train=8, n_infer=4, infer_tp=2,
                                quant_ratio=0.5)
print("schedule:", schedule_stats(routes, 8, 4))

cl = make_cluster(8, 4, max(sizes["train"].values()),
                  max(sizes["infer"].values()), nic="cx7")
r_p2p = p2p_transfer(cl, routes, watermark_bytes=1 << 20, chunk_bytes=65536)
assert verify_contents(cl, routes)
assert r_p2p["committed"] and r_p2p["watermark_ok"]
cl2 = make_cluster(8, 4, max(sizes["train"].values()),
                   max(sizes["infer"].values()), nic="cx7")
r_r0 = rank0_transfer(cl2, routes)
assert verify_contents(cl2, routes)
print(f"P2P   : {r_p2p['total_us']:8.0f} us  "
      f"({r_p2p['n_chunks']} chunks -> {r_p2p['writes']} writes in "
      f"{r_p2p['n_batches']} enqueues, peak staged "
      f"{r_p2p['peak_staged_bytes'] >> 10} KiB, "
      f"commit flips {r_p2p['commits']}, bit-exact)")
print(f"rank0 : {r_r0['total_us']:8.0f} us  (gather {r_r0['gather_us']:.0f} us)")
print(f"speedup {r_r0['total_us'] / r_p2p['total_us']:.1f}x on an 8->4 toy cluster\n")

# -- Part 2: delta update (async fine-tuning) ---------------------------------
dirty = [f"layer{i}" for i in (3, 11, 19)]
delta_routes, _ = compute_routing(params, n_train=8, n_infer=4, infer_tp=2,
                                  quant_ratio=0.5, changed=dirty)
# scribble fresh "fine-tuned" bytes into the dirty source ranges
for r in delta_routes:
    cl.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes] ^= 0xA5
r_delta = p2p_transfer(cl, delta_routes, watermark_bytes=1 << 20,
                       chunk_bytes=65536, update_id=1)
assert verify_contents(cl, delta_routes) and r_delta["committed"]
d = schedule_stats(delta_routes, 8, 4, full_routes=routes)
print(f"DELTA : {r_delta['total_us']:8.0f} us for {len(dirty)}/24 dirty "
      f"layers — {d['delta_bytes']} of {d['full_bytes']} bytes "
      f"({d['delta_frac'] * 100:.0f}%), second atomic flip per rank\n")

# -- Part 3: trillion-parameter scale (synthetic) -----------------------------
from benchmarks.bench_rlweights import p2p_synthetic, rank0_synthetic
from repro.core.transport import Channel

Channel.MAX_CHUNKS = 2
p2p = p2p_synthetic()
print(f"Kimi-K2 1T, 256 bf16 -> 128 fp8 GPUs over 2x200G EFA:")
print(f"  P2P pipelined: {p2p['total_ms']:.0f} ms "
      f"(paper: 1233 ms; h2d {p2p['h2d_ms']:.0f} ms, prep {p2p['prep_ms']:.0f} ms, "
      f"peak staged {p2p['peak_staged_bytes'] / (1 << 30):.2f} GiB, "
      f"committed={p2p['committed']})")
r0 = rank0_synthetic()
print(f"  rank0 gather+broadcast: {r0['total_ms'] / 1e3:.1f} s "
      f"-> {r0['total_ms'] / p2p['total_ms']:.0f}x slower (paper: >100x)")
