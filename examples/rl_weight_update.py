"""RL rollout weight-update demo (paper §5).

Part 1: small cluster with REAL bytes — plan a static routing schedule,
execute P2P and rank0-gather/broadcast transfers, verify bit-exactness and
compare virtual-time latency.

Part 2: Kimi-K2 scale (1T params, 256 -> 128 GPUs) with synthetic writes —
reproduces the paper's 1.3 s claim and the ~100x gap.

    PYTHONPATH=src python examples/rl_weight_update.py
"""

import numpy as np

from repro.rlweights import (ParamMeta, compute_routing, make_cluster,
                             p2p_transfer, rank0_transfer, schedule_stats,
                             verify_contents)

# -- Part 1: real bytes --------------------------------------------------------
params = [ParamMeta(f"layer{i}", (1024, 512), 2) for i in range(24)]  # 24 MB
routes, sizes = compute_routing(params, n_train=8, n_infer=4, infer_tp=2,
                                quant_ratio=0.5)
print("schedule:", schedule_stats(routes, 8, 4))

cl = make_cluster(8, 4, max(sizes["train"].values()),
                  max(sizes["infer"].values()), nic="cx7")
r_p2p = p2p_transfer(cl, routes)
assert verify_contents(cl, routes)
cl2 = make_cluster(8, 4, max(sizes["train"].values()),
                   max(sizes["infer"].values()), nic="cx7")
r_r0 = rank0_transfer(cl2, routes)
assert verify_contents(cl2, routes)
print(f"P2P   : {r_p2p['total_us']:8.0f} us  ({r_p2p['writes']} writes, bit-exact)")
print(f"rank0 : {r_r0['total_us']:8.0f} us  (gather {r_r0['gather_us']:.0f} us)")
print(f"speedup {r_r0['total_us'] / r_p2p['total_us']:.1f}x on an 8->4 toy cluster\n")

# -- Part 2: trillion-parameter scale (synthetic) ---------------------------------
from benchmarks.bench_rlweights import p2p_synthetic, rank0_synthetic
from repro.core.transport import Channel

Channel.MAX_CHUNKS = 2
p2p = p2p_synthetic()
print(f"Kimi-K2 1T, 256 bf16 -> 128 fp8 GPUs over 2x200G EFA:")
print(f"  P2P pipelined: {p2p['total_ms']:.0f} ms "
      f"(paper: 1233 ms; h2d {p2p['h2d_ms']:.0f} ms, prep {p2p['prep_ms']:.0f} ms)")
r0 = rank0_synthetic()
print(f"  rank0 gather+broadcast: {r0['total_ms'] / 1e3:.1f} s "
      f"-> {r0['total_ms'] / p2p['total_ms']:.0f}x slower (paper: >100x)")
