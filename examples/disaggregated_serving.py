"""Disaggregated serving demo (paper §4): elastic prefill over the control
plane, on a PATTERN-SPLIT architecture.

One prefill node and two decode nodes register with the ControlPlane and
serve a batch of requests over the simulated EFA fabric; a SECOND prefiller
joins mid-run (epoch bump, VIEW-UPDATE) and picks up traffic.  KV state
moves layer-by-layer via batched WRITEIMM, decode starts on the ImmCounter,
and the generations are verified against a monolithic run of the same
model.

Uses gemma3-1b: its reduced cache is NOT a uniform k/v stack — local
layers carry a window-sized ring (``lk/lv``), global layers a full-length
stack (``sk/sv``).  ``repro.kvlayout`` derives that schema from the config
and compiles per-request transfer plans, so the same §4 protocol serves it
(the old ``disagg_unsupported_reason`` guard that forced the stablelm
workaround here is retired).

    PYTHONPATH=src python examples/disaggregated_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Fabric
from repro.ctrl import ControlPlane
from repro.kvlayout import DECODE_MARGIN, schema_from_config
from repro.models import decode_step, init_params, prefill
from repro.serving import Decoder, Prefiller, Scheduler

cfg = get_config("gemma3-1b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
schema = schema_from_config(cfg)
print("KvSchema:", ", ".join(
    f"{c.name}({c.kind}, layers={list(c.layers)})" for c in schema.components))

fab = Fabric(seed=1)
ctrl = ControlPlane(fab, nic="efa")
prefillers = [Prefiller(fab, "prefill0", cfg, params, nic="efa", ctrl=ctrl)]
decoders = [Decoder(fab, f"decode{i}", cfg, params, nic="efa", ctrl=ctrl)
            for i in range(2)]
sched = Scheduler(fab, ctrl)

# a second prefiller JOINs mid-run — scale-up is just another epoch
fab.loop.schedule(150.0, lambda: prefillers.append(
    Prefiller(fab, "prefill1", cfg, params, nic="efa", ctrl=ctrl)))

rng = np.random.default_rng(0)
requests = [rng.integers(0, cfg.vocab, size=24 + 8 * i) for i in range(4)]
rids = []
for i, ids in enumerate(requests):
    # arrivals spread over virtual time, so the joiner picks up traffic
    fab.loop.schedule_at(100.0 * i, lambda ids=ids: rids.append(
        sched.submit(ids, n_decode=4)))
fab.run()
sched.check_drained()   # raises if anything was left unrouted

for rid, ids in zip(rids, requests):
    r = sched.completed[rid]
    # monolithic reference
    lg, cache = prefill(params, jnp.asarray(ids)[None], cfg,
                        max_len=len(ids) + DECODE_MARGIN, moe_mode="dense")
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(ids)
    for _ in range(3):
        lg, cache = decode_step(params, jnp.asarray([[toks[-1]]]),
                                jnp.asarray([pos], jnp.int32), cache, cfg,
                                moe_mode="dense")
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    ok = r["tokens"] == toks
    print(f"req {rid}: prompt {len(ids):3d} tok  TTFT {r['ttft_us']:7.1f}us  "
          f"served by {r['prefiller']}  tokens {r['tokens']}  "
          f"match_monolithic={ok}")
    assert ok
served = {r["prefiller"] for r in sched.completed.values()}
print(f"disaggregated == monolithic on a pattern-split arch ✓  "
      f"(prefillers used: {sorted(served)}, final epoch {sched.view.epoch})")
