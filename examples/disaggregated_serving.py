"""Disaggregated serving demo (paper §4): prefillers + decoders + scheduler.

Two prefill nodes and two decode nodes serve a batch of requests over the
simulated EFA fabric; KV pages move layer-by-layer via paged WRITEIMM,
decode starts on the ImmCounter, and the generations are verified against a
monolithic run of the same model.

    PYTHONPATH=src python examples/disaggregated_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Fabric
from repro.models import decode_step, init_params, prefill
from repro.serving import Decoder, Prefiller, Scheduler

cfg = get_config("gemma3-1b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))

fab = Fabric(seed=1)
prefillers = [Prefiller(fab, f"prefill{i}", cfg, params, nic="efa")
              for i in range(2)]
decoders = [Decoder(fab, f"decode{i}", cfg, params, nic="efa")
            for i in range(2)]
sched = Scheduler(fab, prefillers, decoders)

rng = np.random.default_rng(0)
requests = [rng.integers(0, cfg.vocab, size=24 + 8 * i) for i in range(4)]
rids = [sched.submit(ids, n_decode=4) for ids in requests]
fab.run()

for rid, ids in zip(rids, requests):
    dec = decoders[rid % len(decoders)]
    r = dec.results[rid]
    # monolithic reference
    lg, cache = prefill(params, jnp.asarray(ids)[None], cfg,
                        max_len=len(ids) + 64, moe_mode="dense")
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(ids)
    for _ in range(3):
        lg, cache = decode_step(params, jnp.asarray([[toks[-1]]]),
                                jnp.asarray([pos], jnp.int32), cache, cfg,
                                moe_mode="dense")
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    ok = r["tokens"] == toks
    print(f"req {rid}: prompt {len(ids):3d} tok  TTFT {r['ttft_us']:7.1f}us  "
          f"tokens {r['tokens']}  match_monolithic={ok}")
    assert ok
print("disaggregated == monolithic for all requests ✓")
