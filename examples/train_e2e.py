"""End-to-end training driver: train a decoder LM on the synthetic corpus.

Defaults to a ~25M-parameter dense model for a few hundred steps (CPU-
friendly); ``--full`` selects the ~100M configuration. Checkpoints
periodically and prints the loss curve.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--full]
    PYTHONPATH=src python examples/train_e2e.py --arch qwen3-moe-30b-a3b
        (trains the REDUCED variant of any assigned arch)
"""

import argparse
import dataclasses

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.training import TrainConfig, train

SMALL = ModelConfig(
    name="lm-25m", family="dense", source="examples",
    n_layers=6, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=32_000)

FULL_100M = ModelConfig(
    name="lm-100m", family="dense", source="examples",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=10, d_ff=2560,
    vocab=50_304)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="train the reduced variant of an assigned arch")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ck")
    args = ap.parse_args()

    if args.arch:
        cfg = get_config(args.arch).reduced()
    else:
        cfg = FULL_100M if args.full else SMALL
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"steps={args.steps} seq={args.seq_len} batch={args.batch}")

    out = train(cfg, TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        log_every=max(1, args.steps // 20), ckpt_every=max(1, args.steps // 2),
        ckpt_path=args.ckpt, warmup=args.steps // 10),
        log_fn=lambda r: print(
            f"step {r['step']:4d}  loss {r['loss']:.4f}  "
            f"gnorm {r['grad_norm']:.2f}  {r['wall_s']:.0f}s"))
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"({(h[0]['loss'] - h[-1]['loss']):.3f} nats improvement); "
          f"checkpoint at {args.ckpt}.npz")
    assert h[-1]["loss"] < h[0]["loss"]


if __name__ == "__main__":
    main()
