"""Quickstart: the TransferEngine API in 60 lines.

Creates a two-node fabric (EFA, 2 NICs/GPU), registers memory, and runs the
three core patterns: one-sided WRITEIMM with an ImmCounter, paged writes,
and two-sided SEND/RECV — all in deterministic virtual time.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Fabric, Pages

fab = Fabric(seed=0)
a = fab.add_engine("node-a", nic="efa")   # 2 x 200 Gbps EFA
b = fab.add_engine("node-b", nic="efa")

# -- register memory ---------------------------------------------------------
src = (np.arange(1 << 20) % 251).astype(np.uint8)
dst = np.zeros(1 << 20, np.uint8)
h_src, d_src = a.reg_mr(src)
h_dst, d_dst = b.reg_mr(dst)

# -- one-sided WRITEIMM + ImmCounter ------------------------------------------
done_at = []
b.expect_imm_count(imm=7, count=1, cb=lambda: done_at.append(fab.now))
a.submit_single_write(src.size, imm=7, src=(h_src, 0), dst=(d_dst, 0))
fab.run()
assert np.array_equal(src, dst)
print(f"1 MiB WRITEIMM delivered at t={done_at[0]:.1f}us "
      f"({src.size * 8e-3 / done_at[0]:.0f} Gbps effective)")

# -- paged writes (KvCache pattern) -------------------------------------------
dst[:] = 0
pages = Pages(indices=tuple(range(64)), stride=4096)
scattered = Pages(indices=tuple(np.random.default_rng(0).permutation(64).tolist()),
                  stride=4096)
b.expect_imm_count(imm=9, count=64, cb=lambda: print(
    f"64 x 4 KiB pages landed (any order, SRD) at t={fab.now:.1f}us"))
a.submit_paged_writes(4096, imm=9, src=(h_src, pages), dst=(d_dst, scattered))
fab.run()

# -- two-sided SEND/RECV (RPC pattern) ------------------------------------------
b.submit_recvs(256, 4, lambda msg: print(f"RECV: {msg.decode()} at t={fab.now:.1f}us"))
a.submit_send(b.address(), b"hello fabric-lib")
fab.run()
print("quickstart OK")
