"""Transport fault injection: retry/timeout budgets, abort/recovery.

Covers the FaultPlan data plane (drop / completion-error / burst /
kill_peer), the per-WR retry budget with exactly-once completion under
replay races, the terminal ``on_error`` paths through the engine, and the
protocol-level recovery logic (rlweights update abort, MoE dispatch abort,
RNR backpressure).  Every test runs under the leak audit — recovery and
abort must both drain the fabric to zero."""

import numpy as np
import pytest

from repro.core import BackpressureError, Fabric, FaultPlan, TransferError
from repro.obs import FlightRecorder


@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield


def _pair(nic: str = "cx7", seed: int = 0, **plan_kw):
    fab = Fabric(seed=seed)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    plan = FaultPlan(fab, **plan_kw)
    return fab, a, b, plan


def _one_write(a, b, nbytes=1 << 14, imm=3, on_error=None):
    src = (np.arange(nbytes) % 251).astype(np.uint8)
    dst = np.zeros(nbytes, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    fired = []
    b.expect_imm_count(imm, 1, lambda: fired.append(True))
    a.submit_single_write(nbytes, imm, (hs, 0), (dd, 0), on_error=on_error)
    return src, dst, fired


# ---------------------------------------------------------------------------
# retry recovery: exactly-once completion, bit-exact payload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_drop_retry_recovers_exactly_once(nic):
    """A dropped WR is timeout-detected and retried; the imm fires exactly
    once and the payload lands bit-exact."""
    fab, a, b, plan = _pair(nic, timeout_us=300.0, max_retries=8,
                            backoff_us=20.0)
    plan.burst("a", "b", 1)           # deterministically lose attempt 0
    src, dst, fired = _one_write(a, b)
    fab.run()
    assert fired == [True]
    assert np.array_equal(src, dst)
    assert plan.stats["drops"] == 1
    assert plan.stats["retries"] == 1
    assert plan.stats["recovered"] == 1
    assert plan.stats["exhausted"] == 0


def test_burst_loss_consumes_budget_then_recovers():
    """burst(n) drops the first n attempts unconditionally; attempt n+1
    goes through."""
    fab, a, b, plan = _pair(timeout_us=200.0, max_retries=8, backoff_us=10.0)
    plan.burst("a", "b", 3)
    src, dst, fired = _one_write(a, b)
    fab.run()
    assert fired == [True] and np.array_equal(src, dst)
    assert plan.stats["drops"] == 3 and plan.stats["retries"] == 3
    assert plan.stats["recovered"] == 1


def test_completion_error_retries_without_waiting_for_timeout():
    """A NIC completion-error is detected at ~RTT and retried immediately —
    recovery lands well before the drop path's delivery timeout would."""
    fab, a, b, plan = _pair(timeout_us=50_000.0, max_retries=4,
                            backoff_us=10.0)
    plan.inject("a", "b", error_prob=1.0)
    # heal the pair before the first retry reposts (error lands at ~RTT,
    # the repost RTT+backoff later): the retry re-runs a clean verdict
    fab.loop.schedule(2.0, lambda: plan.inject("a", "b", error_prob=0.0))
    src, dst, fired = _one_write(a, b)
    fab.run()
    assert fired == [True] and np.array_equal(src, dst)
    assert plan.stats["errors"] == 1 and plan.stats["recovered"] == 1
    assert plan.stats["drops"] == 0
    # detected via error completion, far sooner than the 50ms timeout
    assert fab.now < 10_000.0


def test_spurious_timeout_replay_is_idempotent_exactly_once():
    """Timeout shorter than the real delivery latency: the WR is replayed
    while the original is still in flight.  Payload replays are idempotent
    and completion is deduplicated — the imm fires exactly once."""
    fab, a, b, plan = _pair("efa", timeout_us=40.0, max_retries=8,
                            backoff_us=10.0)
    fab.degrade_pair("a", "b", bw_scale=0.25)     # push delivery past 40us
    src, dst, fired = _one_write(a, b, nbytes=1 << 20)
    fab.run()
    assert fired == [True]
    assert np.array_equal(src, dst)
    assert plan.stats["retries"] >= 1
    # per wire op (the write may stripe across rails), each original
    # delivery beat its replay: recovered, never exhausted
    assert plan.stats["recovered"] >= 1
    assert plan.stats["exhausted"] == 0


# ---------------------------------------------------------------------------
# exhaustion: terminal on_error path, loud when unhandled
# ---------------------------------------------------------------------------

def test_exhaustion_takes_on_error_path_and_dumps_recorder():
    fab, a, b, plan = _pair(timeout_us=100.0, max_retries=2, backoff_us=10.0)
    mon, rec = fab.health, fab.recorder   # attached by the audited fixture
    plan.inject("a", "b", drop_prob=1.0)
    errors = []
    src, dst, fired = _one_write(a, b, imm=7, on_error=errors.append)
    fab.run()
    assert fired == []
    assert len(errors) == 1
    assert "failed after 2 retries" in errors[0]
    assert "delivery-timeout" in errors[0]
    assert plan.stats == dict(drops=3, errors=0, retries=2, recovered=0,
                              exhausted=1, killed=0, blackholed_sends=0)
    assert mon.fault_counts["exhausted"] == 1
    assert mon.fault_counts["drop"] == 3
    assert rec.dumps and "retry-exhausted" in rec.dumps[-1]
    # the failed WR's expectation never fires: the handler must reset it
    b.counters[0].reset(7)


def test_unhandled_exhaustion_raises_transfer_error():
    fab, a, b, plan = _pair(timeout_us=100.0, max_retries=0, backoff_us=10.0)
    plan.inject("a", "b", drop_prob=1.0)
    _, _, _fired = _one_write(a, b, imm=9)
    with pytest.raises(TransferError, match="a->b"):
        fab.run()
    fab.run()                 # drain whatever the raise interrupted
    b.counters[0].reset(9)


def test_batch_on_error_fires_once_and_suppresses_on_done():
    """One shared handler per batch: the first failed WR wins, on_done is
    permanently suppressed (no 'done' after 'failed')."""
    fab, a, b, plan = _pair(timeout_us=100.0, max_retries=0, backoff_us=10.0)
    plan.inject("a", "b", drop_prob=1.0)
    src = np.zeros(4096, np.uint8)
    dst = np.zeros(4096, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    from repro.core import ScatterDst
    dsts = [ScatterDst(len=1024, src=i * 1024, dst=(dd, i * 1024))
            for i in range(4)]
    done, errs = [], []
    a.submit_scatters([(hs, dsts, 11, lambda: done.append(True),
                        errs.append)])
    fab.run()
    assert done == [] and len(errs) == 1
    assert plan.stats["exhausted"] == 4    # every WR failed ...
    b.counters[0].reset(11)                # ... and none completed


# ---------------------------------------------------------------------------
# kill_peer: channel-level error state
# ---------------------------------------------------------------------------

def test_kill_peer_fails_outstanding_writes_and_blackholes_sends():
    fab, a, b, plan = _pair("efa", timeout_us=50_000.0, max_retries=4)
    errors = []
    src, dst, fired = _one_write(a, b, nbytes=1 << 20, imm=5,
                                 on_error=errors.append)
    # kill mid-flight: the big WRITE is still on the wire at t=5us
    fab.loop.schedule(5.0, lambda: plan.kill_peer("b"))
    # later SENDs to the dead peer are blackholed, never delivered
    fab.loop.schedule(10.0, lambda: a.submit_send(b.address(0), b"hello"))
    fab.run()
    b.counters[0].reset(5)    # failed WR's imm will never fire: disarm it
    assert fired == [] and len(errors) == 1
    assert "died with WR outstanding" in errors[0]
    # one logical write may stripe across rails: >= 1 wire op killed, but
    # the engine-level on_error fired exactly once (first failure wins)
    assert plan.stats["killed"] >= 1
    assert plan.stats["blackholed_sends"] == 1

    # new WRs to the dead peer fail immediately, skipping the retry budget
    errors2 = []
    _, _, fired2 = _one_write(a, b, imm=6, on_error=errors2.append)
    fab.run()
    b.counters[0].reset(6)
    assert fired2 == [] and len(errors2) == 1 and "peer dead" in errors2[0]


# ---------------------------------------------------------------------------
# determinism: inactive plans are invisible, schedules replay bit-identically
# ---------------------------------------------------------------------------

def _timed_workload(attach_plan: bool):
    fab = Fabric(seed=13)
    a = fab.add_engine("a", nic="efa")
    b = fab.add_engine("b", nic="efa")
    if attach_plan:
        FaultPlan(fab, seed=5)            # attached, zero injected pairs
    src = (np.arange(1 << 18) % 241).astype(np.uint8)
    dst = np.zeros(1 << 18, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    times = []
    b.expect_imm_count(2, 4, lambda: times.append(fab.now))
    for i in range(4):
        a.submit_single_write(1 << 16, 2, (hs, i << 16), (dd, i << 16))
    fab.run()
    return fab.now, times, dst.copy()


def test_attached_inactive_plan_is_bit_identical_to_no_plan():
    t0, fire0, bytes0 = _timed_workload(attach_plan=False)
    t1, fire1, bytes1 = _timed_workload(attach_plan=True)
    assert t0 == t1 and fire0 == fire1
    assert np.array_equal(bytes0, bytes1)


def test_fault_schedule_replays_bit_identically():
    """Same seeds => same drops, same retries, same final virtual time."""
    def run():
        fab, a, b, plan = _pair("efa", seed=21, timeout_us=200.0,
                                max_retries=8, backoff_us=25.0)
        plan.inject("a", "b", drop_prob=0.4)
        src, dst, fired = _one_write(a, b, nbytes=1 << 16)
        fab.run()
        assert fired == [True] and np.array_equal(src, dst)
        return fab.now, dict(plan.stats)

    assert run() == run()


# ---------------------------------------------------------------------------
# RNR backpressure (bounded pending-send requeue)
# ---------------------------------------------------------------------------

def test_rnr_requeue_cap_surfaces_backpressure_error():
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    b.max_pending_sends = 4
    seen = []
    b.on_backpressure = seen.append
    for _ in range(7):                    # no RECVs posted on b
        a.submit_send(b.address(0), b"x" * 32)
    fab.run()
    assert b.dropped_sends == 3 and len(seen) == 3
    err = seen[0]
    assert isinstance(err, BackpressureError)
    assert (err.node, err.device, err.depth) == ("b", 0, 4)
    # posting RECVs drains the 4 parked sends; the 3 dropped stay dropped
    got = []
    b.submit_recvs(64, 8, lambda p: got.append(bytes(p)))
    fab.run()
    assert len(got) == 4


def test_rnr_cap_without_handler_raises():
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    b.max_pending_sends = 1
    a.submit_send(b.address(0), b"one")
    a.submit_send(b.address(0), b"two")
    with pytest.raises(BackpressureError, match="b/gpu0"):
        fab.run()
    fab.run()
    b.submit_recvs(16, 2, lambda p: None)
    fab.run()


# ---------------------------------------------------------------------------
# rlweights: commit-under-loss and abort/recovery
# ---------------------------------------------------------------------------

def _rl_plan():
    from repro.rlweights import ParamMeta, compute_routing
    params = [ParamMeta(f"w{i}", (256, 96), 2) for i in range(4)]
    return compute_routing(params, 2, 2, infer_tp=1)


def _rl_cluster(sizes, nic="cx7", seed=0, infer_nic=None):
    from repro.rlweights import make_cluster
    return make_cluster(2, 2, max(sizes["train"].values()),
                        max(sizes["infer"].values()), nic=nic, seed=seed,
                        infer_nic=infer_nic)


def test_rlweights_commits_exactly_once_under_loss():
    """With a generous retry budget, 30% loss on one pair still yields a
    bit-exact, exactly-once commit — just later."""
    from repro.rlweights import p2p_transfer, verify_contents
    routes, sizes = _rl_plan()
    cl = _rl_cluster(sizes, seed=3)
    plan = FaultPlan(cl.fabric, timeout_us=400.0, max_retries=16,
                     backoff_us=25.0)
    plan.inject("train0", "infer0", drop_prob=0.3)
    stats = p2p_transfer(cl, routes, chunk_bytes=4096)
    assert stats["committed"] and not stats["aborted"]
    assert stats["commits"] == [1, 1]
    assert verify_contents(cl, routes)
    assert plan.stats["drops"] > 0 and plan.stats["exhausted"] == 0
    # every drop was retried; a WR may need several retries to land
    assert plan.stats["retries"] == plan.stats["drops"]
    assert plan.stats["recovered"] >= 1


def test_rlweights_abort_is_leak_free_and_next_update_proceeds():
    """Retry exhaustion aborts the update: commit is withheld on every
    rank, staging is released, the audit stays clean — and after the fault
    clears, the next update_id commits normally on the same cluster."""
    from repro.rlweights import p2p_transfer, verify_contents
    routes, sizes = _rl_plan()
    # mixed-NIC pair under degradation: the CX7->EFA path both slows and
    # loses — the acceptance scenario
    cl = _rl_cluster(sizes, nic="cx7", infer_nic="efa", seed=7)
    cl.fabric.degrade_pair("train0", "infer0", bw_scale=0.25)
    plan = FaultPlan(cl.fabric, timeout_us=300.0, max_retries=1,
                     backoff_us=20.0)
    plan.inject("train0", "infer0", drop_prob=1.0)
    stats = p2p_transfer(cl, routes, chunk_bytes=4096)
    assert stats["aborted"] and not stats["committed"]
    assert "retr" in stats["abort_reason"]
    assert stats["commits"] == [0, 0]
    assert plan.stats["exhausted"] >= 1

    # recovery: heal the pair, rerun as the next update on the same engines
    plan.clear()
    stats2 = p2p_transfer(cl, routes, chunk_bytes=4096, update_id=1)
    assert stats2["committed"] and stats2["commits"] == [1, 1]
    assert verify_contents(cl, routes)


# ---------------------------------------------------------------------------
# MoE: dispatch to a dead rank fails loudly with a clean round teardown
# ---------------------------------------------------------------------------

def test_moe_dispatch_to_dead_rank_raises_dispatch_error():
    from repro.moekit import DispatchError, MoEConfig, make_endpoints
    fab = Fabric(seed=7)
    cfg = MoEConfig(n_ranks=2, n_experts=4, top_k=2, max_tokens=8,
                    token_bytes=64)
    eps = make_endpoints(fab, cfg, gpus_per_node=1)
    plan = FaultPlan(fab, max_retries=1, timeout_us=200.0)
    plan.kill_peer("node1-r1")
    T = 4
    tokens = np.arange(T * 16, dtype=np.float32).reshape(T, 16)
    eids = np.array([[0, 2], [1, 3], [0, 1], [2, 3]], np.int32)
    completed = []
    eps[0].dispatch(tokens.view(np.uint8).reshape(T, -1), eids,
                    lambda: completed.append(True))
    with pytest.raises(DispatchError) as ei:
        fab.run()
    assert ei.value.rank == 0 and ei.value.round_id == 1
    assert "dispatch.p1" in str(ei.value)
    fab.run()                             # drain sibling WRs; dedup holds
    assert completed == []
    assert eps[0].stats["failures"] == 1
    # abort_round cleared the round's expectations: audit is clean (fixture)


def test_moe_dispatch_on_error_handler_absorbs_failure():
    from repro.moekit import DispatchError, MoEConfig, make_endpoints
    fab = Fabric(seed=7)
    cfg = MoEConfig(n_ranks=2, n_experts=4, top_k=2, max_tokens=8,
                    token_bytes=64)
    eps = make_endpoints(fab, cfg, gpus_per_node=1)
    plan = FaultPlan(fab, max_retries=1, timeout_us=200.0)
    plan.kill_peer("node1-r1")
    T = 2
    tokens = np.zeros((T, 16), np.float32)
    eids = np.array([[0, 2], [1, 3]], np.int32)
    caught = []
    eps[0].dispatch(tokens.view(np.uint8).reshape(T, -1), eids,
                    lambda: None, on_error=caught.append)
    fab.run()
    assert len(caught) == 1 and isinstance(caught[0], DispatchError)


# ---------------------------------------------------------------------------
# serving: mid-handoff KV failure re-routes, output parity with monolithic
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kv_handoff_failure_reroutes_with_output_parity():
    """All KV WRITEs from p0 to the decoder exhaust their retry budget:
    the prefiller escalates XferFail, the decoder frees the attempt and
    forwards to the scheduler, which re-routes to p1 — the request still
    completes with the exact tokens the monolithic path produces."""
    import jax

    from repro.configs import get_config
    from repro.ctrl import ControlPlane
    from repro.models import decode_step, init_params, prefill
    from repro.serving import Decoder, Prefiller, Scheduler
    import jax.numpy as jnp

    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=3)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=64)
    p0 = Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl,
                   max_renewals=64)
    Prefiller(fab, "p1", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    dec = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                  max_renewals=64)
    sched = Scheduler(fab, ctrl)
    plan = FaultPlan(fab, timeout_us=10_000.0, max_retries=1,
                     backoff_us=50.0)
    plan.inject("p0", "d0", drop_prob=1.0)     # p0's handoffs always fail

    ids = np.random.default_rng(0).integers(0, cfg.vocab, size=37)
    rid = sched.submit(ids, n_decode=5)
    fab.run()

    r = sched.completed[rid]
    assert r["attempt"] == 1 and r["prefiller"] == "p1"
    assert sched.rerouted == [rid]
    assert sched.xfer_failures and sched.xfer_failures[0][0] == rid
    assert dec.xfer_failed and dec.xfer_failed[0][0] == rid
    assert p0.stats["xfer_failures"] >= 1
    assert not sched.failed
    # p0's staged pages were freed on the failure path
    assert len(p0.pool._free) == p0.pool.n_pages
    assert len(dec.pool._free) == dec.pool.n_pages

    # output parity with the monolithic single-process path
    lg, cache = prefill(params, jnp.asarray(ids)[None], cfg,
                        max_len=len(ids) + 64, moe_mode="dense")
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(ids)
    for _ in range(4):
        lg, cache = decode_step(params, jnp.asarray([[toks[-1]]]),
                                jnp.asarray([pos], jnp.int32), cache, cfg,
                                moe_mode="dense")
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert r["tokens"] == toks


@pytest.mark.slow
def test_kv_handoff_exhausts_attempts_terminally():
    """Every prefiller's path to the decoder is lossy: the scheduler
    re-routes up to max_attempts, then records a terminal failure instead
    of retrying forever."""
    import jax

    from repro.configs import get_config
    from repro.ctrl import ControlPlane
    from repro.models import init_params
    from repro.serving import Decoder, Prefiller, Scheduler

    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=4)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=64)
    Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    sched = Scheduler(fab, ctrl, max_attempts=2)
    plan = FaultPlan(fab, timeout_us=10_000.0, max_retries=0,
                     backoff_us=50.0)
    plan.inject("p0", "d0", drop_prob=1.0)
    rid = sched.submit(np.arange(24) % cfg.vocab, n_decode=2)
    fab.run()
    assert rid in sched.failed and rid not in sched.completed
    assert sched.failed[rid]["attempts"] == 2
    assert len(sched.rerouted) == 1


# ---------------------------------------------------------------------------
# flight recorder: per-reason rate limiting
# ---------------------------------------------------------------------------

def test_recorder_rate_limits_per_reason(tmp_path):
    fab = Fabric(seed=0)
    rec = FlightRecorder(fab, dump_dir=str(tmp_path), max_dumps=8,
                         max_per_reason=2)
    assert rec.dump("retry-exhausted") is not None
    assert rec.dump("retry-exhausted") is not None
    assert rec.dump("retry-exhausted") is None      # third is suppressed
    assert rec.dump("update-abort") is not None     # other reasons unaffected
    assert sum("retry-exhausted" in p for p in rec.dumps) == 2
