"""Per-architecture smoke tests (assignment §f).

For every assigned architecture: instantiate the REDUCED variant of the
same family (2 layers, d_model<=256, <=4 experts) and run one forward +
one train step on CPU, asserting output shapes and absence of NaNs; then
check prefill+decode consistency against the full forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward_train, init_params, loss_fn,
                          prefill)
from repro.optim import AdamWConfig, adamw_update, init_adamw

KEY = jax.random.PRNGKey(0)

# jit-compile cost dominates these smokes; the heaviest arches move to the
# slow (full-CI) tier per test kind, keeping the tier-1 subset fast while
# every arch still gets forward+prefill coverage there.
_HEAVY_TRAIN = {"deepseek-moe-16b", "gemma3-1b", "mamba2-780m",
                "llama-3.2-vision-90b", "zamba2-1.2b", "qwen3-moe-30b-a3b",
                "granite-3-8b", "granite-8b", "musicgen-large"}
_HEAVY_FWD = {"deepseek-moe-16b"}


def _arch_params(heavy):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in ARCH_IDS]


def _batch(cfg, B=2, S=48):
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :S], "targets": tokens[:, 1:S + 1]}
    if cfg.family == "vlm":
        batch["vision_emb"] = jax.random.normal(
            KEY, (B, cfg.vision_seq, cfg.vision_dim), jnp.float32)
    return batch, tokens


@pytest.mark.parametrize("arch", _arch_params(_HEAVY_FWD))
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_routed <= 4
    params = init_params(cfg, KEY)
    batch, _ = _batch(cfg)
    logits, aux = forward_train(params, batch["tokens"], cfg,
                                vision_emb=batch.get("vision_emb"),
                                moe_mode="dense", remat=False)
    B, S = batch["tokens"].shape
    from repro.models.model import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg))
    logits = logits[..., :cfg.vocab]
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", _arch_params(_HEAVY_TRAIN))
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    opt = init_adamw(params)
    batch, _ = _batch(cfg, B=2, S=32)

    def loss(p):
        return loss_fn(p, batch, cfg, moe_mode="dense", remat=True)

    (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
    params2, opt2, om = adamw_update(grads, opt, params, AdamWConfig())
    assert np.isfinite(float(l))
    assert np.isfinite(float(om["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", _arch_params(_HEAVY_FWD))
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    batch, tokens = _batch(cfg, B=2, S=48)
    S = 48
    kw = {}
    if cfg.family == "vlm":
        kw["vision_emb"] = batch["vision_emb"]
    logits_full, _ = forward_train(params, tokens, cfg, moe_mode="dense",
                                   remat=False, **kw)
    lg_pre, cache = prefill(params, tokens[:, :S], cfg, max_len=S + 8,
                            moe_mode="dense", **kw)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    pos = jnp.full((2,), S, jnp.int32)
    lg_dec, _ = decode_step(params, tokens[:, S:S + 1], pos, cache, cfg,
                            moe_mode="dense")
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_moe_modes_agree():
    """dense (oracle) vs scatter (capacity) dispatch on a moe arch."""
    cfg = get_config("deepseek-moe-16b").reduced()
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    l_dense, _ = forward_train(params, tokens, cfg, moe_mode="dense", remat=False)
    l_scat, _ = forward_train(params, tokens, cfg, moe_mode="scatter", remat=False)
    # capacity factor 1.25 may drop a few tokens; allow small deviation
    diff = np.abs(np.asarray(l_dense) - np.asarray(l_scat))
    assert np.median(diff) < 1e-3
