"""The fabric must be deterministic ACROSS processes, not just within one.

The seed repo derived per-channel RNG seeds from Python's ``hash()`` of
tuples containing strings — randomised by PYTHONHASHSEED, so two identical
runs in different processes produced different SRD jitter and different
simulated times.  Seeds now come from a stable CRC-based hash; these tests
pin that contract (CI depends on it for reproducible benchmarks)."""

import os
import pathlib
import subprocess
import sys

import numpy as np

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

_PROBE = """
import numpy as np
from repro.core import Fabric, Pages
fab = Fabric(seed=5)
a = fab.add_engine("a", nic="efa")
b = fab.add_engine("b", nic="efa")
src = np.arange(64 * 1024, dtype=np.uint8) % 113
dst = np.zeros_like(src)
hs, _ = a.reg_mr(src)
_, dd = b.reg_mr(dst)
idx = Pages(tuple(range(16)), 4096)
a.submit_paged_writes(4096, 1, (hs, idx), (dd, idx))
print(f"{fab.run():.9f}")
"""


def _run_probe(hashseed: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC, PYTHONHASHSEED=hashseed)
    out = subprocess.run([sys.executable, "-c", _PROBE], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    return out.stdout.strip()


def test_simulated_time_stable_across_hash_randomisation():
    """Same fabric seed => same virtual end time, whatever PYTHONHASHSEED."""
    t1 = _run_probe("1")
    t2 = _run_probe("271828")
    assert t1 == t2, f"cross-process nondeterminism: {t1} vs {t2}"


def test_channel_seeds_stable_in_process():
    from repro.core import Fabric

    def derived(seed):
        fab = Fabric(seed=seed)
        eng = fab.add_engine("n0", nic="efa4")
        return [d._seed for d in eng.groups[0].domains]

    assert derived(3) == derived(3)
    assert derived(3) != derived(4)
