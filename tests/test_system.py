"""End-to-end system test: train -> checkpoint -> restore -> weight push
over the fabric -> disaggregated serving with the trained weights (the full
paper workflow in miniature: the RL loop trains, pushes weights P2P, and the
serving fleet decodes disaggregated)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.core import Fabric
from repro.ctrl import ControlPlane
from repro.rlweights import (ParamMeta, compute_routing, make_cluster,
                             p2p_transfer, verify_contents)
from repro.serving import Decoder, Prefiller, Scheduler
from repro.training import TrainConfig, train


@pytest.mark.slow
def test_train_checkpoint_push_serve_roundtrip():
    # stablelm: uniform KV layout — the disaggregated transfer app moves
    # per-layer pages; pattern-split archs (gemma3/vlm) use the split cache
    # and are served monolithically (launch/serve.py guards this)
    cfg = get_config("stablelm-3b").reduced()

    # 1. train a few steps
    out = train(cfg, TrainConfig(steps=8, seq_len=48, global_batch=4,
                                 log_every=4, seed=3))
    params = out["params"]
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]

    # 2. checkpoint round-trip
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save(path, {"params": params}, step=8, meta={"arch": cfg.name})
        like = {"params": jax.tree.map(jnp.zeros_like, params)}
        restored, step = restore(path, like)
        params = restored["params"]
        assert step == 8

    # 3. weight "push" to the serving fleet over the fabric (§5 pattern)
    flat = np.concatenate([np.asarray(x, np.float32).reshape(-1)
                           for x in jax.tree.leaves(params)])
    raw = flat.view(np.uint8)
    meta = [ParamMeta("flat", (raw.size,), 1)]
    routes, sizes = compute_routing(meta, n_train=4, n_infer=2, infer_tp=1)
    cl = make_cluster(4, 2, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic="efa")
    shard = -(-raw.size // 4)
    for i in range(4):
        lo = i * shard
        hi = min(raw.size, lo + shard)
        cl.train_bufs[i][:hi - lo] = raw[lo:hi]
    p2p_transfer(cl, routes)
    assert verify_contents(cl, routes)
    got = cl.infer_bufs[0][:raw.size]
    np.testing.assert_array_equal(got, raw)

    # 4. serve disaggregated with the trained weights: the fleet registers
    # with the control plane and the scheduler routes via epoch views
    fab = Fabric(seed=1)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=64)
    Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    sched = Scheduler(fab, ctrl)
    ids = np.random.default_rng(5).integers(0, cfg.vocab, size=30)
    rid = sched.submit(ids, n_decode=4)
    fab.run()
    toks = sched.completed[rid]["tokens"]
    assert len(toks) == 4 and all(0 <= t < cfg.vocab for t in toks)
