"""Control-plane tests: wire codec, epoch monotonicity, leases, drain
semantics, crash failover, and the autoscaler policy."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Fabric, MrDesc, NetAddr
from repro.ctrl import (Autoscaler, ControlClient, ControlPlane,
                        MembershipView, PeerRegistry, PeerView, ScalingPolicy)
from repro.ctrl import messages as m
from repro.models import init_params
from repro.serving import (Decoder, DispatchReq, Prefiller, Scheduler,
                           disagg_unsupported_reason)
from repro.serving.kvpool import PagedKvPool, PoolGeometry


@pytest.fixture(scope="module")
def model():
    cfg = get_config("stablelm-3b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

def test_wire_roundtrip_messages():
    desc = MrDesc(3, NetAddr("p0", 0), 4096, ((0, 123), (1, 456)))
    join = m.Join(peer_id="p0", role="prefill", addr=NetAddr("p0", 0),
                  nic="efa", kv_desc=desc,
                  geom={"n_layers": 2, "page_bytes": 2048}, n_pages=64,
                  lease_us=2000.0)
    back = m.decode(m.encode(join))
    assert back == join and isinstance(back.kv_desc, MrDesc)

    sub = m.SubmitReq(request_id=7, input_ids=np.arange(5, dtype=np.int64),
                      prefiller=NetAddr("p0", 0), n_decode=4,
                      reply_to=NetAddr("sched", 0), attempt=2)
    got = m.decode(m.encode(sub))
    np.testing.assert_array_equal(got.input_ids, sub.input_ids)
    assert (got.request_id, got.attempt, got.prefiller) == (7, 2, sub.prefiller)

    dreq = DispatchReq(input_ids=np.arange(9), decoder_addr=NetAddr("d0", 0),
                       imm=5, kv_desc=desc, pages=[4, 5, 6],
                       tail_desc=desc, tail_idx=1, request_id=3)
    got = m.decode(m.encode(dreq))
    assert got.pages == [4, 5, 6] and got.kv_desc == desc
    np.testing.assert_array_equal(got.input_ids, dreq.input_ids)

    for msg in (m.LeaseRenew("p0", 3, 12), m.Drain("p0"), m.Leave("p0"),
                m.JoinAck("p0", 4, 1500.0), m.CancelReq(9, 1),
                m.ReqDone(9, 1, "d0", 123.4, [1, 2, 3])):
        assert m.decode(m.encode(msg)) == msg

    with pytest.raises(ValueError):
        m.decode(b"XXXX\0{}")


# ---------------------------------------------------------------------------
# registry: epoch monotonicity
# ---------------------------------------------------------------------------

def test_registry_epochs_strictly_monotonic():
    reg = PeerRegistry()
    kw = dict(role="prefill", addr=NetAddr("x", 0), nic="efa", kv_desc=None,
              geom={}, n_pages=4, lease_us=100.0, now=0.0)
    assert reg.join(peer_id="a", **kw) == 1
    assert reg.join(peer_id="b", **kw) == 2
    assert reg.join(peer_id="c", **kw) == 3
    # renew refreshes liveness but is NOT a membership change
    assert reg.renew("a", now=50.0, lease_us=100.0, inflight=2, free_pages=1)
    assert reg.epoch == 3
    assert reg.start_drain("b") == 4
    assert reg.start_drain("b") is None        # already draining: no bump
    assert reg.leave("b") == 5
    assert reg.leave("b") is None
    # c's lease (expires at 100) lapses; a was renewed to 150
    died = reg.expire(now=120.0)
    assert [r.peer_id for r in died] == ["c"] and reg.epoch == 6
    epochs = [e for e, _ in reg.epoch_log]
    assert epochs == list(range(1, 7))
    view = reg.view()
    assert view.epoch == 6 and view.ids() == ("a",)
    assert view.peer("a").inflight == 2


def test_view_routable_excludes_draining():
    reg = PeerRegistry()
    kw = dict(role="prefill", addr=NetAddr("x", 0), nic="efa", kv_desc=None,
              geom={}, n_pages=4, lease_us=100.0, now=0.0)
    reg.join(peer_id="a", **kw)
    reg.join(peer_id="b", **kw)
    reg.start_drain("a")
    view = reg.view()
    assert {p.peer_id for p in view.by_role("prefill")} == {"a", "b"}
    assert [p.peer_id for p in view.routable("prefill")] == ["b"]
    # wire round-trip preserves the epoch and statuses
    back = MembershipView.from_wire(view.epoch, view.to_wire())
    assert back.epoch == view.epoch
    assert [p.peer_id for p in back.routable("prefill")] == ["b"]
    assert back.peer("a").status == "draining"


# ---------------------------------------------------------------------------
# control plane over the wire (no model: raw engines + pools)
# ---------------------------------------------------------------------------

class WirePeer:
    """Minimal control-plane citizen: engine + KV pool + ControlClient."""

    def __init__(self, fab, ctrl, name, role, n_pages=8, **kw):
        self.engine = fab.add_engine(name, nic=ctrl.nic)
        self.geom = PoolGeometry(n_layers=2, page_tokens=4, n_kv=1, head_dim=8)
        self.pool = PagedKvPool(self.engine, self.geom, n_pages)
        self.alive = True
        self.views, self.drains = [], []
        self.client = ControlClient(
            self.engine, fab, ctrl.address(), name, role,
            alive_fn=lambda: self.alive, on_drain=self.drains.append,
            on_view=self.views.append, **kw)
        self.engine.submit_recvs(1 << 14, 8, self._on_msg)
        self.client.join(nic=ctrl.nic, kv_desc=self.pool.desc,
                         geom={"page_bytes": self.geom.page_bytes},
                         n_pages=n_pages)

    def _on_msg(self, payload):
        self.client.handle(m.decode(payload))


class ViewCollector:
    """A bare subscriber engine that records every VIEW-UPDATE."""

    def __init__(self, fab, ctrl, name="watch"):
        self.engine = fab.add_engine(name, nic=ctrl.nic)
        self.views = []
        self.engine.submit_recvs(1 << 14, 16, self._on_msg)
        ctrl.subscribe(self.engine.address(0))

    def _on_msg(self, payload):
        msg = m.decode(payload)
        if isinstance(msg, m.ViewUpdate):
            self.views.append(MembershipView.from_wire(msg.epoch, msg.peers))


def test_join_publishes_descriptors_over_wire():
    fab = Fabric(seed=11)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=16)
    watch = ViewCollector(fab, ctrl)
    a = WirePeer(fab, ctrl, "pf0", "prefill", max_renewals=8)
    b = WirePeer(fab, ctrl, "dc0", "decode", max_renewals=8)
    fab.run()
    assert a.client.joined and b.client.joined
    # near-simultaneous broadcasts may be delivered out of order (SRD);
    # the epoch stamp is what lets subscribers order them
    final = max(watch.views, key=lambda v: v.epoch)
    assert final.epoch == ctrl.registry.epoch
    assert {p.peer_id for p in final.peers} == {"pf0", "dc0"}
    # the MrDesc crossed the wire and equals the locally registered one
    pf = final.peer("pf0")
    assert pf.kv_desc == a.pool.desc and pf.nic == "efa"
    assert pf.geom["page_bytes"] == a.geom.page_bytes
    # one view per membership change, each with a distinct epoch
    epochs = [v.epoch for v in watch.views]
    assert len(set(epochs)) == len(epochs)


def test_lease_expiry_marks_crashed_peer_dead():
    fab = Fabric(seed=12)
    ctrl = ControlPlane(fab, nic="efa", lease_us=500.0, sweep_us=100.0,
                        max_sweeps=40)
    watch = ViewCollector(fab, ctrl)
    a = WirePeer(fab, ctrl, "pf0", "prefill", renew_us=100.0, max_renewals=40)
    WirePeer(fab, ctrl, "pf1", "prefill", renew_us=100.0, max_renewals=40)
    fab.loop.schedule(300.0, lambda: setattr(a, "alive", False))
    fab.run()
    assert ctrl.registry.record("pf0") is None
    assert any(e == "dead:pf0" for _, e in ctrl.registry.epoch_log)
    final = max(watch.views, key=lambda v: v.epoch)
    assert final.ids() == ("pf1",)
    # pf1 kept renewing and is still live
    assert ctrl.registry.record("pf1").status == "live"


def test_scheduler_never_routes_to_draining_peer():
    fab = Fabric(seed=13)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=24)
    p0 = WirePeer(fab, ctrl, "p0", "prefill", max_renewals=12)
    WirePeer(fab, ctrl, "p1", "prefill", max_renewals=12)
    WirePeer(fab, ctrl, "d0", "decode", max_renewals=12)
    sched = Scheduler(fab, ctrl)
    fab.loop.schedule(100.0, lambda: ctrl.drain("p0"))
    for i in range(8):
        fab.loop.schedule_at(200.0 + 10.0 * i,
                             lambda: sched.submit(np.arange(4), n_decode=1))
    fab.run()
    assert p0.drains and p0.drains[0].peer_id == "p0"
    # p0 stayed in the view (status draining) but took zero new routes
    assert sched.view.peer("p0").status == "draining"
    assert len(sched.routing_log) == 8
    assert all(pf == "p1" for _, _, pf, _ in sched.routing_log)


# ---------------------------------------------------------------------------
# e2e elasticity with the real model
# ---------------------------------------------------------------------------

def test_join_route_drain_leaves_no_leaked_pages(model):
    cfg, params = model
    fab = Fabric(seed=4)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=60)
    p0 = Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl,
                   max_renewals=60)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 max_renewals=60)
    sched = Scheduler(fab, ctrl)
    rng = np.random.default_rng(1)
    rids = [sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)
            for _ in range(2)]
    # p1 JOINs mid-run, serves traffic, then is drained out
    joined = []
    fab.loop.schedule(120.0, lambda: joined.append(Prefiller(
        fab, "p1", cfg, params, nic="efa", ctrl=ctrl, max_renewals=60)))
    for i in range(3):
        fab.loop.schedule_at(300.0 + 60.0 * i, lambda: rids.append(
            sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)))
    fab.loop.schedule_at(600.0, lambda: ctrl.drain("p1"))
    fab.loop.schedule_at(900.0, lambda: rids.append(
        sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)))
    fab.run()
    assert len(sched.completed) == len(rids) == 6
    p1 = joined[0]
    # the joiner served real traffic...
    assert any(r["prefiller"] == "p1" for r in sched.completed.values())
    # ...and drained out with nothing leaked
    assert p1.client.left and p1.inflight == 0
    assert len(p1.pool._free) == p1.pool.n_pages
    assert len(p0.pool._free) == p0.pool.n_pages
    assert len(d0.pool._free) == d0.pool.n_pages and not d0._pending
    # post-drain request went to p0
    assert sched.completed[rids[-1]]["prefiller"] == "p0"
    # epochs strictly monotonic end to end
    assert sched.view_epochs == sorted(set(sched.view_epochs))


def test_decoder_drain_finishes_and_leaves(model):
    cfg, params = model
    fab = Fabric(seed=15)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=60)
    Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=60)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 max_renewals=60)
    d1 = Decoder(fab, "d1", cfg, params, nic="efa", ctrl=ctrl,
                 max_renewals=60)
    sched = Scheduler(fab, ctrl)
    rng = np.random.default_rng(3)
    rids = [sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)
            for _ in range(2)]
    fab.loop.schedule(200.0, lambda: ctrl.drain("d1"))
    for i in range(2):
        fab.loop.schedule_at(400.0 + 60.0 * i, lambda: rids.append(
            sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)))
    fab.run()
    sched.check_drained()
    assert len(sched.completed) == 4
    # d1 finished its in-flight work, freed everything, and LEFT
    assert d1.client.left and not d1._pending
    assert len(d1.pool._free) == d1.pool.n_pages
    assert ctrl.registry.record("d1") is None
    # post-drain requests all decoded on d0
    assert all(sched.completed[r]["decoder"] == "d0" for r in rids[2:])
    assert len(d0.pool._free) == d0.pool.n_pages


def test_lease_expiry_cancels_and_reroutes_inflight(model):
    cfg, params = model
    fab = Fabric(seed=9)
    ctrl = ControlPlane(fab, nic="efa", lease_us=800.0, sweep_us=200.0,
                        max_sweeps=60)
    q0 = Prefiller(fab, "q0", cfg, params, nic="efa", ctrl=ctrl,
                   renew_us=200.0, max_renewals=60)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 renew_us=200.0, max_renewals=60)
    sched = Scheduler(fab, ctrl)
    rng = np.random.default_rng(2)
    rids = [sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)
            for _ in range(3)]
    # crash q0 after it has accepted work but before transfers complete;
    # the replacement joins later, after the lease has already lapsed
    fab.loop.schedule(130.0, q0.crash)
    spare = []
    fab.loop.schedule_at(500.0, lambda: spare.append(Prefiller(
        fab, "q1", cfg, params, nic="efa", ctrl=ctrl, renew_us=200.0,
        max_renewals=60)))
    fab.run()
    # the crash was detected via lease expiry, in-flight requests were
    # cancelled at the decoder and re-routed, and all of them completed
    assert ctrl.registry.record("q0") is None
    assert set(sched.rerouted) == set(rids)
    assert len(sched.completed) == 3
    for rid in rids:
        r = sched.completed[rid]
        assert r["prefiller"] == "q1" and r["attempt"] >= 1
        assert len(r["tokens"]) == 2
    # cancelled attempts freed their pages and tail slots
    assert len(d0.pool._free) == d0.pool.n_pages
    assert len(d0._tail_free) == 16 and not d0._pending


# ---------------------------------------------------------------------------
# autoscaler policy (no fabric: synthetic signals)
# ---------------------------------------------------------------------------

class _FakeCtrl:
    def __init__(self, view):
        self._view = view
        self.drained = []

    def view(self):
        return self._view

    def drain(self, peer_id):
        self.drained.append(peer_id)


class _FakeSched:
    def __init__(self):
        self.depth = 0
        self.ttft_ema = None

    def queue_depth(self):
        return self.depth


def _pf(pid, status="live", inflight=0):
    return PeerView(peer_id=pid, role="prefill", addr=NetAddr(pid, 0),
                    nic="efa", status=status, kv_desc=None, geom={},
                    n_pages=8, inflight=inflight)


def test_autoscaler_policy_decisions():
    view = MembershipView(3, (_pf("a", inflight=2), _pf("b", inflight=0)))
    ctrl, sched = _FakeCtrl(view), _FakeSched()
    spawned = []
    pol = ScalingPolicy(queue_high=3, idle_ticks_down=2, min_prefillers=1,
                        max_prefillers=3, cooldown_us=500.0)
    sc = Autoscaler(ctrl, sched, spawned.append, policy=pol, auto=False,
                    next_index=2)
    # overload -> scale up; cooldown blocks an immediate second action
    sched.depth = 5
    assert sc.step(0.0) == "up" and spawned == [2]
    assert sc.step(100.0) is None
    # still overloaded after cooldown -> another up, capped at max (3 peers)
    assert sc.step(600.0) == "up" and spawned == [2, 3]
    sc.ctrl._view = MembershipView(5, (_pf("a", inflight=2), _pf("b"),
                                       _pf("c"), _pf("d")))
    assert sc.step(1300.0) is None          # at max_prefillers
    # idle for idle_ticks_down consecutive ticks -> drain the least loaded
    sched.depth = 0
    assert sc.step(1400.0) is None          # idle tick 1
    assert sc.step(1550.0) == "down"
    assert ctrl.drained == ["b"]            # least inflight, stable tiebreak
    # while one peer is draining, no further scale-down
    sc.ctrl._view = MembershipView(6, (_pf("a"), _pf("b", status="draining"),
                                       _pf("c"), _pf("d")))
    assert sc.step(2300.0) is None
    assert sc.step(2450.0) is None
    # TTFT SLO violation is an alternative scale-up trigger
    sc.ctrl._view = MembershipView(7, (_pf("a"),))
    sc.policy = ScalingPolicy(queue_high=99, ttft_high_us=200.0,
                              cooldown_us=0.0, max_prefillers=3)
    sched.ttft_ema = 450.0
    assert sc.step(3000.0) == "up"


def test_autoscaler_respects_min_prefillers():
    ctrl, sched = _FakeCtrl(MembershipView(1, (_pf("a"),))), _FakeSched()
    pol = ScalingPolicy(idle_ticks_down=1, min_prefillers=1, cooldown_us=0.0)
    sc = Autoscaler(ctrl, sched, lambda i: None, policy=pol, auto=False)
    for t in (0.0, 100.0, 200.0):
        assert sc.step(t) is None
    assert ctrl.drained == []


# ---------------------------------------------------------------------------
# the state-handoff guard is RETIRED: every cache shape has a KvSchema
# ---------------------------------------------------------------------------

def test_disagg_guard_retired_for_all_archs():
    """`disagg_unsupported_reason` is None for pattern-split (gemma3, vlm),
    SSM/hybrid, and first-k-dense archs — the ROADMAP guard is gone."""
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        assert disagg_unsupported_reason(get_config(arch).reduced()) is None
    # constructors admit the formerly rejected families (params untouched
    # at construction time, so None suffices here)
    fab = Fabric(seed=0)
    for i, arch in enumerate(("gemma3-1b", "mamba2-780m",
                              "deepseek-moe-16b")):
        cfg = get_config(arch).reduced()
        Prefiller(fab, f"p{i}", cfg, None, nic="efa")
        Decoder(fab, f"d{i}", cfg, None, nic="efa")


def test_scheduler_refuses_mismatched_schemas():
    """A gemma3 prefiller and a stablelm decoder must never be paired: the
    route is refused at the scheduler, not discovered mid-transfer."""
    from repro.kvlayout import schema_from_config

    fab = Fabric(seed=21)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=16)
    sched = Scheduler(fab, ctrl)
    pf = WirePeer(fab, ctrl, "p0", "prefill", max_renewals=8)
    dc = WirePeer(fab, ctrl, "d0", "decode", max_renewals=8)
    # overwrite the advertised schemas with incompatible ones
    pf_schema = schema_from_config(get_config("gemma3-1b").reduced())
    dc_schema = schema_from_config(get_config("stablelm-3b").reduced())
    fab.loop.schedule(50.0, lambda: pf.client.join(
        nic="efa", kv_desc=pf.pool.desc, geom={}, n_pages=8,
        schema=pf_schema.to_wire()))
    fab.loop.schedule(50.0, lambda: dc.client.join(
        nic="efa", kv_desc=dc.pool.desc, geom={}, n_pages=8,
        schema=dc_schema.to_wire()))
    fab.loop.schedule(200.0, lambda: sched.submit(np.arange(4), n_decode=1))
    fab.run()
    assert len(sched.routing_log) == 0
    assert sched.schema_mismatches > 0
    assert len(sched.backlog) == 1        # parked, never mis-routed
    with pytest.raises(RuntimeError, match="schema mismatches"):
        sched.check_drained()


def test_least_loaded_policy_orders_by_load():
    """policy="least-loaded" prefers the peer with the smallest effective
    load (LEASE-RENEW-piggybacked inflight, or the scheduler's own
    outstanding count when fresher); round-robin stays the default."""
    fab = Fabric(seed=22)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=4)
    sched = Scheduler(fab, ctrl, policy="least-loaded")
    assert Scheduler(fab, ctrl, node="sched2").policy == "round-robin"
    with pytest.raises(ValueError, match="unknown policy"):
        Scheduler(fab, ctrl, node="sched3", policy="busiest-first")
    sched.view = MembershipView(3, (
        _pf("a", inflight=2), _pf("b", inflight=0), _pf("c", inflight=1)))
    order = [p.peer_id for p in sched._candidates("prefill")]
    assert order == ["b", "c", "a"]
    # the scheduler's own outstanding count dominates when fresher
    sched._outstanding["b"] = 5
    order = [p.peer_id for p in sched._candidates("prefill")]
    assert order == ["c", "a", "b"]
    # round-robin rotates instead
    rr = Scheduler(fab, ctrl, node="sched4")
    rr.view = sched.view
    rr._rr["prefill"] = 1
    assert [p.peer_id for p in rr._candidates("prefill")] == ["b", "c", "a"]


def test_least_loaded_weights_by_plan_slots(model):
    """The local outstanding ledger charges each request its
    ``TransferPlan.n_slots`` on the decoder's advertised KvSchema — pool
    pressure — so one long prompt outweighs several short ones."""
    from repro.kvlayout import KvSchema, TransferPlan, schema_from_config

    cfg, _ = model
    schema = schema_from_config(cfg)
    fab = Fabric(seed=31)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=4)
    sched = Scheduler(fab, ctrl, policy="least-loaded")

    def _dc(pid):
        return PeerView(peer_id=pid, role="decode", addr=NetAddr(pid, 0),
                        nic="efa", status="live", kv_desc=None, geom={},
                        n_pages=8, inflight=0, schema=schema.to_wire())

    d1, d2 = _dc("d1"), _dc("d2")
    long_slots = sched._req_slots(d1, 400)
    short_slots = sched._req_slots(d1, 10)
    assert long_slots == TransferPlan(schema, 400).n_slots
    assert short_slots == TransferPlan(schema, 10).n_slots
    assert long_slots > 2 * short_slots
    # schema-less peers weigh 1 per request (raw count fallback)
    assert sched._req_slots(_pf("x"), 400) == 1

    # d1 holds ONE long prompt, d2 holds TWO short ones: raw request count
    # says d1 is less loaded; pool pressure says d2 is
    sched.view = MembershipView(3, (d1, d2))
    sched._outstanding = {"d1": long_slots, "d2": 2 * short_slots}
    order = [p.peer_id for p in sched._candidates("decode")]
    assert order == ["d2", "d1"]

    # the ledger releases exactly what routing charged
    st = dict(prefiller="d1", decoder="d2", slots=2 * short_slots)
    sched._release(st)
    assert sched._outstanding == {"d1": long_slots - 2 * short_slots}
    sched._release(dict(prefiller="d1", decoder="x", slots=10 ** 6))
    assert "d1" not in sched._outstanding and "x" not in sched._outstanding
