"""Sharding rules: spec trees match param trees; divisibility rules hold."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.models import init_cache, init_params
from repro.models import sharding as S


class FakeMesh:
    """Shape-only stand-in: sharding rules never touch devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_param_tree(arch):
    cfg = get_config(arch)
    spec = S.param_spec_tree(cfg, MESH)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    # identical tree structure
    jax.tree.map(lambda sh, sp: None, shapes, spec,
                 is_leaf=lambda x: isinstance(x, P))
    # every sharded dim divides evenly
    def check(sh, sp):
        assert isinstance(sp, P), f"{arch}: {sp}"
        assert len(sp) <= len(sh.shape)
        for dim, names in zip(sh.shape, tuple(sp)):
            if names is None:
                continue
            for name in ([names] if isinstance(names, str) else names):
                size = MESH.shape[name]
                assert dim % size == 0, f"{arch}: {sh.shape} {sp}"
    jax.tree.map(check, shapes, spec, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_match_cache_tree(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    from repro.configs import shape_applicable
    if not shape_applicable(cfg, shape):
        pytest.skip("long_500k requires sub-quadratic attention")
    spec = S.cache_spec_tree(cfg, MESH, shape.global_batch, shape.seq_len)
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    jax.tree.map(lambda sh, sp: None, shapes, spec,
                 is_leaf=lambda x: isinstance(x, P))

    def check(sh, sp):
        for dim, names in zip(sh.shape, tuple(sp)):
            if names is None:
                continue
            for name in ([names] if isinstance(names, str) else names):
                assert dim % MESH.shape[name] == 0, f"{arch}: {sh.shape} {sp}"
    jax.tree.map(check, shapes, spec, is_leaf=lambda x: isinstance(x, P))


def test_gqa_kv_replicated_when_heads_dont_divide():
    cfg = get_config("granite-3-8b")      # kv=8 < model=16
    spec = S.param_spec_tree(cfg, MESH)
    assert spec["layers"]["attn"]["wk"] == P(None, None, None)
    assert spec["layers"]["attn"]["wq"] == P(None, None, "model")


def test_gemma3_attention_replicated_ffn_sharded():
    cfg = get_config("gemma3-1b")          # 4 heads < 16
    spec = S.param_spec_tree(cfg, MESH)
    assert spec["layers"]["attn"]["wq"] == P(None, None, None)
    assert spec["layers"]["ffn"]["wg"] == P(None, None, "model")


def test_moe_experts_ep_sharded():
    cfg = get_config("qwen3-moe-30b-a3b")  # 128 experts / 16
    spec = S.param_spec_tree(cfg, MESH)
    assert spec["layers"]["ffn"]["wg"] == P(None, "model", None, None)


def test_long_context_cache_shards_sequence():
    cfg = get_config("gemma3-1b")
    spec = S.cache_spec_tree(cfg, MESH, batch=1, seq_len=524_288)
    # pattern-split: the special (global) layers' full-length cache shards
    # its sequence axis; the 1024-token local ring shards too (1024 % 16 == 0)
    assert spec["sk"] == P(None, None, "data", None, None)
    assert spec["lk"] == P(None, None, "data", None, None)
    # non-pattern arch still uses the uniform cache key
    spec2 = S.cache_spec_tree(get_config("stablelm-3b"), MESH, 128, 32_768)
    assert spec2["k"][1] == ("data",) or spec2["k"][1] == "data"


def test_batch_spec_multi_pod():
    cfg = get_config("granite-8b")
    spec = S.batch_spec_tree(cfg, MESH3, INPUT_SHAPES["train_4k"])
    assert spec["tokens"] == P(("pod", "data"), None)
    spec_l = S.batch_spec_tree(cfg, MESH3, INPUT_SHAPES["long_500k"])
    assert spec_l["tokens"] == P(None, None)   # batch=1 cannot shard
