"""Per-pair channel selection (heterogeneous fabrics).

Three layers of coverage:
* resolution rules — same-host pairs ride NVLink, same-kind pairs the
  sender's NIC, mixed-kind pairs the derived cross-fabric spec;
* golden regression pins — single-kind fabrics must stay BIT-identical to
  the pre-refactor timings (values captured at the pre-PR HEAD);
* subsystem integration — moekit NVLink fast path stays numerically exact
  vs the oracle and gets faster; rlweights mixed clusters deliver bytes.
"""

import numpy as np
import pytest

from repro.core import (CX7, EFA_200, NVLINK, Fabric, NetAddr, NicSpec,
                        TopoEntry, Topology, cross_spec)


# ---------------------------------------------------------------------------
# resolution rules
# ---------------------------------------------------------------------------

def test_same_host_pair_rides_nvlink():
    fab = Fabric(seed=0)
    fab.add_engine("rank0", nic="cx7", host="hostA")
    fab.add_engine("rank1", nic="cx7", host="hostA")
    spec = fab.pair_spec(NetAddr("rank0", 0), NetAddr("rank1", 0))
    assert spec is NVLINK
    assert spec.ordered and spec.srd_jitter_us == 0.0


def test_distinct_hosts_stay_on_nic():
    fab = Fabric(seed=0)
    fab.add_engine("a", nic="cx7")
    fab.add_engine("b", nic="cx7")
    assert fab.pair_spec(NetAddr("a", 0), NetAddr("b", 0)) is CX7


def test_nvlink_false_pins_same_host_pair_to_nic():
    fab = Fabric(seed=0)
    fab.add_engine("r0", nic="cx7", host="h", nvlink=False)
    fab.add_engine("r1", nic="cx7", host="h", nvlink=False)
    assert fab.pair_spec(NetAddr("r0", 0), NetAddr("r1", 0)) is CX7


def test_mixed_kind_fabric_allowed_and_uses_cross_model():
    fab = Fabric(seed=0)
    fab.add_engine("a", nic="cx7")
    fab.add_engine("b", nic="efa")   # pre-PR: ValueError
    spec = fab.pair_spec(NetAddr("a", 0), NetAddr("b", 0))
    assert spec.name == "x:cx7+efa200"
    # weaker composition of both fabrics
    assert spec.bw_gbps == min(CX7.bw_gbps, EFA_200.bw_gbps)
    assert spec.base_latency_us == CX7.base_latency_us + EFA_200.base_latency_us
    assert spec.rtt_us == CX7.rtt_us + EFA_200.rtt_us
    assert spec.mtu_bytes == min(CX7.mtu_bytes, EFA_200.mtu_bytes)
    assert not spec.ordered                 # one SRD hop => unordered
    assert spec.srd_jitter_us == EFA_200.srd_jitter_us


def test_cross_spec_symmetric_and_cached():
    assert cross_spec(CX7, EFA_200) is cross_spec(EFA_200, CX7)


def test_intra_engine_devices_keep_nvlink():
    # the pre-existing multi-device NVLink path must survive the refactor
    fab = Fabric(seed=0)
    fab.add_engine("n", nic="efa", num_devices=2)
    assert fab.pair_spec(NetAddr("n", 0), NetAddr("n", 1)) is NVLINK


def test_standalone_topology_legacy_rule():
    # unregistered endpoints fall back to the node-string rule
    topo = Topology()
    plan = topo.plan(NetAddr("x", 0), CX7, NetAddr("x", 1))
    assert plan.kind == "nvlink"
    plan = topo.plan(NetAddr("x", 0), CX7, NetAddr("y", 0))
    assert plan.kind == "nic" and plan.spec is CX7


def test_plan_cached_per_pair():
    topo = Topology()
    topo.register(NetAddr("a", 0), TopoEntry(host="ha", nic="cx7", spec=CX7))
    topo.register(NetAddr("b", 0), TopoEntry(host="hb", nic="efa",
                                             spec=EFA_200))
    p1 = topo.plan(NetAddr("a", 0), CX7, NetAddr("b", 0))
    p2 = topo.plan(NetAddr("a", 0), CX7, NetAddr("b", 0))
    assert p1 is p2 and p1.kind == "cross" and p1.dedicated


# ---------------------------------------------------------------------------
# cross-fabric transfers actually work (bytes + timing direction)
# ---------------------------------------------------------------------------

def _p2p(nic_a, nic_b, seed=0):
    fab = Fabric(seed=seed)
    a = fab.add_engine("a", nic=nic_a)
    b = fab.add_engine("b", nic=nic_b)
    data = (np.arange(1 << 20) % 199).astype(np.uint8)
    dst_buf = np.zeros(1 << 20, np.uint8)
    h, _ = a.reg_mr(data.copy())
    _, d = b.reg_mr(dst_buf)
    imm_at = {}
    b.expect_imm_count(5, 1, lambda: imm_at.setdefault("t", fab.now))
    a.submit_single_write(1 << 20, 5, (h, 0), (d, 0))
    end = fab.run()
    assert bytes(dst_buf) == bytes(data)
    return imm_at["t"], end


def test_cross_fabric_write_delivers_and_is_slower_than_either_side():
    cross_imm, _ = _p2p("cx7", "efa")
    cx7_imm, _ = _p2p("cx7", "cx7")
    # both wire hops are paid and the bottleneck bandwidth rules: the
    # cross pair can't beat the all-CX7 fabric
    assert cross_imm > cx7_imm


def test_nvlink_pair_beats_nic_pair():
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic="cx7", host="h")
    b = fab.add_engine("b", nic="cx7", host="h")
    data = (np.arange(1 << 20) % 199).astype(np.uint8)
    dst_buf = np.zeros(1 << 20, np.uint8)
    h, _ = a.reg_mr(data.copy())
    _, d = b.reg_mr(dst_buf)
    imm_at = {}
    b.expect_imm_count(5, 1, lambda: imm_at.setdefault("t", fab.now))
    a.submit_single_write(1 << 20, 5, (h, 0), (d, 0))
    fab.run()
    assert bytes(dst_buf) == bytes(data)
    nic_imm, _ = _p2p("cx7", "cx7")
    assert imm_at["t"] < nic_imm


# ---------------------------------------------------------------------------
# golden regression pins: single-kind fabrics are bit-identical
# (values captured at the pre-refactor HEAD, PYTHONHASHSEED-independent)
# ---------------------------------------------------------------------------

GOLD_P2P = {
    "cx7": (25.685284210526316, 33.68528421052632),
    "efa": (39.70032301645298, 54.37952),
    "efa4": (40.950834229927594, 55.33152000000001),
}


@pytest.mark.parametrize("nic", sorted(GOLD_P2P))
def test_single_kind_p2p_bit_identical(nic):
    imm_at, end = _p2p(nic, nic)
    gold_imm, gold_end = GOLD_P2P[nic]
    assert imm_at == gold_imm
    assert end == gold_end


GOLD_MOE = {
    "cx7": ([42.72241052631579, 42.857410526315796, 42.99780000000001,
             43.127410526315806],
            [11.568084210526337, 11.57212631578949, 11.565389473684228,
             11.569431578947388],
            62.696842105263194),
    "efa": ([72.77844476355834, 74.21273378260064, 74.47684110768868,
             74.57662774228031],
            [27.700840497471106, 27.12065384188149, 26.817621671143158,
             27.04955435094483],
            116.45454774228031),
}


def _moe_inputs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks, eidss, gatess = [], [], []
    for _ in range(cfg.n_ranks):
        toks.append(rng.standard_normal((16, 16)).astype(np.float32))
        eids = np.stack([rng.choice(8, 2, replace=False) for _ in range(16)])
        gates = np.zeros((16, 8), np.float32)
        for i in range(16):
            gates[i, eids[i]] = 0.5
        eidss.append(eids)
        gatess.append(gates)
    return toks, eidss, gatess


def _run_moe(nic, nvlink=False, nics=None):
    from repro.moekit import MoEConfig, make_endpoints, run_moe_layer
    cfg = MoEConfig(n_ranks=4, n_experts=8, top_k=2, max_tokens=16,
                    token_bytes=64, t_priv=2)
    fab = Fabric(seed=1)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=2,
                         nvlink=nvlink, nics=nics)
    toks, eidss, gatess = _moe_inputs(cfg)
    res, stats = run_moe_layer(fab, eps, toks, eidss, gatess,
                               lambda e, x: x * (e + 1))
    return res, stats, fab.now, (toks, eidss, gatess)


@pytest.mark.parametrize("nic", sorted(GOLD_MOE))
def test_single_kind_moe_bit_identical(nic):
    _res, stats, end, _ = _run_moe(nic)
    gold_d, gold_c, gold_end = GOLD_MOE[nic]
    assert stats["dispatch_us"] == gold_d
    assert stats["combine_us"] == gold_c
    assert end == gold_end


# ---------------------------------------------------------------------------
# subsystem integration
# ---------------------------------------------------------------------------

def test_moekit_nvlink_fast_path_exact_and_faster():
    from repro.moekit import oracle
    res, _stats, _end, (toks, eidss, gatess) = _run_moe("cx7", nvlink=True)
    ref = oracle(toks, eidss, gatess, lambda e, x: x * (e + 1), 8)
    for r in range(4):
        np.testing.assert_allclose(res[r], ref[r], rtol=1e-5, atol=1e-5)
    # bigger payloads: NVLink offload must strictly beat all-NIC
    from repro.moekit import MoEConfig, make_endpoints, run_moe_layer

    def big(nvl):
        cfg = MoEConfig(n_ranks=4, n_experts=8, top_k=2, max_tokens=32,
                        token_bytes=4096, t_priv=2)
        fab = Fabric(seed=1)
        eps = make_endpoints(fab, cfg, nic="cx7", gpus_per_node=4,
                             nvlink=nvl)
        rng = np.random.default_rng(0)
        toks = [rng.integers(0, 255, (32, 4096), dtype=np.uint8)
                for _ in range(4)]
        eidss = [np.stack([rng.choice(8, 2, replace=False)
                           for _ in range(32)]) for _ in range(4)]
        gatess = []
        for r in range(4):
            g = np.zeros((32, 8), np.float32)
            for i in range(32):
                g[i, eidss[r][i]] = 0.5
            gatess.append(g)
        run_moe_layer(fab, eps, toks, eidss, gatess, lambda e, x: x,
                      dtype=np.uint8)
        return fab.now

    assert big(True) < big(False)


def test_moekit_mixed_cluster_correct():
    from repro.moekit import oracle
    res, _stats, _end, (toks, eidss, gatess) = _run_moe(
        "cx7", nvlink=True, nics=["cx7", "cx7", "efa", "efa"])
    ref = oracle(toks, eidss, gatess, lambda e, x: x * (e + 1), 8)
    for r in range(4):
        np.testing.assert_allclose(res[r], ref[r], rtol=1e-5, atol=1e-5)


def test_rlweights_mixed_cluster_delivers_bytes():
    from repro.rlweights import ParamMeta, compute_routing
    from repro.rlweights import transfer as t
    params = [ParamMeta(f"p{i}", (256, 256), 2) for i in range(2)]
    routes, _ = compute_routing(params, n_train=2, n_infer=2)
    shard = max(r.src_off + r.nbytes for r in routes)
    infer_bytes = max(r.dst_off + r.nbytes for r in routes)
    cluster = t.make_cluster(2, 2, shard, infer_bytes,
                             nic="cx7", infer_nic="efa")
    assert cluster.infer_engines[0].nic_name == "efa"
    stats = t.p2p_transfer(cluster, routes, chunk_bytes="auto")
    assert stats["commits"]
    for r in routes:
        src = cluster.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes]
        dst = cluster.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes]
        assert bytes(src) == bytes(dst)


def test_autotune_uses_pair_cost_model():
    from repro.rlweights.transfer import autotune_chunk_bytes
    same = autotune_chunk_bytes("cx7", 1 << 30)
    mixed = autotune_chunk_bytes("cx7", 1 << 30, dst_nic="efa")
    # the cross pair is slower per byte and pays EFA's higher fixed cost:
    # the sweet spot moves; both stay 256 KiB-aligned
    assert mixed != same
    assert mixed % (256 << 10) == 0 and same % (256 << 10) == 0
    assert autotune_chunk_bytes("cx7", 1 << 30, dst_nic="cx7") == same


def test_ctrl_join_carries_host_and_nvlink():
    from repro.ctrl import messages as m
    msg = m.Join(peer_id="p", role="prefill", addr=NetAddr("n", 0),
                 nic="cx7", kv_desc=None, geom={}, n_pages=4,
                 lease_us=100.0, host="hostA", nvlink=True)
    decoded = m.decode(m.encode(msg))
    assert decoded.host == "hostA" and decoded.nvlink is True
    # pre-PR wire payloads (no host/nvlink keys) still decode
    legacy = m.encode(m.Join(peer_id="p", role="prefill",
                             addr=NetAddr("n", 0), nic="cx7", kv_desc=None,
                             geom={}, n_pages=4, lease_us=100.0))
    import json
    tag, _, body = legacy.partition(b"\0")
    raw = json.loads(body)
    raw.pop("host"), raw.pop("nvlink")
    stripped = tag + b"\0" + json.dumps(raw).encode()
    old = m.decode(stripped)
    assert old.host is None and old.nvlink is False


def test_registry_view_roundtrips_host_nvlink():
    from repro.ctrl.registry import MembershipView, PeerRegistry
    reg = PeerRegistry()
    reg.join(peer_id="p1", role="decode", addr=NetAddr("d", 0), nic="efa",
             kv_desc=None, geom={}, n_pages=8, lease_us=100.0, now=0.0,
             host="hostB", nvlink=True)
    view = reg.view()
    assert view.peers[0].host == "hostB" and view.peers[0].nvlink
    rt = MembershipView.from_wire(view.epoch, view.to_wire())
    assert rt.peers[0].host == "hostB" and rt.peers[0].nvlink
