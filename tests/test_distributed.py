"""Multi-device tests (subprocess: XLA device-count flag must precede jax
import, and the main test process must keep seeing ONE device)."""

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

# Each case spawns a subprocess that jit-compiles on 8-512 host devices —
# minutes of wall-clock.  Runs in the non-blocking full-suite CI job.
pytestmark = pytest.mark.slow

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_a2a_matches_dense_oracle():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_dense
    from repro.comm import moe_a2a, use_mesh
    cfg = get_config('qwen3-moe-30b-a3b').reduced()
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model)) * 0.5
    y_ref, aux_ref = moe_dense(p, h, cfg)
    with use_mesh(mesh):
        y, aux = jax.jit(lambda p, h: moe_a2a(p, h, cfg, 'model'))(p, h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
    # decode-size fallback path
    with use_mesh(mesh):
        y2, _ = jax.jit(lambda p, h: moe_a2a(p, h, cfg, 'model'))(p, h[:6])
    y2_ref, _ = moe_dense(p, h[:6], cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_ref), atol=2e-5)
    print('ok')
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, INPUT_SHAPES
    import dataclasses
    from repro.launch import steps as St
    from repro.models import init_params
    from repro.optim import init_adamw
    shape = dataclasses.replace(INPUT_SHAPES['train_4k'], seq_len=64, global_batch=4)
    cfg = get_config('gemma3-1b').reduced()
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adamw(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 65), 0, cfg.vocab)
    batch = {'tokens': toks[:, :64], 'targets': toks[:, 1:]}
    # single-device reference FIRST (the sharded step donates params)
    from repro.models import loss_fn
    (l, mm), g = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg, moe_mode='scatter'), has_aux=True)(params)

    fn, _ = St.build_train_step(cfg, mesh, shape, moe_mode='scatter')
    p2, o2, m2 = fn(params, opt, batch)
    np.testing.assert_allclose(float(m2['loss']), float(l), rtol=2e-4)
    print('ok', float(l))
    """)


def test_dryrun_production_mesh_single_and_multi_pod():
    """One representative combo on BOTH production meshes (512 devices)."""
    _run("""
    from repro.launch.dryrun import run_one
    r1 = run_one('gemma3-1b', 'decode_32k', multi_pod=False)
    assert r1['status'] == 'ok', r1
    r2 = run_one('gemma3-1b', 'decode_32k', multi_pod=True)
    assert r2['status'] == 'ok', r2
    assert r2['mesh'] == 'pod2x16x16'
    skip = run_one('granite-8b', 'long_500k')
    assert skip['status'] == 'skip'
    print('ok')
    """, devices=512)


def test_dryrun_moe_a2a_has_all_to_all():
    """The paper-style MoE path must lower to all-to-all collectives."""
    _run("""
    from repro.launch.dryrun import run_one
    r = run_one('deepseek-moe-16b', 'prefill_32k', moe_mode='a2a')
    assert r['status'] == 'ok'
    assert r['coll_breakdown'].get('all-to-all', 0) > 0, r['coll_breakdown']
    print('ok')
    """, devices=512)


def test_explicit_reshard_beats_gspmd_fallback():
    """§5 on TPU: the explicit FSDP->TP schedule (a2a + gather) moves fewer
    wire bytes than GSPMD's replicate-then-slice fallback, bit-exactly."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.comm.reshard import reshard_plan, fsdp_to_tp
    from repro.compat import make_mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    x = jnp.arange(1024*512, dtype=jnp.float32).reshape(1024, 512)
    xs = jax.device_put(x, NamedSharding(mesh, P(('data','model'), None)))
    y = jax.jit(lambda t: fsdp_to_tp(t, mesh, daxes=('data',)))(xs)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    shapes = {'w': jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16)}
    plan = reshard_plan(mesh, shapes, {'w': P(('data','model'), None)},
                        {'w': P(None, 'model')})
    assert plan['smart_wire_bytes'] < plan['gspmd_wire_bytes'], plan
    print('ok', plan['smart_vs_gspmd'])
    """)
