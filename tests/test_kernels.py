"""Per-kernel validation: shape/dtype sweeps + hypothesis vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("T,D,M", [(16, 64, 16), (50, 200, 70), (128, 512, 256),
                                   (7, 33, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_pack_sweep(T, D, M, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)), dtype)
    perm = jnp.asarray(rng.integers(-1, T, size=(M,)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ops.moe_pack(x, perm), np.float32),
        np.asarray(ref.moe_pack(x, perm), np.float32), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 70), st.integers(1, 90),
       st.integers(0, 2**16))
def test_moe_pack_property(T, D, M, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    perm = jnp.asarray(rng.integers(-1, T, size=(M,)), jnp.int32)
    np.testing.assert_allclose(ops.moe_pack(x, perm), ref.moe_pack(x, perm),
                               rtol=1e-6)


@pytest.mark.parametrize("T,D,M,K", [(16, 64, 24, 2), (64, 300, 200, 8),
                                     (5, 130, 11, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_combine_sweep(T, D, M, K, dtype):
    rng = np.random.default_rng(1)
    ye = jnp.asarray(rng.normal(size=(M, D)), dtype)
    inv = jnp.asarray(rng.integers(-1, M, size=(T, K)), jnp.int32)
    gates = jnp.asarray(rng.random(size=(T, K)), jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(ops.moe_combine(ye, inv, gates), np.float32),
        np.asarray(ref.moe_combine(ye, inv, gates), np.float32),
        rtol=tol, atol=tol)


def test_kernel_vjps_match_oracle_grads():
    rng = np.random.default_rng(2)
    T, D, M, K = 20, 32, 30, 3
    x = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    perm = jnp.asarray(rng.integers(-1, T, size=(M,)), jnp.int32)
    g1 = jax.grad(lambda x: (ops.moe_pack(x, perm) ** 2).sum())(x)
    g2 = jax.grad(lambda x: (ref.moe_pack(x, perm) ** 2).sum())(x)
    np.testing.assert_allclose(g1, g2, atol=1e-5)

    ye = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    inv = jnp.asarray(rng.integers(-1, M, size=(T, K)), jnp.int32)
    gates = jnp.asarray(rng.random(size=(T, K)), jnp.float32)
    ga = jax.grad(lambda y, g: (ops.moe_combine(y, inv, g) ** 2).sum(), (0, 1))(ye, gates)
    gb = jax.grad(lambda y, g: (ref.moe_combine(y, inv, g) ** 2).sum(), (0, 1))(ye, gates)
    np.testing.assert_allclose(ga[0], gb[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ga[1], gb[1], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Ps,Pd,E,P", [(8, 8, 128, 4), (32, 40, 300, 10),
                                       (4, 4, 4096, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_paged_copy_sweep(Ps, Pd, E, P, dtype):
    rng = np.random.default_rng(3)
    if dtype == jnp.int32:
        src = jnp.asarray(rng.integers(0, 100, (Ps, E)), dtype)
        dst = jnp.asarray(rng.integers(0, 100, (Pd, E)), dtype)
    else:
        src = jnp.asarray(rng.normal(size=(Ps, E)), dtype)
        dst = jnp.asarray(rng.normal(size=(Pd, E)), dtype)
    sidx = jnp.asarray(rng.choice(Ps, P, replace=False), jnp.int32)
    didx = jnp.asarray(rng.choice(Pd, P, replace=False), jnp.int32)
    out = ops.paged_copy(src, sidx, dst, didx)
    expect = ref.paged_copy(src, sidx, dst, didx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.sampled_from([16, 32, 64]),
       st.integers(1, 6), st.sampled_from([8, 16]), st.sampled_from([8, 24]),
       st.integers(0, 2**16))
def test_ssd_intra_property(b, nc, cl, h, p, n, seed):
    rng = np.random.default_rng(seed)
    xw = jnp.asarray(rng.normal(size=(b, nc, cl, h, p)), jnp.float32)
    dA = -jnp.asarray(rng.random(size=(b, nc, cl, h)), jnp.float32) * 0.2
    cum = jnp.cumsum(dA, axis=2)
    Br = jnp.asarray(rng.normal(size=(b, nc, cl, h, n)), jnp.float32)
    Cr = jnp.asarray(rng.normal(size=(b, nc, cl, h, n)), jnp.float32)
    y, stt = ops.ssd_intra(xw, cum, Br, Cr)
    y_r, st_r = ref.ssd_intra(xw, cum, Br, Cr)
    np.testing.assert_allclose(y, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(stt, st_r, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_ssd_kernel_inside_model():
    """End-to-end: mamba2 forward with/without the Pallas kernel agrees."""
    from repro.configs import get_config
    from repro.models import forward_train, init_params
    cfg = get_config("mamba2-780m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    l1, _ = forward_train(params, tokens, cfg, use_kernel=False, remat=False)
    l2, _ = forward_train(params, tokens, cfg, use_kernel=True, remat=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [(2, 4, 128, 64, True, 0),
                                 (1, 2, 256, 64, True, 32),
                                 (1, 1, 64, 128, False, 0)])
def test_flash_attention_vs_oracle(cfg):
    B, H, S, D, causal, win = cfg
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              block_q=64, block_k=64)
    exp = ref.flash_attention(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_flash_path_matches_chunked_in_model():
    """attn_prefill with the flash kernel (FORCE_FLASH) agrees with the
    chunked-jnp path across dense / GQA / windowed archs."""
    from repro.models import attention as A
    from repro.models import forward_train, init_params
    from repro.configs import get_config
    for arch in ("stablelm-3b", "granite-3-8b", "gemma3-1b"):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
        l_ref, _ = forward_train(params, tokens, cfg, moe_mode="dense",
                                 remat=False)
        A.FORCE_FLASH = True
        try:
            l_flash, _ = forward_train(params, tokens, cfg, moe_mode="dense",
                                       remat=False)
        finally:
            A.FORCE_FLASH = False
        np.testing.assert_allclose(np.asarray(l_flash), np.asarray(l_ref),
                                   rtol=5e-4, atol=5e-4)
