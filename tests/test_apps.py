"""Integration tests for the three production systems (§4, §5, §6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import Fabric
from repro.ctrl import ControlPlane
from repro.models import decode_step, init_params, prefill
from repro.moekit import MoEConfig, make_endpoints, oracle, run_moe_layer
from repro.rlweights import (ParamMeta, compute_routing, make_cluster,
                             p2p_transfer, rank0_transfer, schedule_stats,
                             verify_contents)
from repro.serving import Decoder, Prefiller, Scheduler


# ---------------------------------------------------------------------------
# §4 KvCache transfer
# ---------------------------------------------------------------------------

def _mono_generate(cfg, params, ids, n_decode):
    lg, cache = prefill(params, jnp.asarray(ids)[None], cfg,
                        max_len=len(ids) + 64, moe_mode="dense")
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(ids)
    for _ in range(n_decode - 1):
        lg, cache = decode_step(params, jnp.asarray([[toks[-1]]]),
                                jnp.asarray([pos], jnp.int32), cache, cfg,
                                moe_mode="dense")
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


@pytest.mark.slow
@pytest.mark.parametrize("nic", ["efa", "cx7"])
def test_disaggregated_equals_monolithic(nic):
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=3)
    ctrl = ControlPlane(fab, nic=nic, max_sweeps=64)
    Prefiller(fab, "p0", cfg, params, nic=nic, ctrl=ctrl, max_renewals=64)
    Decoder(fab, "d0", cfg, params, nic=nic, ctrl=ctrl, max_renewals=64)
    sched = Scheduler(fab, ctrl)
    ids = np.random.default_rng(0).integers(0, cfg.vocab, size=37)
    rid = sched.submit(ids, n_decode=5)
    fab.run()
    r = sched.completed[rid]
    assert r["tokens"] == _mono_generate(cfg, params, ids, 5)
    assert r["ttft_us"] > 0


@pytest.mark.slow
def test_disagg_multiple_requests_and_page_reuse():
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=5)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=64)
    Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    dec = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                  max_renewals=64)
    sched = Scheduler(fab, ctrl)
    rng = np.random.default_rng(1)
    rids = [sched.submit(rng.integers(0, cfg.vocab, size=20 + 3 * i),
                         n_decode=3) for i in range(3)]
    fab.run()
    for rid in rids:
        assert len(sched.completed[rid]["tokens"]) == 3
    # all pages returned to the pool
    assert len(dec.pool._free) == dec.pool.n_pages


def test_scheduler_drops_crashed_prefiller_from_view():
    """A crashed prefiller stops renewing its lease; the control plane
    declares it dead and the scheduler's routable view excludes it."""
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=6)
    ctrl = ControlPlane(fab, nic="efa", lease_us=1_000.0, sweep_us=250.0,
                        max_sweeps=64)
    p0 = Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl,
                   renew_us=250.0, max_renewals=64)
    p1 = Prefiller(fab, "p1", cfg, params, nic="efa", ctrl=ctrl,
                   renew_us=250.0, max_renewals=64)
    Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=64)
    sched = Scheduler(fab, ctrl)
    fab.loop.schedule(100.0, p0.crash)
    fab.run()
    assert ctrl.registry.record("p0") is None
    assert any(e.startswith("dead:p0") for _, e in ctrl.registry.epoch_log)
    routable = [p.peer_id for p in sched.view.routable("prefill")]
    assert routable == [p1.client.peer_id] == ["p1"]


def test_prefiller_cancellation_stops_transfers():
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=8)
    pf = Prefiller(fab, "p0", cfg, params, nic="efa")
    dec = Decoder(fab, "d0", cfg, params, nic="efa")
    pf.cancel(0)
    dec.submit(0, np.arange(24) % cfg.vocab, pf.address(), n_decode=2)
    fab.run()
    assert "tokens" not in dec.results.get(0, {})


# ---------------------------------------------------------------------------
# §5 RL weight transfer
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 4),
       st.integers(1, 6))
def test_routing_covers_every_inference_byte(n_train, n_infer_rep, tp, n_params):
    n_infer = n_infer_rep * tp
    params = [ParamMeta(f"w{i}", (64, 8 * (i + 1)), 2) for i in range(n_params)]
    routes, sizes = compute_routing(params, n_train, n_infer, infer_tp=tp)
    # every inference rank's buffer must be covered exactly once
    for r in range(n_infer):
        need = sizes["infer"][r]
        cover = np.zeros(need, np.int32)
        for rt in routes:
            if rt.infer_rank == r:
                cover[rt.dst_off:rt.dst_off + rt.nbytes] += 1
        assert (cover == 1).all(), f"rank {r}: coverage {cover.min()}..{cover.max()}"


def test_p2p_and_rank0_move_identical_bytes():
    params = [ParamMeta(f"w{i}", (256, 256), 2) for i in range(8)]
    routes, sizes = compute_routing(params, 4, 2, infer_tp=2)
    shard = max(sizes["train"].values())
    infb = max(sizes["infer"].values())
    c1 = make_cluster(4, 2, shard, infb, nic="cx7", seed=1)
    p2p_transfer(c1, routes)
    assert verify_contents(c1, routes)
    c2 = make_cluster(4, 2, shard, infb, nic="cx7", seed=1)
    rank0_transfer(c2, routes)
    assert verify_contents(c2, routes)
    for a, b in zip(c1.infer_bufs, c2.infer_bufs):
        assert np.array_equal(a, b)


def test_p2p_beats_rank0_and_scales():
    params = [ParamMeta(f"w{i}", (512, 512), 2) for i in range(16)]
    speeds = []
    for n_train in (4, 16):
        routes, sizes = compute_routing(params, n_train, 4, infer_tp=2)
        shard = max(sizes["train"].values())
        infb = max(sizes["infer"].values())
        ca = make_cluster(n_train, 4, shard, infb, nic="cx7")
        ra = p2p_transfer(ca, routes)
        cb = make_cluster(n_train, 4, shard, infb, nic="cx7")
        rb = rank0_transfer(cb, routes)
        speeds.append(rb["total_us"] / ra["total_us"])
    assert speeds[0] > 1.5
    assert speeds[1] > speeds[0]  # the gap grows with cluster size


# ---------------------------------------------------------------------------
# §6 MoE dispatch/combine
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16), st.sampled_from([2, 4]), st.integers(1, 2),
       st.sampled_from([4, 9]), st.sampled_from([0, 2, 64]))
def test_moekit_matches_oracle(seed, N, k_half, T, t_priv):
    rng = np.random.default_rng(seed)
    E, R, elems = 2 * N, 2 * k_half, 16
    cfgk = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                     token_bytes=elems * 4, t_priv=max(t_priv, 1))
    fab = Fabric(seed=seed)
    eps = make_endpoints(fab, cfgk, nic="efa", gpus_per_node=2)
    tokens, eids, gates = [], [], []
    for r in range(N):
        tokens.append(rng.normal(size=(T, elems)).astype(np.float32))
        ei = np.stack([rng.choice(E, R, replace=False) for _ in range(T)]).astype(np.int32)
        eids.append(ei)
        g = np.zeros((T, E), np.float32)
        for t in range(T):
            w = rng.random(R)
            g[t, ei[t]] = w / w.sum()
        gates.append(g)
    f = lambda e, x: np.tanh(x) * (e + 1)
    res, stats = run_moe_layer(fab, eps, tokens, eids, gates, f)
    ref = oracle(tokens, eids, gates, f, E)
    for r in range(N):
        np.testing.assert_allclose(res[r], ref[r], rtol=1e-4, atol=1e-4)
    assert all(d > 0 for d in stats["dispatch_us"])


def test_moekit_multi_round():
    """Two MoE layers back to back (round-scoped imm values)."""
    rng = np.random.default_rng(3)
    N, E, R, T, elems = 2, 4, 2, 8, 8
    cfgk = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                     token_bytes=elems * 4, t_priv=2)
    fab = Fabric(seed=3)
    eps = make_endpoints(fab, cfgk, nic="cx7", gpus_per_node=2)
    for layer in range(2):
        tokens = [rng.normal(size=(T, elems)).astype(np.float32) for _ in range(N)]
        eids = [np.stack([rng.choice(E, R, replace=False) for _ in range(T)]).astype(np.int32)
                for _ in range(N)]
        gates = []
        for r in range(N):
            g = np.zeros((T, E), np.float32)
            for t in range(T):
                g[t, eids[r][t]] = 1.0 / R
            gates.append(g)
        f = lambda e, x: x + e
        res, _ = run_moe_layer(fab, eps, tokens, eids, gates, f)
        ref = oracle(tokens, eids, gates, f, E)
        for r in range(N):
            np.testing.assert_allclose(res[r], ref[r], rtol=1e-4, atol=1e-4)
