"""Optional-hypothesis shim for the property-test modules.

CI installs the ``[dev]`` extra and runs the property tests for real.  In
environments without ``hypothesis`` the modules must still *collect* (the
seed repo errored collection, interrupting the whole suite): the stand-ins
below turn every ``@given`` test into a skip while leaving the example-based
tests in the same module runnable.

Set ``REQUIRE_HYPOTHESIS=1`` (CI does, on the test jobs) to turn the
silent fallback into a hard error — proof the property tests actually ran
rather than all skipping because an environment forgot the dev extra.
"""

import os

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dev extra
    import pytest

    if os.environ.get("REQUIRE_HYPOTHESIS") == "1":
        raise RuntimeError(
            "REQUIRE_HYPOTHESIS=1 but hypothesis is not importable — the "
            "property tests would all skip; install the [dev] extra")

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (install the [dev] extra "
                       "to run the property tests)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Looks enough like ``hypothesis.strategies`` to be called at
        decoration time; the decorated tests are skipped, so the returned
        placeholders are never drawn from."""

        def __getattr__(self, _name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _StrategyStub()
