"""Shared pytest configuration.

* registers the ``slow`` marker (also declared in pyproject.toml);
* pins ``PYTHONHASHSEED``-independent behaviour by asserting the fabric's
  stable seeding once per session (cheap canary against determinism
  regressions).
"""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute end-to-end case, excluded from the default/tier-1 "
        "subset (run all with -m \"\")")


@pytest.fixture(scope="session", autouse=True)
def _fabric_determinism_canary():
    """Two fabrics built in-process from the same seed must agree on the
    derived per-channel seeds (guards the stable-hash determinism fix)."""
    from repro.core import Fabric

    def derived(seed):
        fab = Fabric(seed=seed)
        eng = fab.add_engine("canary", nic="efa")
        return [d._seed for d in eng.groups[0].domains]

    assert derived(7) == derived(7)
    yield
