"""Shared pytest configuration.

* registers the ``slow`` marker (also declared in pyproject.toml);
* pins ``PYTHONHASHSEED``-independent behaviour by asserting the fabric's
  stable seeding once per session (cheap canary against determinism
  regressions).
"""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute end-to-end case, excluded from the default/tier-1 "
        "subset (run all with -m \"\")")


@pytest.fixture
def audited_fabrics(monkeypatch, tmp_path):
    """Track every Fabric built during the test and, at teardown, assert
    each one that ran to quiescence is leak-free: no un-delivered WRs, no
    unfulfilled ImmCounter expectations, no unreleased staging
    reservations (``repro.obs.assert_clean``).  Fabrics left with pending
    events were stopped mid-flight on purpose (bounded ``run_until`` /
    crash scenarios) and are skipped.  Fabric test modules opt in with a
    one-line autouse wrapper.

    Every tracked fabric also gets the always-on :class:`HealthMonitor` +
    :class:`FlightRecorder` attached (dumps into the test's tmp dir) — the
    whole audited suite doubles as the proof that always-on monitoring
    changes no simulated timing, since none of these tests expect it.

    Fault-injection tests get the same guarantee for free: an attached
    :class:`repro.core.FaultPlan` registers as an auditable, so any WR
    still tracked at quiescence (a leaked retry/guard timer) fails the
    audit, and the plan's ``outstanding()`` table is asserted empty
    explicitly — recovery AND abort paths must both drain to zero."""
    from repro.core import Fabric
    from repro.obs import FlightRecorder, HealthMonitor, assert_clean

    built = []
    orig = Fabric.__init__

    def wrapped(self, *a, **kw):
        orig(self, *a, **kw)
        HealthMonitor(self)
        FlightRecorder(self, dump_dir=str(tmp_path / "flight-dumps"))
        built.append(self)

    monkeypatch.setattr(Fabric, "__init__", wrapped)
    yield built
    for fab in built:
        if fab.loop.pending == 0:
            assert_clean(fab, allow_pending_sends=True)
            plan = getattr(fab, "faults", None)
            if plan is not None:
                assert not plan.outstanding(), plan.outstanding()


@pytest.fixture(scope="session", autouse=True)
def _fabric_determinism_canary():
    """Two fabrics built in-process from the same seed must agree on the
    derived per-channel seeds (guards the stable-hash determinism fix)."""
    from repro.core import Fabric

    def derived(seed):
        fab = Fabric(seed=seed)
        eng = fab.add_engine("canary", nic="efa")
        return [d._seed for d in eng.groups[0].domains]

    assert derived(7) == derived(7)
    yield
