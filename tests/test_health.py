"""Closed-loop observability: always-on health monitor (bit-identity,
degradation detection + attribution), flight recorder, commit anomalies,
SLO tracking, and the online chunk tuner."""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Fabric, Pages
from repro.core.netsim import degrade
from repro.obs import FlightRecorder, HealthMonitor, assert_clean
from repro.rlweights import (CommitGate, ParamMeta, commit_imm,
                             compute_routing, data_imm, make_cluster,
                             p2p_transfer, verify_contents)
from repro.serving import SloTracker

PAGE = 256 << 10          # large pages: bandwidth-dominated wire times, so
                          # a bw_scale cut is visible above base latency


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _fanout_run(nic, *, monitored=True, degrade_ab=None, n_pages=128,
                seed=5, window_wrs=32, recorder_dir=None):
    """One engine writing ``n_pages`` large pages to each of two peers;
    optionally degrade only the a->b pair before any traffic."""
    fab = Fabric(seed=seed)
    mon = HealthMonitor(fab, window_wrs=window_wrs) if monitored else None
    if monitored and recorder_dir is not None:
        FlightRecorder(fab, dump_dir=recorder_dir)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    c = fab.add_engine("c", nic=nic)
    if degrade_ab:
        assert fab.degrade_pair("a", "b", bw_scale=degrade_ab) > 0
    src = (np.arange(n_pages * PAGE) % 251).astype(np.uint8)
    dstb = np.zeros(n_pages * PAGE, np.uint8)
    dstc = np.zeros(n_pages * PAGE, np.uint8)
    hs, _ = a.reg_mr(src)
    _, db = b.reg_mr(dstb)
    _, dc = c.reg_mr(dstc)
    idx = tuple(range(n_pages))
    a.submit_paged_writes(PAGE, 1, (hs, Pages(idx, PAGE)),
                          (db, Pages(idx, PAGE)))
    a.submit_paged_writes(PAGE, 2, (hs, Pages(idx, PAGE)),
                          (dc, Pages(idx, PAGE)))
    fab.run()
    assert np.array_equal(src, dstb) and np.array_equal(src, dstc)
    return fab, mon


# ---------------------------------------------------------------------------
# the always-on invariant: monitoring changes NO simulated time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_monitored_run_is_bit_identical(nic):
    """Golden pin: HealthMonitor + FlightRecorder never schedule events and
    never draw RNG — monitored virtual time equals bare virtual time
    exactly, including through EFA's jittered SRD path."""
    fab_off, _ = _fanout_run(nic, monitored=False)
    fab_on, mon = _fanout_run(nic, monitored=True)
    assert fab_on.now == fab_off.now          # bit-identical, not approx
    assert mon.n_wrs == 256 and not mon.flags


def test_degrade_rejects_nonpositive_bw():
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic="cx7")
    ch = a.groups[0].domains[0].channel_to(
        fab.add_engine("b", nic="cx7").groups[0].addr, 0)
    with pytest.raises(ValueError):
        degrade(ch, bw_scale=0.0)


# ---------------------------------------------------------------------------
# deviation detection + attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_degraded_pair_flagged_and_attributed(nic):
    """A 4x bandwidth cut on a->b is flagged within a few observation
    windows, attributed to exactly that pair; the co-resident clean pair
    a->c never trips."""
    fab, mon = _fanout_run(nic, degrade_ab=0.25)
    flagged = {(f["src"], f["dst"]) for f in mon.flags}
    assert flagged == {("a/gpu0", "b/gpu0")}
    flag = mon.flags[0]
    assert flag["ratio"] > 1.5
    assert flag["window"] <= 3                # detected promptly
    assert mon.pairs[("a/gpu0", "b/gpu0")].flagged
    assert not mon.pairs[("a/gpu0", "c/gpu0")].flagged
    assert mon.pairs[("a/gpu0", "c/gpu0")].last_ratio <= 1.05
    assert_clean(fab, allow_pending_sends=True)


@pytest.mark.parametrize("nic", ["cx7", "efa", "efa4"])
def test_clean_fabric_never_flags(nic):
    """No false positives: observed wire time on an undegraded channel
    never exceeds the pair-spec model by the flag threshold."""
    _, mon = _fanout_run(nic)
    assert mon.flags == []
    for ph in mon.pairs.values():
        assert ph.windows >= 2                # the detector actually ran
        assert ph.last_ratio <= 1.05


def test_src_stats_and_summary_consistency():
    """Aggregations agree: per-src sums equal the per-pair sums, the
    global summary equals the whole population, segments are all
    accounted (enqueue + post + wire == total)."""
    _, mon = _fanout_run("efa")
    s = mon.src_stats("a/gpu0")
    assert s["n"] == mon.n_wrs == 256
    assert s["nbytes"] == mon.n_bytes == 256 * PAGE
    assert s["post_enqueue_ratio"] > 1.0      # batched posting
    doc = mon.summary()
    assert doc["wrs"] == 256 and len(doc["pairs"]) == 2
    for row in doc["pairs"].values():
        assert (row["enqueue_us"] + row["post_us"] + row["wire_us"]
                == pytest.approx(row["total_us"]))


def test_health_flag_dumps_flight_recorder(tmp_path):
    """The first deviation flag triggers a flight-recorder dump whose JSON
    carries the ring events and the full health summary."""
    d = str(tmp_path / "dumps")
    _, mon = _fanout_run("cx7", degrade_ab=0.25, recorder_dir=d)
    assert mon.flags
    files = sorted(os.listdir(d))
    assert files and files[0].startswith("flight_00_health-flag")
    doc = json.loads((tmp_path / "dumps" / files[0]).read_text())
    assert doc["reason"] == "health-flag"
    assert doc["events"]
    assert doc["health"]["flags"]


# ---------------------------------------------------------------------------
# flight recorder ring
# ---------------------------------------------------------------------------

def test_recorder_ring_bounded_and_audit_dump(tmp_path):
    """The ring never exceeds its capacity (memory-bounded always-on), a
    failed audit dumps it, and max_dumps caps disk usage."""
    fab = Fabric(seed=1)
    HealthMonitor(fab)
    rec = FlightRecorder(fab, capacity=16, max_dumps=2,
                         dump_dir=str(tmp_path))
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    src = np.zeros(64 * 4096, np.uint8)
    dst = np.zeros(64 * 4096, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = tuple(range(64))
    a.submit_paged_writes(4096, 1, (hs, Pages(idx, 4096)),
                          (dd, Pages(idx, 4096)))
    a.expect_imm_count(99, 5, lambda: None)   # never fulfilled -> dirty audit
    fab.run()
    assert len(rec.ring) <= 16 and rec.n_events > 16
    with pytest.raises(AssertionError):
        assert_clean(fab)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 1 and "audit-failure" in files[0]
    doc = json.loads((tmp_path / files[0]).read_text())
    assert doc["reason"] == "audit-failure"
    assert len(doc["events"]) <= 16
    # max_dumps: repeated failures stop writing after the cap
    assert rec.dump("again") is not None
    assert rec.dump("over-cap") is None
    assert len(os.listdir(tmp_path)) == 2


# ---------------------------------------------------------------------------
# commit anomalies
# ---------------------------------------------------------------------------

def _tiny_cluster(seed=3):
    params = [ParamMeta("w0", (256, 64), 2)]
    routes, sizes = compute_routing(params, 1, 1, infer_tp=1,
                                    quant_ratio=1.0)
    cl = make_cluster(1, 1, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic="cx7", seed=seed)
    return cl, routes


def test_commit_gate_rearm_is_anomalous(tmp_path):
    cl, _ = _tiny_cluster()
    FlightRecorder(cl.fabric, dump_dir=str(tmp_path))
    gate = CommitGate(cl.infer_engines[0])
    gate.arm(7, 2)
    gate.arm(7, 2)                            # double-arm: protocol bug
    assert [a["kind"] for a in gate.anomalies] == ["re-armed"]
    files = os.listdir(tmp_path)
    assert files and "commit-anomaly" in files[0]
    # leave the fabric clean for teardown-free exit
    cl.infer_engines[0].counters[0].reset(data_imm(7))
    cl.infer_engines[0].counters[0].reset(commit_imm(7))


def test_commit_gate_detects_extra_imms():
    """audit_commits flags over-delivery: more data immediates landed than
    the gate armed for (a duplicated WRITE would corrupt versioning)."""
    cl, _ = _tiny_cluster()
    eng = cl.infer_engines[0]
    gate = CommitGate(eng)
    gate.arm(3, 1)
    ctr = eng.counters[0]
    ctr.increment(data_imm(3), 0.0)
    ctr.increment(data_imm(3), 1.0)           # one too many
    ctr.increment(commit_imm(3), 2.0)
    assert len(gate.flips) == 1               # still flips exactly once
    anomalies = gate.audit_commits(3)
    assert [a["kind"] for a in anomalies] == ["extra-data-imm"]
    assert anomalies[0]["have"] == 2 and anomalies[0]["need"] == 1


# ---------------------------------------------------------------------------
# online chunk calibration (the closed loop)
# ---------------------------------------------------------------------------

def _online_setup(seed=7):
    params = [ParamMeta(f"w{i}", (4096, 1024), 2) for i in range(8)]
    routes, sizes = compute_routing(params, 2, 2, infer_tp=1,
                                    quant_ratio=1.0)
    return routes, sizes


def _online_run(mode, *, degrade_scale=None, seed=7):
    routes, sizes = _online_setup(seed)
    cl = make_cluster(2, 2, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic="efa", seed=seed)
    HealthMonitor(cl.fabric)
    if degrade_scale:
        for t in range(2):
            for i in range(2):
                cl.fabric.degrade_pair(f"train{t}", f"infer{i}",
                                       bw_scale=degrade_scale)
    stats = p2p_transfer(cl, routes, chunk_bytes=mode,
                         watermark_bytes=8 << 20)
    assert stats["committed"] and stats["commit_anomalies"] == 0
    assert verify_contents(cl, routes)
    assert_clean(cl.fabric, allow_pending_sends=True)
    return stats


def test_online_matches_auto_on_clean_fabric():
    """On an undegraded fabric the measured costs match the spec model, the
    1.5x hysteresis suppresses every retune, and the online schedule is
    bit-identical to static "auto"."""
    auto = _online_run("auto")
    online = _online_run("online")
    assert online["total_us"] == auto["total_us"]
    assert online["n_retunes"] == 0 and online["n_merges"] == 0
    assert online["chunk_bytes_final"] == online["chunk_bytes"] \
        == auto["chunk_bytes"]


def test_online_beats_auto_under_congestion():
    """With every train->infer channel cut to 1/4 bandwidth, measured
    per-WR cost (NIC backlog lands in the post segment) exceeds the spec
    model, the tuner merges the queued tail into bigger chunks, and the
    congested update strictly beats static "auto" on the same fabric."""
    auto = _online_run("auto", degrade_scale=0.25)
    online = _online_run("online", degrade_scale=0.25)
    assert online["n_retunes"] > 0 and online["n_merges"] > 0
    assert online["chunk_bytes_final"] > online["chunk_bytes"]
    assert online["writes"] < auto["writes"]  # fewer, bigger WRs
    assert online["total_us"] < auto["total_us"]


# ---------------------------------------------------------------------------
# SLO tracking
# ---------------------------------------------------------------------------

def test_slo_percentiles_match_numpy():
    rng = np.random.default_rng(2)
    xs = rng.uniform(50.0, 5000.0, size=200)
    slo = SloTracker(window=256)
    for x in xs:
        slo.observe_ttft(float(x))
        slo.observe_queue_depth(int(x) % 17)
    for p in (50, 95, 99):
        assert slo.ttft_percentile(p) == pytest.approx(np.percentile(xs, p))
    s = slo.summary()
    assert s["ttft_n"] == 200 and s["breaches"] == 0


def test_slo_window_slides():
    slo = SloTracker(window=8)
    for v in [1000.0] * 8 + [10.0] * 8:
        slo.observe_ttft(v)
    assert slo.ttft_percentile(99) == 10.0    # old samples aged out
    assert slo.n_ttft == 16


def test_slo_breach_records_and_dumps(tmp_path):
    """Crossing the SLO from ok to breached records exactly one breach (no
    re-trigger while still breached) and dumps the flight recorder once."""
    fab = Fabric(seed=0)
    FlightRecorder(fab, dump_dir=str(tmp_path))
    slo = SloTracker(fab, window=32, ttft_slo_us=100.0, min_samples=4)
    for _ in range(4):
        slo.observe_ttft(50.0)
    assert not slo.breaches
    for _ in range(8):
        slo.observe_ttft(500.0)               # p95 shoots past the SLO
    assert len(slo.breaches) == 1 and slo.in_breach
    files = os.listdir(tmp_path)
    assert len(files) == 1 and "slo-breach" in files[0]
    # recovery then re-breach -> a second record, but no second dump
    for _ in range(32):
        slo.observe_ttft(10.0)
    assert not slo.in_breach
    for _ in range(32):
        slo.observe_ttft(900.0)
    assert len(slo.breaches) == 2
    assert len(os.listdir(tmp_path)) == 1


def test_autoscaler_scales_on_percentile_not_ema():
    """A scheduler carrying an SloTracker feeds the autoscaler tail
    percentiles: a p95 blowout triggers scale-up even while the EMA
    (dragged down by many fast requests) sits below the threshold."""
    from repro.ctrl.autoscaler import Autoscaler, ScalingPolicy
    from test_ctrl import _FakeCtrl, _FakeSched, _pf
    from repro.ctrl.registry import MembershipView

    sched = _FakeSched()
    slo = SloTracker(window=64, min_samples=4)
    for _ in range(30):
        slo.observe_ttft(50.0)
    for _ in range(3):
        slo.observe_ttft(5000.0)              # 3/33 tail blowout
    sched.slo = slo
    sched.ttft_ema = 60.0                     # EMA says: all fine
    ctrl = _FakeCtrl(MembershipView(1, (_pf("a"),)))
    spawned = []
    pol = ScalingPolicy(queue_high=99, ttft_high_us=200.0,
                        ttft_percentile=95.0, cooldown_us=0.0,
                        max_prefillers=4)
    sc = Autoscaler(ctrl, sched, spawned.append, policy=pol, auto=False)
    assert slo.ttft_percentile(95.0) > 200.0 > (sched.ttft_ema or 0)
    assert sc.step(0.0) == "up" and spawned == [1]
    # without the tracker the same EMA would NOT have scaled
    sched.slo = None
    sc2 = Autoscaler(ctrl, sched, spawned.append, policy=pol, auto=False,
                     next_index=9)
    assert sc2.step(0.0) is None


# ---------------------------------------------------------------------------
# live parity (streaming counters vs post-hoc span attribution)
# ---------------------------------------------------------------------------

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_trace_report_live_parity_cli(tmp_path):
    """A trace exported from a monitored+traced fabric passes
    --live-parity (streaming per-pair sums == recomputed span sums within
    1%) and prints the per-channel health table; a bare trace fails."""
    from repro.obs import Tracer, export_chrome_trace

    def traced_run(monitored):
        fab = Fabric(seed=5)
        tr = Tracer(fab)
        if monitored:
            HealthMonitor(fab)
        a = fab.add_engine("a", nic="efa")
        b = fab.add_engine("b", nic="efa")
        src = np.zeros(64 * PAGE, np.uint8)
        dst = np.zeros(64 * PAGE, np.uint8)
        hs, _ = a.reg_mr(src)
        _, dd = b.reg_mr(dst)
        idx = tuple(range(64))
        a.submit_paged_writes(PAGE, 1, (hs, Pages(idx, PAGE)),
                              (dd, Pages(idx, PAGE)))
        fab.run()
        return tr

    path = tmp_path / "trace.json"
    export_chrome_trace(traced_run(monitored=True), str(path))
    p = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(path),
         "--live-parity", "--min-coverage", "0.5"],
        cwd=REPO, capture_output=True, text=True)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "live parity" in p.stdout and "channel" in p.stdout

    bare = tmp_path / "bare.json"
    export_chrome_trace(traced_run(monitored=False), str(bare))
    p = subprocess.run(
        [sys.executable, "tools/trace_report.py", str(bare),
         "--live-parity", "--min-coverage", "0.5"],
        cwd=REPO, capture_output=True, text=True)
    assert p.returncode == 1
    assert "no embedded health doc" in p.stderr
