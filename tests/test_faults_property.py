"""Property tests for the fault plan's exactly-once delivery contract.

For ANY seeded drop/error schedule with a sufficient retry budget:
every ImmCounter expectation fires exactly once, every submitted byte
lands bit-exact at its destination, and the plan's tracking table drains
to empty (no leaked retry state).  Runs under hypothesis when installed
(CI sets ``REQUIRE_HYPOTHESIS=1``); collects and skips cleanly without
the dev extra."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Fabric, FaultPlan


@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), fault_seed=st.integers(0, 2**16),
       drop=st.floats(0.0, 0.5), error=st.floats(0.0, 0.3),
       burst=st.integers(0, 3), n_writes=st.integers(1, 8),
       nic=st.sampled_from(["cx7", "efa"]))
def test_random_loss_schedule_delivers_exactly_once(seed, fault_seed, drop,
                                                    error, burst, n_writes,
                                                    nic):
    """drop + error <= 0.8 with 24 retries: terminal exhaustion is outside
    the search space, so every schedule must recover — exactly one imm
    event per WR, submitted bytes == delivered bytes."""
    fab = Fabric(seed=seed)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    plan = FaultPlan(fab, seed=fault_seed, timeout_us=250.0,
                     max_retries=24, backoff_us=20.0)
    plan.inject("a", "b", drop_prob=drop, error_prob=error)
    if burst:
        plan.burst("a", "b", burst)

    chunk = 4096
    src = np.random.default_rng(seed).integers(
        0, 255, n_writes * chunk, dtype=np.uint8)
    dst = np.zeros_like(src)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    fires = []
    b.expect_imm_count(4, n_writes, lambda: fires.append(fab.now))
    for i in range(n_writes):
        a.submit_single_write(chunk, 4, (hs, i * chunk), (dd, i * chunk))
    fab.run()

    assert fires and len(fires) == 1          # expectation fired exactly once
    assert b.imm_value(4) == n_writes         # one event per WR, no dupes
    assert np.array_equal(src, dst)           # delivered == submitted
    assert plan.stats["exhausted"] == 0
    assert plan.outstanding() == []           # tracking table drained


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), drop=st.floats(0.1, 0.5))
def test_fault_runs_replay_bit_identically(seed, drop):
    """Any (seed, drop) schedule replays with identical final virtual time,
    identical fault counters, and identical destination bytes."""
    def run():
        fab = Fabric(seed=seed)
        a = fab.add_engine("a", nic="efa")
        b = fab.add_engine("b", nic="efa")
        plan = FaultPlan(fab, seed=seed ^ 0x5A5A, timeout_us=250.0,
                         max_retries=24, backoff_us=20.0)
        plan.inject("a", "b", drop_prob=drop)
        src = np.random.default_rng(seed).integers(0, 255, 1 << 15,
                                                   dtype=np.uint8)
        dst = np.zeros_like(src)
        hs, _ = a.reg_mr(src)
        _, dd = b.reg_mr(dst)
        for i in range(4):
            a.submit_single_write(1 << 13, 6, (hs, i << 13), (dd, i << 13))
        fab.run()
        return fab.now, dict(plan.stats), dst.copy()

    t1, s1, d1 = run()
    t2, s2, d2 = run()
    assert t1 == t2 and s1 == s2 and np.array_equal(d1, d2)
