"""Observability package: the bit-identical tracing invariant, span
completeness under SRD shuffle, window/BatchStats parity, percentile math,
exporter validity, audit leak detection, and the report/check CLIs."""

import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Fabric, Pages
from repro.moekit import MoEConfig, make_endpoints, oracle, run_moe_layer
from repro.obs import (Histogram, MetricRegistry, Tracer, assert_clean,
                       build_trace_events, export_chrome_trace, format_audit)

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# histogram / registry math
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    """percentile() pins numpy's default linear-interpolation definition."""
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.0, 1000.0, size=173)
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    for p in (0, 10, 25, 50, 75, 90, 95, 99, 100):
        assert h.percentile(p) == pytest.approx(np.percentile(xs, p))
    s = h.summary()
    assert s["count"] == 173
    assert s["mean"] == pytest.approx(xs.mean())
    assert s["max"] == pytest.approx(xs.max())


def test_histogram_degenerate_cases():
    h = Histogram()
    assert h.percentile(50) == 0.0 and h.mean == 0.0 and h.max == 0.0
    h.observe(42.0)
    assert h.percentile(0) == h.percentile(100) == 42.0
    h.observe(44.0)
    assert h.percentile(50) == pytest.approx(43.0)


def test_registry_flattening():
    m = MetricRegistry()
    m.count("a", 2)
    m.count("a")
    m.gauge("g", 5.0)
    m.gauge("g", 3.0)
    m.observe("h", 1.0)
    m.observe("h", 3.0)
    d = m.as_dict()
    assert d["a"] == 3
    assert d["g"] == 3.0 and d["g.peak"] == 5.0
    assert d["h.count"] == 2 and d["h.mean"] == 2.0 and d["h.max"] == 3.0


# ---------------------------------------------------------------------------
# the bit-identical invariant
# ---------------------------------------------------------------------------

def _paged_run(nic, traced, n_pages=64, page=8192, seed=3):
    fab = Fabric(seed=seed)
    tr = Tracer(fab) if traced else None
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    src = (np.arange(n_pages * page) % 251).astype(np.uint8)
    dst = np.zeros(n_pages * page, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    fired = []
    b.expect_imm_count(1, n_pages, lambda: fired.append(fab.now))
    idx = tuple(range(n_pages))
    a.submit_paged_writes(page, 1, (hs, Pages(idx, page)),
                          (dd, Pages(idx, page)))
    fab.run()
    assert fired and np.array_equal(src, dst)
    return fab.now, fired[0], tr


@pytest.mark.parametrize("nic", ["cx7", "efa", "efa4"])
def test_traced_run_is_bit_identical_p2p(nic):
    """Golden pin: attaching a Tracer changes NO simulated time — the
    tracer never schedules events and never draws from any RNG."""
    t_off, fire_off, _ = _paged_run(nic, traced=False)
    t_on, fire_on, tr = _paged_run(nic, traced=True)
    assert t_on == t_off            # bit-identical, not approx
    assert fire_on == fire_off
    assert len(tr.spans) == 64 and all(s.complete for s in tr.spans)


def _moe_run(traced, nic="efa", seed=11):
    cfg = MoEConfig(n_ranks=4, n_experts=8, top_k=2, max_tokens=16,
                    token_bytes=64, t_priv=4)
    fab = Fabric(seed=seed)
    tr = Tracer(fab) if traced else None
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=2)
    rng = np.random.default_rng(5)
    tokens = [rng.normal(size=(16, 16)).astype(np.float32) for _ in range(4)]
    eids = [np.stack([rng.choice(8, 2, replace=False) for _ in range(16)])
            .astype(np.int32) for _ in range(4)]
    gates = []
    for r in range(4):
        g = np.zeros((16, 8), np.float32)
        for t in range(16):
            g[t, eids[r][t]] = 1.0 / 2
        gates.append(g)
    outs, _stats = run_moe_layer(fab, eps, tokens, eids, gates,
                                 lambda e, x: x * (1.0 + e))
    return fab.now, outs, tr, fab


def test_traced_run_is_bit_identical_moe():
    """Same invariant through the whole MoE stack (dispatch kernels, host
    proxy, SRD shuffle, ImmCounters): times AND payloads identical."""
    t_off, outs_off, _, _ = _moe_run(traced=False)
    t_on, outs_on, tr, fab = _moe_run(traced=True)
    assert t_on == t_off
    for x, y in zip(outs_off, outs_on):
        assert np.array_equal(x, y)
    # and the traced run still matches the dense oracle
    assert tr is fab.tracer and tr.spans


def test_no_orphan_spans_under_srd_shuffle():
    """Every WR submitted through the MoE round lands: zero spans missing
    t_deliver even with EFA's unordered SRD jitter, and lifecycle stamps
    are monotone."""
    _, _, tr, fab = _moe_run(traced=True, nic="efa")
    assert tr.spans, "MoE round produced no spans"
    for sp in tr.spans:
        assert sp.complete, f"orphan span: {sp.as_dict()}"
        assert sp.t_submit <= sp.t_enqueue <= sp.t_post
        assert sp.t_post0 <= sp.t_post
        assert sp.t_wire is not None and sp.t_deliver >= sp.t_wire
        assert sp.track, "span never stamped with a queue track"
    m = tr.finalize()
    assert m["wr.orphans"] == 0
    assert m["wr.complete"] == m["wr.spans"] == len(tr.spans)
    # the moe.layer window wrapped the whole round
    assert "moe.layer" in tr.windows
    # compute spans rode along (kernel launch / route processing)
    assert any(n == "kernel_launch" for _, n, _, _, _ in tr.xspans)
    assert_clean(fab)


def test_window_ratio_matches_batch_stats():
    """A window spanning the whole run must agree exactly with the
    engines' BatchStats on WRs, batches, bytes and the post/enqueue
    ratio (SENDs are excluded from both sides)."""
    fab = Fabric(seed=2)
    tr = Tracer(fab)
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    n_pages, page = 32, 4096
    src = np.zeros(n_pages * page, np.uint8)
    dst = np.zeros(n_pages * page, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = tuple(range(n_pages))
    with tr.window("prepare") as w:
        a.submit_paged_writes(page, 1, (hs, Pages(idx, page)),
                              (dd, Pages(idx, page)))
        fab.run()
    stats = a.batch_stats
    assert w.wrs == stats.wrs
    assert w.batches == stats.batches
    assert w.nbytes == stats.nbytes
    assert w.post_enqueue_ratio == stats.wrs_per_enqueue
    d = tr.metrics.as_dict()
    assert d["window.prepare.us.count"] == 1
    assert d["window.prepare.wrs_per_enqueue.p50"] == stats.wrs_per_enqueue


def test_phase_tags_spans():
    fab = Fabric(seed=0)
    tr = Tracer(fab)
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    src = np.zeros(4096, np.uint8)
    dst = np.zeros(4096, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    with tr.phase("warmup"):
        a.submit_single_write(4096, 1, (hs, 0), (dd, 0))
    a.submit_single_write(4096, 2, (hs, 0), (dd, 0))
    fab.run()
    assert [sp.phase for sp in tr.spans] == ["warmup", ""]


# ---------------------------------------------------------------------------
# gauges + ctrl instants
# ---------------------------------------------------------------------------

def test_sample_gauges_and_imm_outstanding():
    fab = Fabric(seed=0)
    tr = Tracer(fab)
    a = fab.add_engine("a", nic="efa")
    a.expect_imm_count(9, 3, lambda: None)
    tr.sample_gauges()
    d = tr.metrics.as_dict()
    assert d["imm.outstanding"] == 1
    assert "queue.backlog_max_us" in d
    assert any(name == "imm.outstanding" for _, name, _ in tr.samples)


def test_ctrl_and_autoscale_instants():
    """JOIN / DRAIN / lease-expiry all leave instant events with the
    right categories (the peer never renews, so its lease lapses)."""
    from repro.ctrl import ControlPlane
    from repro.ctrl import messages as m

    fab = Fabric(seed=4)
    tr = Tracer(fab)
    ctrl = ControlPlane(fab, lease_us=500.0, sweep_us=200.0, max_sweeps=30)
    e1 = fab.add_engine("p0", nic="efa")
    join = m.Join(peer_id="p0", role="prefill", addr=e1.address(0),
                  nic="efa", kv_desc=None, geom={}, n_pages=0,
                  lease_us=300.0)
    e1.submit_send(ctrl.address(), m.encode(join))
    fab.run_until(lambda: ctrl.registry.record("p0") is not None)
    ctrl.drain("p0")
    fab.run()                      # no renewals -> the lease expires
    cats = {c for _, c, _, _ in tr.instants}
    names = [n for _, _, n, _ in tr.instants]
    assert "ctrl" in cats
    assert any(n.startswith("join:p0") for n in names)
    assert any(n.startswith("drain:p0") for n in names)
    assert any(n.startswith("lease_expired:p0") for n in names)
    assert tr.metrics.as_dict()["instant.ctrl"] >= 3


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_export(tmp_path):
    """The exported file is valid trace-event JSON: b/e pairs match per
    op id, every queue track is declared, stamps ride in the b args."""
    _, _, tr, _ = _moe_run(traced=True)
    path = tmp_path / "trace.json"
    n = export_chrome_trace(tr, str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == n
    b = [e for e in events if e.get("ph") == "b"]
    e_ = [e for e in events if e.get("ph") == "e"]
    assert len(b) == len(tr.spans)
    assert {ev["id"] for ev in b} == {ev["id"] for ev in e_}
    tracks = {ev["args"]["name"] for ev in events
              if ev.get("ph") == "M" and ev["name"] == "process_name"}
    assert any(t.startswith("queue ") for t in tracks)
    assert {"compute + engines", "ctrl", "gauges"} <= tracks
    for ev in b:
        a = ev["args"]
        assert {"dst", "nbytes", "t_submit", "t_enqueue", "t_wire",
                "t_deliver"} <= set(a)
    # X events carry durations on the compute pid
    assert any(ev.get("ph") == "X" and ev["pid"] == 1 for ev in events)


def test_build_trace_events_orphan_has_no_end():
    fab = Fabric(seed=0)
    tr = Tracer(fab)
    sp = tr.begin_wr("write", "nowhere", 128, None)
    assert not sp.complete
    events = build_trace_events(tr)
    assert sum(1 for e in events if e.get("ph") == "b") == 1
    assert sum(1 for e in events if e.get("ph") == "e") == 0


# ---------------------------------------------------------------------------
# audit: leak detection
# ---------------------------------------------------------------------------

def test_audit_clean_after_full_run():
    fab = Fabric(seed=1)
    a = fab.add_engine("a", nic="efa")
    b = fab.add_engine("b", nic="efa")
    src = np.zeros(8192, np.uint8)
    dst = np.zeros(8192, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_single_write(8192, 1, (hs, 0), (dd, 0))
    assert fab.inflight_writes == 1          # counted at submission
    fab.run()
    assert fab.inflight_writes == 0
    report = fab.audit()
    assert report["clean"], format_audit(report)
    assert_clean(fab)


def test_audit_catches_unfulfilled_imm():
    fab = Fabric(seed=1)
    a = fab.add_engine("a", nic="efa")
    a.expect_imm_count(5, 3, lambda: None)   # nothing will ever fire this
    fab.run()
    report = fab.audit()
    assert not report["clean"]
    with pytest.raises(AssertionError, match="unfulfilled_imms"):
        assert_clean(fab)


def test_audit_pending_sends_tolerance():
    """A SEND parked with no matching RECV is visible to the audit; the
    teardown fixture tolerates it (unconsumed ctrl messages are normal)
    but the strict check does not."""
    fab = Fabric(seed=1)
    a = fab.add_engine("a", nic="efa")
    b = fab.add_engine("b", nic="efa")
    a.submit_send(b.address(0), b"orphan message")
    fab.run()
    assert fab.inflight_sends == 0           # delivered, merely unconsumed
    with pytest.raises(AssertionError, match="pending_sends"):
        assert_clean(fab)
    assert_clean(fab, allow_pending_sends=True)


def test_audit_registered_auditable():
    class Leaky:
        def audit_leaks(self):
            return {"staged_bytes": 123}

    fab = Fabric(seed=0)
    fab.register_auditable("rlweights.rank0", Leaky())
    with pytest.raises(AssertionError, match="rlweights.rank0"):
        assert_clean(fab)


# ---------------------------------------------------------------------------
# CLI tools (subprocess, as CI invokes them)
# ---------------------------------------------------------------------------

def _run_tool(args):
    return subprocess.run([sys.executable, *args], cwd=REPO,
                          capture_output=True, text=True)


def test_trace_report_cli(tmp_path):
    _, _, tr, _ = _moe_run(traced=True)
    path = tmp_path / "trace.json"
    export_chrome_trace(tr, str(path))
    # the tiny 4-rank round leaves relatively larger PCIe-poll gaps than
    # the EP32 bench trace (CI pins >=95% on that one via bench-smoke)
    p = _run_tool(["tools/trace_report.py", str(path), "--min-coverage",
                   "0.85"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "coverage:" in p.stdout
    assert "post-limited" in p.stdout or "wire-limited" in p.stdout \
        or "enqueue-limited" in p.stdout
    # an impossible floor must fail
    p = _run_tool(["tools/trace_report.py", str(path), "--min-coverage",
                   "1.01"])
    assert p.returncode == 1


def test_bench_check_cli(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    doc = {"bench": "moe", "smoke": False,
           "rows": {"r1": {"us": 100.0, "ok": True},
                    "r2": {"us": 50.0}}}
    (base / "BENCH_moe.json").write_text(json.dumps(doc))
    (fresh / "BENCH_moe.json").write_text(json.dumps(doc))
    p = _run_tool(["tools/bench_check.py", "--baseline", str(base),
                   "--new", str(fresh), "BENCH_moe.json"])
    assert p.returncode == 0, p.stdout + p.stderr

    # 30% regression on one row + a flipped invariant -> violations
    bad = {"bench": "moe", "smoke": False,
           "rows": {"r1": {"us": 130.0, "ok": False},
                    "r2": {"us": 50.0}}}
    (fresh / "BENCH_moe.json").write_text(json.dumps(bad))
    p = _run_tool(["tools/bench_check.py", "--baseline", str(base),
                   "--new", str(fresh), "--tolerance", "0.15",
                   "BENCH_moe.json"])
    assert p.returncode == 1
    assert "VIOLATION" in p.stdout and "r1.us" in p.stdout

    # smoke-scale run must never be compared against a full baseline
    (fresh / "BENCH_moe.json").write_text(
        json.dumps({**doc, "smoke": True}))
    p = _run_tool(["tools/bench_check.py", "--baseline", str(base),
                   "--new", str(fresh), "BENCH_moe.json"])
    assert p.returncode == 1
    assert "scales differ" in p.stderr
