"""Staged RL weight-update engine: chunked staging under the watermark,
window-coalesced WrBatches, two-phase commit, and the delta planner."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Fabric
from repro.rlweights import (CommitGate, ParamMeta, autotune_chunk_bytes,
                             commit_imm, compute_routing, data_imm,
                             launch_p2p_update, make_cluster, p2p_transfer,
                             plan_chunks, rank0_transfer, schedule_stats,
                             verify_contents)


@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield


def _plan(n_params=6, n_train=4, n_infer=4, tp=2, quant=0.5, changed=None):
    params = [ParamMeta(f"w{i}", (512, 64 + 32 * i), 2)
              for i in range(n_params)]
    return params, *compute_routing(params, n_train, n_infer, infer_tp=tp,
                                    quant_ratio=quant, changed=changed)


def _cluster(sizes, n_train=4, n_infer=4, nic="cx7", seed=0):
    return make_cluster(n_train, n_infer, max(sizes["train"].values()),
                        max(sizes["infer"].values()), nic=nic, seed=seed)


# ---------------------------------------------------------------------------
# bytes conservation under chunked staging
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic,chunk", [("cx7", 4096), ("efa", 8192),
                                       ("cx7", None)])
def test_chunked_staging_conserves_bytes(nic, chunk):
    """Whatever the chunking, every routed byte lands bit-exact exactly
    once and the NICs carry exactly the scheduled payload."""
    _, routes, sizes = _plan()
    cl = _cluster(sizes, nic=nic, seed=3)
    stats = p2p_transfer(cl, routes, chunk_bytes=chunk)
    assert stats["all_sent"] and verify_contents(cl, routes)
    total = sum(r.nbytes for r in routes)
    sent = sum(sum(d.nic.bytes_sent for d in e.groups[0].domains)
               for e in cl.train_engines)
    # + one accounting byte per zero-length commit-barrier descriptor
    assert sent == total + len(cl.infer_engines)
    # every inference byte covered exactly once
    for ir in range(4):
        need = sizes["infer"][ir]
        cover = np.zeros(need, np.int32)
        for r in routes:
            if r.infer_rank == ir:
                cover[r.dst_off:r.dst_off + r.nbytes] += 1
        assert (cover == 1).all()


def test_chunking_splits_to_subparameter_granularity():
    _, routes, sizes = _plan()
    chunks = plan_chunks(routes, chunk_bytes=1024, watermark_bytes=1 << 20)
    for rank, cs in chunks.items():
        assert all(c.nbytes <= 1024 for c in cs)
        # replicas are staged once: each chunk fans out to >1 target here
        assert all(len(c.targets) == 2 for c in cs)   # n_infer/tp replicas
    # chunks of one source range reassemble it exactly
    per_route = sum(r.nbytes for r in routes)
    per_chunk = sum(c.nbytes * len(c.targets)
                    for cs in chunks.values() for c in cs)
    assert per_chunk == per_route


# ---------------------------------------------------------------------------
# watermark: staging memory is bounded and the bound is honoured
# ---------------------------------------------------------------------------

def test_watermark_never_exceeded_and_serialises():
    _, routes, sizes = _plan()
    cl = _cluster(sizes, nic="cx7", seed=1)
    wm = 4096
    stats = p2p_transfer(cl, routes, watermark_bytes=wm, chunk_bytes=2048)
    assert stats["watermark_ok"] and stats["peak_staged_bytes"] <= wm
    assert verify_contents(cl, routes)
    # a generous watermark pipelines deeper and finishes no later
    cl2 = _cluster(sizes, nic="cx7", seed=1)
    stats2 = p2p_transfer(cl2, routes, watermark_bytes=1 << 30,
                          chunk_bytes=2048)
    assert stats2["peak_staged_bytes"] >= stats["peak_staged_bytes"]
    assert stats2["total_us"] <= stats["total_us"]


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8), st.sampled_from([1.0, 2.0]))
def test_watermark_property(wm_chunks, chunk_kb, stage_scale):
    """Property: for any (watermark, chunk size, stage scale), planned
    chunks individually fit the watermark and the executed pipeline's peak
    staging never exceeds it."""
    params = [ParamMeta(f"w{i}", (256, 96), 2) for i in range(3)]
    routes, sizes = compute_routing(params, 2, 2, infer_tp=1)
    chunk = chunk_kb << 10
    wm = wm_chunks * 1024
    chunks = plan_chunks(routes, chunk_bytes=chunk, watermark_bytes=wm,
                         stage_scale=stage_scale)
    assert all(c.stage_bytes <= wm for cs in chunks.values() for c in cs)
    cl = make_cluster(2, 2, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic="cx7", seed=2)
    stats = p2p_transfer(cl, routes, watermark_bytes=wm, chunk_bytes=chunk,
                         stage_scale=stage_scale)
    assert stats["watermark_ok"] and stats["peak_staged_bytes"] <= wm
    assert verify_contents(cl, routes)


# ---------------------------------------------------------------------------
# two-phase commit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7, 23])
def test_commit_fires_only_after_all_data(seed):
    """Under SRD's shuffled delivery, every inference rank flips exactly
    once, and AT the flip its whole byte range is already bit-exact —
    checked inside the flip callback, not after the run."""
    _, routes, sizes = _plan()
    cl = _cluster(sizes, nic="efa", seed=seed)
    checked = {}

    by_rank = {}
    for r in routes:
        by_rank.setdefault(r.infer_rank, []).append(r)

    # observer gates armed on the same imms the transfer will use; they
    # fire at the same ImmCounter events as the engine's own gates
    chunks = plan_chunks(routes, chunk_bytes=4096, watermark_bytes=2 << 30)
    n_data = [0] * 4
    for cs in chunks.values():
        for c in cs:
            for ir, _ in c.targets:
                n_data[ir] += 1

    gates = []
    for ir, eng in enumerate(cl.infer_engines):
        gate = CommitGate(eng)

        def on_flip(_uid, ir=ir):
            ok = all(np.array_equal(
                cl.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes],
                cl.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes])
                for r in by_rank.get(ir, []))
            checked.setdefault(ir, []).append(ok)

        gate.arm(0, n_data[ir], on_flip=on_flip)
        gates.append(gate)

    stats = p2p_transfer(cl, routes, chunk_bytes=4096)
    assert stats["committed"] and stats["commits"] == [1, 1, 1, 1]
    # observer gates flipped exactly once per rank, with all data in place
    assert sorted(checked) == [0, 1, 2, 3]
    assert all(v == [True] for v in checked.values())
    assert all(len(g.flips) == 1 and g.version == 1 for g in gates)


def test_commit_requires_both_data_and_commit_write():
    """The gate must hold with the commit write delivered BEFORE the data
    (the no-ordering contract): drive an ImmCounter by hand."""
    fab = Fabric(seed=0)
    eng = fab.add_engine("i0", nic="cx7")
    gate = CommitGate(eng)
    flips = []
    gate.arm(3, n_data=5, on_flip=flips.append)
    ctr = eng.counters[0]
    ctr.increment(commit_imm(3), now=1.0)        # commit arrives first
    assert gate.version == 0 and not flips
    for k in range(5):
        ctr.increment(data_imm(3), now=2.0 + k)  # data trickles in
        assert gate.version == (1 if k == 4 else 0)
    assert flips == [3] and len(gate.flips) == 1
    # duplicate/late events never flip again
    ctr.increment(data_imm(3), now=10.0)
    ctr.increment(commit_imm(3), now=11.0)
    assert gate.version == 1 and len(gate.flips) == 1


def test_empty_delta_update_still_commits():
    params, routes, sizes = _plan(changed=[])
    assert routes == []
    cl = _cluster(sizes, seed=5)
    stats = p2p_transfer(cl, routes, update_id=2)
    assert stats["writes"] == 0 and stats["committed"]
    assert stats["commits"] == [1, 1, 1, 1]


# ---------------------------------------------------------------------------
# delta planner
# ---------------------------------------------------------------------------

def test_delta_plan_equals_full_plan_on_dirty_subset():
    dirty = ["w1", "w3", "w4"]
    params, full, sizes_full = _plan()
    _, delta, sizes_delta = _plan(changed=dirty)
    assert sizes_full == sizes_delta          # layout identical
    assert delta == [r for r in full if r.param in dirty]
    stats = schedule_stats(delta, 4, 4, full_routes=full)
    assert stats["delta_bytes"] == sum(r.nbytes for r in delta)
    assert stats["full_bytes"] == sum(r.nbytes for r in full)
    assert 0 < stats["delta_frac"] < 1
    with pytest.raises(ValueError, match="not in params"):
        compute_routing(params, 4, 4, infer_tp=2, changed=["nope"])


def test_delta_transfer_touches_only_dirty_ranges():
    dirty = ["w0", "w2"]
    params, full, sizes = _plan(quant=1.0)
    _, delta, _ = _plan(quant=1.0, changed=dirty)
    cl = _cluster(sizes, seed=9)
    stats = p2p_transfer(cl, delta, chunk_bytes=4096)
    assert stats["committed"] and verify_contents(cl, delta)
    # clean params' destination ranges were never written
    clean = [r for r in full if r.param not in dirty]
    for r in clean:
        dst = cl.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes]
        assert not dst.any()


# ---------------------------------------------------------------------------
# batching: ImmCounter parity and windowed submission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_batched_pipeline_imm_parity_with_per_op_path(nic):
    """The windowed WrBatch submission must land the same ImmCounter state
    (one event per fully-landed chunk write) as issuing every chunk write
    as its own single WRITE."""
    _, routes, sizes = _plan()
    chunk = 4096
    cl1 = _cluster(sizes, nic=nic, seed=11)
    stats = p2p_transfer(cl1, routes, chunk_bytes=chunk)

    cl2 = _cluster(sizes, nic=nic, seed=11)
    chunks = plan_chunks(routes, chunk_bytes=chunk, watermark_bytes=2 << 30)
    n_data = [0] * 4
    for rank, cs in chunks.items():
        eng = cl2.train_engines[rank]
        h = cl2.train_handles[rank]
        for c in cs:
            for ir, doff in c.targets:
                n_data[ir] += 1
                eng.submit_single_write(c.nbytes, data_imm(0),
                                        (h, c.src_off),
                                        (cl2.infer_descs[ir], doff))
    cl2.fabric.run()
    for ir in range(4):
        assert (cl1.infer_engines[ir].imm_value(data_imm(0))
                == cl2.infer_engines[ir].imm_value(data_imm(0))
                == n_data[ir])
    for a, b in zip(cl1.infer_bufs, cl2.infer_bufs):
        assert np.array_equal(a, b)


def test_window_coalesces_chunks_into_fewer_enqueues():
    """Chunks prepared inside one pipeline window share a WrBatch: with a
    wide window the whole rank's schedule is a handful of enqueues, never
    one per chunk."""
    _, routes, sizes = _plan()
    cl = _cluster(sizes, seed=4)
    stats = p2p_transfer(cl, routes, chunk_bytes=2048, window_us=50.0)
    assert stats["n_chunks"] > 4 * stats["n_batches"]
    assert verify_contents(cl, routes)
    batches = sum(e.batch_stats.batches for e in cl.train_engines)
    assert batches == stats["n_batches"] + 1   # + the rank-0 commit barrier


def _prepr_transfer(cluster, routes, h2d_gbps, prep_gbps):
    """The seed's per-route path, verbatim: one submission per whole route
    at per-route prepare granularity — no chunking, batching, or commit."""
    fab = cluster.fabric
    by_rank = {}
    for r in routes:
        by_rank.setdefault(r.train_rank, []).append(r)
    for rank, rs in by_rank.items():
        eng = cluster.train_engines[rank]
        handle = cluster.train_handles[rank]
        t_h2d, t_prep = 0.0, 0.0
        for r in rs:
            t_h2d = t_h2d + (r.nbytes / h2d_gbps) * 1e-3
            t_prep = max(t_prep, t_h2d) + (r.nbytes / prep_gbps) * 1e-3

            def submit(r=r, eng=eng, handle=handle):
                eng.submit_single_write(
                    r.nbytes, None, (handle, r.src_off),
                    (cluster.infer_descs[r.infer_rank], r.dst_off))

            fab.loop.schedule(t_prep, submit)
    return fab.run()


# ---------------------------------------------------------------------------
# per-NIC chunk autotuning
# ---------------------------------------------------------------------------

def test_autotune_picks_per_nic_sweet_spots():
    """EFA's ~7x higher per-WR posting+fixed cost pushes its optimum to
    much larger chunks than CX7; both respect the clamps."""
    B = 63 << 30
    efa = autotune_chunk_bytes("efa", B)
    cx7 = autotune_chunk_bytes("cx7", B)
    assert efa > 2 * cx7
    from repro.rlweights.transfer import MIN_CHUNK_BYTES
    for nic in ("efa", "cx7", "efa4"):
        c = autotune_chunk_bytes(nic, B)
        assert c % MIN_CHUNK_BYTES == 0 and c >= MIN_CHUNK_BYTES
    # a tight watermark caps the chunk so at least two fit
    wm = 1 << 20
    assert autotune_chunk_bytes("efa", B, watermark_bytes=wm,
                                stage_scale=2.0) <= wm
    # larger jobs get larger chunks (sqrt scaling)
    assert autotune_chunk_bytes("efa", B) > autotune_chunk_bytes("efa", B // 64)


def test_p2p_transfer_auto_chunking_end_to_end():
    _, routes, sizes = _plan()
    for nic in ("cx7", "efa"):
        cl = _cluster(sizes, nic=nic, seed=21)
        stats = p2p_transfer(cl, routes, chunk_bytes="auto")
        assert stats["committed"] and verify_contents(cl, routes)
        assert stats["chunk_bytes"] >= 1


# ---------------------------------------------------------------------------
# rank0 baseline: commit parity with the p2p path
# ---------------------------------------------------------------------------

def test_rank0_transfer_commits_like_p2p():
    """The baseline now ends with the same two-phase commit: every
    inference rank flips exactly once, with its bytes already in place
    (checked INSIDE the flip), and the total still includes the barrier."""
    _, routes, sizes = _plan(quant=1.0)
    cl = _cluster(sizes, nic="efa", seed=8)
    by_rank = {}
    for r in routes:
        by_rank.setdefault(r.infer_rank, []).append(r)
    checked = {}
    observers = []
    for ir, eng in enumerate(cl.infer_engines):
        gate = CommitGate(eng)

        def on_flip(_uid, ir=ir):
            ok = all(np.array_equal(
                cl.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes],
                cl.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes])
                for r in by_rank.get(ir, []))
            checked.setdefault(ir, []).append(ok)

        gate.arm(0, len(by_rank.get(ir, [])), on_flip=on_flip)
        observers.append(gate)

    stats = rank0_transfer(cl, routes)
    assert stats["committed"] and stats["commits"] == [1, 1, 1, 1]
    assert verify_contents(cl, routes)
    assert sorted(checked) == [0, 1, 2, 3]
    assert all(v == [True] for v in checked.values())


# ---------------------------------------------------------------------------
# overlapping updates (async RL): gates flip in order per update_id
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_overlapping_updates_commit_in_order(nic):
    """Update 1 launches while update 0's tail is still in flight; each
    inference rank's gates flip exactly once per update_id, in order —
    data/commit immediates are update-scoped so the interleaved WRITEs
    never cross-talk."""
    _, routes, sizes = _plan(quant=1.0)
    cl = _cluster(sizes, nic=nic, seed=31)
    fab = cl.fabric

    # the next weight version lives in fresh buffers on the same engines
    rng = np.random.default_rng(99)
    handles2 = []
    for i, eng in enumerate(cl.train_engines):
        b = rng.integers(0, 255, cl.train_bufs[i].size, dtype=np.uint8)
        h, _ = eng.reg_mr(b)
        handles2.append(h)

    # observer gates (one per rank) record flip order across BOTH updates
    chunk = 2048
    n_data = {}
    for uid in (0, 1):
        chunks = plan_chunks(routes, chunk_bytes=chunk,
                             watermark_bytes=2 << 30)
        cnt = [0] * len(cl.infer_engines)
        for cs in chunks.values():
            for c in cs:
                for ir, _ in c.targets:
                    cnt[ir] += 1
        n_data[uid] = cnt
    observers = []
    for ir, eng in enumerate(cl.infer_engines):
        gate = CommitGate(eng)
        gate.arm(0, n_data[0][ir])
        gate.arm(1, n_data[1][ir])
        observers.append(gate)

    collect0 = launch_p2p_update(cl, routes, chunk_bytes=chunk, update_id=0)
    launched = {}

    def launch1() -> None:
        launched["t"] = fab.now
        launched["collect"] = launch_p2p_update(
            cl, routes, chunk_bytes=chunk, update_id=1, src_handles=handles2)

    fab.loop.schedule(40.0, launch1)   # well inside update 0's lifetime
    fab.run()

    s0, s1 = collect0(), launched["collect"]()
    assert s0["committed"] and s1["committed"]
    assert s0["commits"] == [1] * 4 and s1["commits"] == [1] * 4
    for gate in observers:
        assert gate.version == 2
        assert [uid for _, uid in gate.flips] == [0, 1]     # in order
        t0f, t1f = gate.flips[0][0], gate.flips[1][0]
        assert t0f < t1f
        # the overlap was real: update 1 started before update 0 committed
        assert launched["t"] < t0f


def test_p2p_pipelined_beats_prepr_path_simulated_time():
    """Acceptance: the staged pipeline improves simulated total vs the
    pre-PR per-route submission under the identical route schedule."""
    from repro.rlweights.transfer import H2D_GBPS, PREP_GBPS
    params = [ParamMeta(f"w{i}", (1024, 512), 2) for i in range(24)]
    routes, sizes = compute_routing(params, 8, 4, infer_tp=2,
                                    quant_ratio=0.5)
    for nic in ("cx7", "efa"):
        old = make_cluster(8, 4, max(sizes["train"].values()),
                           max(sizes["infer"].values()), nic=nic)
        t_old = _prepr_transfer(old, routes, H2D_GBPS, PREP_GBPS)
        assert verify_contents(old, routes)
        new = make_cluster(8, 4, max(sizes["train"].values()),
                           max(sizes["infer"].values()), nic=nic)
        stats = p2p_transfer(new, routes)
        assert verify_contents(new, routes)
        assert stats["total_us"] < t_old
        for a, b in zip(old.infer_bufs, new.infer_bufs):
            assert np.array_equal(a, b)   # identical schedule, same bytes
