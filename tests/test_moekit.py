"""The rebuilt moekit decode fast path (ISSUE 5 / paper §6).

Covers: bit-exactness vs the dense oracle across EP/t_priv/skewed expert
distributions, the <=2-data-WRITEs-per-peer invariant via ``batch_stats``,
route-only offset derivation (endpoints hold nothing but PeerPorts),
ImmCounter parity under SRD shuffle, and cross-process bit-stability.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Fabric, MrDesc
from repro.moekit import (MoEConfig, MoEEndpoint, PeerPorts, make_endpoints,
                          multi_arange, oracle, run_moe_layer)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield


def _mk_inputs(cfg: MoEConfig, rng, skew: str = "uniform"):
    """tokens/eids/gates per rank; ``skew`` shapes the expert distribution."""
    N, E, R, T = cfg.n_ranks, cfg.n_experts, cfg.top_k, cfg.max_tokens
    tokens, eids, gates = [], [], []
    for r in range(N):
        tokens.append(rng.normal(size=(T, cfg.token_bytes // 4))
                      .astype(np.float32))
        if skew == "hot-rank":
            # every token routes to the lowest-ranked experts (top-k stays
            # distinct, so the fewest ranks get the hottest load)
            n_pool = min(E, -(-R // cfg.e_local) * cfg.e_local)
            pool = np.arange(n_pool)
            ei = np.stack([rng.choice(pool, R, replace=False)
                           for _ in range(T)])
        elif skew == "self-heavy":
            # tokens prefer their own rank's experts, spilling to the rest
            # only when top_k exceeds e_local (top-k stays distinct)
            lo = r * cfg.e_local
            own = np.arange(lo, lo + cfg.e_local)
            rest = np.setdiff1d(np.arange(E), own)
            rows = []
            for _ in range(T):
                picks = np.concatenate([rng.permutation(own),
                                        rng.permutation(rest)])[:R]
                rows.append(picks)
            ei = np.stack(rows)
        else:
            ei = np.stack([rng.choice(E, R, replace=False) for _ in range(T)])
        ei = ei.astype(np.int32)
        eids.append(ei)
        g = np.zeros((T, E), np.float32)
        for t in range(T):
            w = rng.random(R)
            g[t, ei[t]] = (w / w.sum()).astype(np.float32)
        gates.append(g)
    return tokens, eids, gates


def _counts_matrix(cfg, eids):
    """n[i, j] = token copies rank i sends to rank j's local experts."""
    N = cfg.n_ranks
    n = np.zeros((N, N), np.int64)
    for i in range(N):
        dest = eids[i].reshape(-1) // cfg.e_local
        n[i] += np.bincount(dest, minlength=N)
    return n


# ---------------------------------------------------------------------------
# bit-exactness vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,R,T,t_priv,nic,skew", [
    (2, 2, 4, 1, "efa", "uniform"),
    (4, 4, 9, 2, "cx7", "uniform"),
    (2, 4, 9, 64, "efa", "uniform"),       # everything fits private
    (4, 2, 16, 0, "efa", "uniform"),       # no private buffers at all
    (8, 8, 16, 4, "cx7", "uniform"),
    (4, 4, 8, 2, "efa", "hot-rank"),       # max skew: one hot rank
    (4, 4, 8, 3, "cx7", "self-heavy"),
])
def test_bit_exact_vs_dense_oracle(N, R, T, t_priv, nic, skew):
    """Element-wise expert fns make the fabric result BIT-equal to the
    dense oracle (fp32 sums accumulate in the same expert-ascending
    order); checked with array_equal, not allclose."""
    rng = np.random.default_rng(N * 1000 + R * 100 + T + t_priv)
    E = max(2 * N, R)
    cfg = MoEConfig(n_ranks=N, n_experts=E, top_k=min(R, E), max_tokens=T,
                    token_bytes=64, t_priv=t_priv)
    fab = Fabric(seed=7)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=2)
    tokens, eids, gates = _mk_inputs(cfg, rng, skew)
    f = lambda e, x: np.tanh(x) * (e + 1)
    res, stats = run_moe_layer(fab, eps, tokens, eids, gates, f)
    ref = oracle(tokens, eids, gates, f, E)
    for r in range(N):
        assert np.array_equal(res[r], ref[r])
    assert all(d > 0 for d in stats["dispatch_us"])
    assert all(c > 0 for c in stats["combine_us"])


# ---------------------------------------------------------------------------
# <=2 data WRITEs per peer, asserted via batch_stats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic,t_priv,skew", [
    ("efa", 2, "uniform"), ("cx7", 2, "hot-rank"), ("efa", 0, "uniform"),
])
def test_dispatch_posts_at_most_two_data_writes_per_peer(nic, t_priv, skew):
    """Per dispatch round, each endpoint posts to each peer exactly
    1 route WRITE + (<=2) data WRITEs — private iff any tokens go there,
    shared iff they exceed the private budget — and the whole round rides
    one WrBatch enqueue per phase."""
    N, R, T = 4, 4, 12
    E = 2 * N
    cfg = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                    token_bytes=32, t_priv=t_priv)
    fab = Fabric(seed=3)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=2)
    rng = np.random.default_rng(5)
    tokens, eids, gates = _mk_inputs(cfg, rng, skew)
    n_ij = _counts_matrix(cfg, eids)

    before = [ep.engine.batch_stats.snapshot_by_dst() for ep in eps]
    before_batches = [ep.engine.batch_stats.batches for ep in eps]
    ctxs = [ep.dispatch(tokens[i].view(np.uint8).reshape(T, -1), eids[i],
                        lambda: None) for i, ep in enumerate(eps)]
    fab.run()

    for i, ep in enumerate(eps):
        after = ep.engine.batch_stats.snapshot_by_dst()
        for j, peer in enumerate(eps):
            addr = peer.engine.main_address()
            sent = after.get(addr, 0) - before[i].get(addr, 0)
            data = sent - 1                      # minus the route WRITE
            assert data <= 2, (i, j, sent)
            expect = int(min(n_ij[i, j], cfg.t_priv) > 0) + \
                int(n_ij[i, j] > cfg.t_priv)
            assert data == expect, (i, j, data, expect)
        # one WrBatch enqueue per phase: routes+private, then shared
        enq = ep.engine.batch_stats.batches - before_batches[i]
        assert enq <= 2, enq

    # combine adds at most ONE more WRITE and one enqueue per peer
    before = [ep.engine.batch_stats.snapshot_by_dst() for ep in eps]
    before_batches = [ep.engine.batch_stats.batches for ep in eps]
    for i, ep in enumerate(eps):
        slabs = ep.gather_expert_tokens(ctxs[i])
        ep.combine(ctxs[i], slabs, lambda: None)
    fab.run()
    for i, ep in enumerate(eps):
        after = ep.engine.batch_stats.snapshot_by_dst()
        for j, peer in enumerate(eps):
            addr = peer.engine.main_address()
            sent = after.get(addr, 0) - before[i].get(addr, 0)
            assert sent == int(n_ij[j, i] > 0), (i, j, sent)
        assert ep.engine.batch_stats.batches - before_batches[i] <= 1


# ---------------------------------------------------------------------------
# route-only offset derivation: endpoints know peers ONLY as PeerPorts
# ---------------------------------------------------------------------------

def test_endpoints_hold_only_peer_ports():
    """No endpoint object graph reaches another endpoint: connect() takes
    serializable PeerPorts (rank + MrDescs) and nothing else; the legacy
    ``peers`` / ``_last_ctx`` backdoors are gone."""
    cfg = MoEConfig(n_ranks=2, n_experts=4, top_k=2, max_tokens=4,
                    token_bytes=32, t_priv=1)
    fab = Fabric(seed=0)
    eps = make_endpoints(fab, cfg, nic="cx7", gpus_per_node=2)
    for ep in eps:
        assert not hasattr(ep, "peers")
        assert not hasattr(ep, "_last_ctx")
        for p in ep.ports:
            assert isinstance(p, PeerPorts)
            for d in (p.d_routes, p.d_priv, p.d_shared, p.d_comb):
                assert isinstance(d, MrDesc)
    with pytest.raises(ValueError, match="ranks 0..N-1"):
        eps[0].connect(list(reversed(eps[0].ports)))


def test_route_only_offsets_with_isolated_construction():
    """Endpoints built one at a time, wired purely through the serializable
    ports — placement must come from the wire-exchanged routes."""
    N, E, R, T = 4, 8, 2, 6
    cfg = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                    token_bytes=64, t_priv=2)
    fab = Fabric(seed=11)
    eps = []
    for r in range(N):
        eng = fab.add_engine(f"iso{r}", nic="efa")
        eps.append(MoEEndpoint(fab, cfg, r, eng))
    ports = [ep.port() for ep in eps]
    for ep in eps:
        ep.connect(ports)
    rng = np.random.default_rng(2)
    tokens, eids, gates = _mk_inputs(cfg, rng)
    f = lambda e, x: x * (e + 2)
    res, _ = run_moe_layer(fab, eps, tokens, eids, gates, f)
    ref = oracle(tokens, eids, gates, f, E)
    for r in range(N):
        assert np.array_equal(res[r], ref[r])


# ---------------------------------------------------------------------------
# ImmCounter parity under SRD shuffle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 17])
def test_srd_shuffle_parity_with_ordered_rc(seed):
    """The same round on EFA (unordered SRD, jittered delivery) and CX7
    (ordered RC) must land byte-identical results and identical ImmCounter
    totals — completion accounting never leans on delivery order."""
    N, E, R, T = 4, 8, 4, 10
    cfg = MoEConfig(n_ranks=N, n_experts=E, top_k=R, max_tokens=T,
                    token_bytes=64, t_priv=2)
    rng_in = np.random.default_rng(seed)
    inputs = _mk_inputs(cfg, rng_in)
    f = lambda e, x: np.tanh(x) + e
    results = {}
    for nic in ("efa", "cx7"):
        fab = Fabric(seed=seed)
        eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=2)
        res, _ = run_moe_layer(fab, eps, *inputs, f)
        imms = [dict(ep.engine.counters[0].counts) for ep in eps]
        results[nic] = (res, imms)
    res_e, imm_e = results["efa"]
    res_c, imm_c = results["cx7"]
    for r in range(N):
        assert np.array_equal(res_e[r], res_c[r])
    assert imm_e == imm_c


# ---------------------------------------------------------------------------
# cross-process bit-stability (PYTHONHASHSEED)
# ---------------------------------------------------------------------------

_PROBE = """
import numpy as np
from repro.core import Fabric
from repro.moekit import MoEConfig, make_endpoints, oracle, run_moe_layer
cfg = MoEConfig(n_ranks=4, n_experts=8, top_k=2, max_tokens=8,
                token_bytes=64, t_priv=2)
fab = Fabric(seed=9)
eps = make_endpoints(fab, cfg, nic="efa", gpus_per_node=2)
rng = np.random.default_rng(1)
tokens, eids, gates = [], [], []
for r in range(4):
    tokens.append(rng.normal(size=(8, 16)).astype(np.float32))
    ei = np.stack([rng.choice(8, 2, replace=False) for _ in range(8)]).astype(np.int32)
    eids.append(ei)
    g = np.zeros((8, 8), np.float32)
    for t in range(8):
        g[t, ei[t]] = 0.5
    gates.append(g)
res, stats = run_moe_layer(fab, eps, tokens, eids, gates, lambda e, x: x + e)
print(",".join(f"{d:.9f}" for d in stats["dispatch_us"]))
print(",".join(f"{c:.9f}" for c in stats["combine_us"]))
print(f"{float(np.sum([r.sum() for r in res])):.9f}")
"""


def test_moe_round_bit_stable_across_hashseed():
    """Simulated dispatch/combine stats and results are identical in
    processes with different PYTHONHASHSEED (moe.csv reproducibility)."""
    outs = []
    for hs in ("1", "31337"):
        env = dict(os.environ, PYTHONPATH=SRC, PYTHONHASHSEED=hs)
        out = subprocess.run([sys.executable, "-c", _PROBE], env=env,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        outs.append(out.stdout)
    assert outs[0] == outs[1]


def test_dispatch_rejects_duplicate_expert_slots():
    """Duplicate experts in one token's top-k would overflow the
    T*min(top_k, E/N)-sized per-source shared regions — dispatch must
    refuse them up front instead of corrupting a neighbour region."""
    cfg = MoEConfig(n_ranks=2, n_experts=4, top_k=2, max_tokens=4,
                    token_bytes=32, t_priv=1)
    fab = Fabric(seed=0)
    eps = make_endpoints(fab, cfg, nic="cx7", gpus_per_node=2)
    tokens = np.zeros((4, 32), np.uint8)
    bad = np.array([[0, 0], [1, 2], [3, 1], [2, 3]], np.int32)
    with pytest.raises(ValueError, match="distinct experts"):
        eps[0].dispatch(tokens, bad, lambda: None)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def test_multi_arange():
    out = multi_arange(np.array([5, 0, 100]), np.array([3, 0, 2]))
    assert out.tolist() == [5, 6, 7, 100, 101]
    assert multi_arange(np.array([]), np.array([])).size == 0
