"""Substrate tests: data pipeline, optimizer, checkpoint, trainer, UVM."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.core import EventLoop, UvmWatcher
from repro.data import Batcher, SyntheticCorpus
from repro.models import init_params
from repro.optim import (AdamWConfig, adamw_update, cosine_with_warmup,
                         global_norm, init_adamw)
from repro.training import TrainConfig, train


# -- data ---------------------------------------------------------------

def test_data_deterministic_and_shifted():
    c = SyntheticCorpus(vocab=500, seed=1)
    b = Batcher(c, global_batch=4, seq_len=32)
    x1, x2 = b.batch(3), b.batch(3)
    assert np.array_equal(x1["tokens"], x2["tokens"])
    assert np.array_equal(x1["tokens"][:, 1:], x1["targets"][:, :-1])
    assert (x1["tokens"] >= 0).all() and (x1["tokens"] < 500).all()


@given(st.integers(1, 4), st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_data_sharding_partitions_global_batch(num_ranks_pow, step):
    num_ranks = 2 ** (num_ranks_pow % 3)
    c = SyntheticCorpus(vocab=100, seed=0)
    gb, S = 8, 16
    full = Batcher(c, gb, S).batch(step)["tokens"]
    parts = [Batcher(c, gb, S, rank=r, num_ranks=num_ranks).batch(step)["tokens"]
             for r in range(num_ranks)]
    assert np.array_equal(np.concatenate(parts), full)


# -- optimizer -----------------------------------------------------------

def test_adamw_decreases_quadratic():
    p = {"w": jnp.asarray([3.0, -2.0])}
    st_ = init_adamw(p)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(50):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
        p, st_, _ = adamw_update(g, st_, p, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_grad_clipping_bounds_update():
    p = {"w": jnp.zeros(4)}
    st_ = init_adamw(p)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, m = adamw_update(g, st_, p, AdamWConfig(lr=1e-3, grad_clip=1.0))
    assert m["grad_norm"] > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 1e-2


def test_schedule_shape():
    assert float(cosine_with_warmup(0, warmup=10, total=100)) == 0.0
    assert float(cosine_with_warmup(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_with_warmup(100, warmup=10, total=100)) == pytest.approx(0.1)


# -- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip():
    cfg = get_config("gemma3-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save(path, {"params": params}, step=7, meta={"arch": cfg.name})
        like = {"params": jax.tree.map(jnp.zeros_like, params)}
        restored, step = restore(path, like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- trainer ------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_loss_decreases():
    cfg = get_config("stablelm-3b").reduced()
    out = train(cfg, TrainConfig(steps=12, seq_len=64, global_batch=4,
                                 log_every=4))
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]
    assert all(np.isfinite(r["loss"]) for r in h)


@pytest.mark.slow
def test_trainer_moe_arch_with_kernels():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    out = train(cfg, TrainConfig(steps=6, seq_len=32, global_batch=2,
                                 log_every=2, moe_mode="scatter"))
    assert np.isfinite(out["history"][-1]["loss"])


# -- UVM watcher -----------------------------------------------------------------

def test_uvm_watcher_coalesces():
    loop = EventLoop()
    events = []
    w = UvmWatcher(loop, lambda old, new: events.append((old, new, loop.now)))
    for i in range(5):
        loop.schedule(0.1 * i, lambda i=i: w.store(i + 1))
    loop.run_until_idle()
    assert events[-1][1] == 5
    total = sum(new - old for old, new, _ in events)
    assert total == 5  # every increment observed exactly once (coalesced ok)
