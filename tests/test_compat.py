"""Regression tests for the jax version-compat shims (AxisType / shard_map).

The seed repo imported ``jax.sharding.AxisType`` unconditionally, which
fails on jax 0.4.x; everything now routes through ``repro.compat`` and
these tests pin the fallback behaviour on whichever jax is installed."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat


def test_axis_type_flag_matches_installed_jax():
    has = hasattr(jax.sharding, "AxisType")
    assert compat.HAS_AXIS_TYPE == has
    if not has:
        # jax 0.4.x: the fallback must be active, not half-imported
        assert compat.AxisType is None


def test_make_mesh_works_without_axis_types():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_launch_mesh_module_imports_and_builds():
    # the seed failure mode was an ImportError at module import time
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    assert mesh.axis_names == ("data", "model")


def test_shard_map_wrapper_runs_and_matches():
    mesh = compat.make_mesh((1,), ("x",))
    f = compat.shard_map(lambda a: a * 2, mesh=mesh, in_specs=P(None),
                         out_specs=P(None), check_vma=False)
    out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0) * 2)
