"""Transport semantics: reliability, (un)ordering, WRITEIMM atomicity."""

import numpy as np
import pytest

from repro.core import CX7, EFA_200, Fabric, Pages

@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield



def _pair(nic: str, seed: int = 0):
    fab = Fabric(seed=seed)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    return fab, a, b


@pytest.mark.parametrize("nic", ["cx7", "efa", "efa4"])
def test_single_write_reliable(nic):
    fab, a, b = _pair(nic)
    src = (np.arange(1 << 18) % 251).astype(np.uint8)
    dst = np.zeros(1 << 18, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    fired = []
    b.expect_imm_count(3, 1, lambda: fired.append(fab.now))
    a.submit_single_write(src.size, 3, (hs, 0), (dd, 0))
    fab.run()
    assert np.array_equal(src, dst)
    assert len(fired) == 1


@pytest.mark.parametrize("nic,seed", [("efa", 0), ("efa", 7), ("cx7", 1)])
def test_paged_writes_any_order(nic, seed):
    """Pages land bit-exact under arbitrary (SRD) delivery permutations."""
    fab, a, b = _pair(nic, seed=seed)
    n_pages, page = 32, 4096
    src = np.random.default_rng(seed).integers(0, 255, n_pages * page, dtype=np.uint8)
    dst = np.zeros_like(src)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    perm = np.random.default_rng(seed + 1).permutation(n_pages)
    a.submit_paged_writes(page, 9,
                          (hs, Pages(tuple(range(n_pages)), page)),
                          (dd, Pages(tuple(int(x) for x in perm), page)))
    fab.run()
    for i in range(n_pages):
        assert np.array_equal(src[i * page:(i + 1) * page],
                              dst[perm[i] * page:(perm[i] + 1) * page])
    assert b.imm_value(9) == n_pages


def test_imm_only_after_full_payload():
    """WRITEIMM atomicity: when the counter fires, the payload IS there.

    We deliberately use a large write (many MTU chunks) on SRD, and check
    inside the callback — not after the run — that the destination matches.
    """
    fab, a, b = _pair("efa", seed=42)
    src = (np.arange(1 << 20) % 199).astype(np.uint8)
    dst = np.zeros(1 << 20, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    checked = []

    def on_fire():
        checked.append(bool(np.array_equal(src, dst)))

    b.expect_imm_count(5, 1, on_fire)
    a.submit_single_write(src.size, 5, (hs, 0), (dd, 0))
    fab.run()
    assert checked == [True]


def test_rc_faster_than_efa_small_writes():
    """Latency model sanity: CX-7 completes small writes sooner than EFA."""
    times = {}
    for nic in ("cx7", "efa"):
        fab, a, b = _pair(nic)
        src = np.zeros(64 << 10, np.uint8)
        dst = np.zeros(64 << 10, np.uint8)
        hs, _ = a.reg_mr(src)
        _, dd = b.reg_mr(dst)
        b.expect_imm_count(1, 1, lambda: None)
        a.submit_single_write(src.size, 1, (hs, 0), (dd, 0))
        times[nic] = fab.run()
    assert times["cx7"] < times["efa"]


def test_out_of_bounds_write_rejected():
    fab, a, b = _pair("cx7")
    src = np.zeros(4096, np.uint8)
    dst = np.zeros(1024, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_single_write(4096, None, (hs, 0), (dd, 0))
    with pytest.raises(IndexError):
        fab.run()


def test_nvlink_intra_node_fast_path():
    fab = Fabric(seed=0)
    e = fab.add_engine("node0", nic="efa", num_devices=2)
    src = np.arange(1 << 16, dtype=np.uint8) % 101
    dst = np.zeros(1 << 16, np.uint8)
    hs, _ = e.reg_mr(src, device=0)
    _, dd = e.reg_mr(dst, device=1)
    e.submit_single_write(src.size, 2, (hs, 0), (dd, 0))
    t = fab.run()
    assert np.array_equal(src, dst)
    assert t < 10.0  # NVLink-class latency, far below EFA's ~31us rtt


# ---------------------------------------------------------------------------
# SRD jitter granularity under coarse chunking
# ---------------------------------------------------------------------------

class _CountingRng:
    """Wraps a Generator to record scalar-uniform vs max-of-n draws."""

    def __init__(self, rng):
        self._rng = rng
        self.uniforms = 0     # single-packet chunks: scalar uniform draw
        self.maxdraws = 0     # multi-packet chunks: one inverse-CDF draw

    def uniform(self, lo, hi):
        self.uniforms += 1
        return self._rng.uniform(lo, hi)

    def random(self):
        self.maxdraws += 1
        return self._rng.random()


def test_rc_channel_never_draws_jitter():
    """The ordered (CX7) path must not consume randomness — pinning that
    finer SRD modeling leaves every RC-transport result bit-identical."""
    from repro.core.netsim import EventLoop, NicQueue, CX7
    from repro.core.transport import Channel, WireOp

    loop = EventLoop()
    ch = Channel(loop, NicQueue(loop, CX7), seed=1)

    class _Poison:
        def uniform(self, *a, **k):
            raise AssertionError("ordered channel drew jitter")

    ch.rng = _Poison()
    done = []
    ch.post(WireOp(kind="write", payload=None, dst_region=None, dst_offset=0,
                   imm=None, on_delivered=lambda op, now: done.append(now),
                   nbytes=32 << 20))
    loop.run_until_idle()
    assert len(done) == 1


def test_srd_multipacket_chunks_draw_per_packet_jitter():
    """When MAX_CHUNKS makes one coarse chunk span several MTU packets, the
    chunk's jitter is the max over its per-packet jitters (drawn in O(1)
    via the inverse CDF of max-of-n); single-packet chunks keep the exact
    scalar draw (bit-identical small-write RNG stream)."""
    from repro.core.netsim import EventLoop, NicQueue, EFA_200
    from repro.core.transport import Channel, WireOp

    mtu = EFA_200.mtu_bytes
    # 64 coarse chunks x 3 packets each -> one max-of-3 draw per chunk
    loop = EventLoop()
    ch = Channel(loop, NicQueue(loop, EFA_200), seed=2)
    ch.rng = _CountingRng(ch.rng)
    done = []
    ch.post(WireOp(kind="write", payload=None, dst_region=None, dst_offset=0,
                   imm=None, on_delivered=lambda op, now: done.append(now),
                   nbytes=Channel.MAX_CHUNKS * 3 * mtu))
    loop.run_until_idle()
    assert len(done) == 1
    assert ch.rng.maxdraws == Channel.MAX_CHUNKS and ch.rng.uniforms == 0

    # sub-MTU chunks: one scalar draw per chunk, exactly as before
    loop2 = EventLoop()
    ch2 = Channel(loop2, NicQueue(loop2, EFA_200), seed=2)
    ch2.rng = _CountingRng(ch2.rng)
    ch2.post(WireOp(kind="write", payload=None, dst_region=None, dst_offset=0,
                    imm=None, on_delivered=lambda op, now: done.append(now),
                    nbytes=4 * mtu))
    loop2.run_until_idle()
    assert ch2.rng.uniforms == 4 and ch2.rng.maxdraws == 0
