"""Launcher entrypoints run end-to-end (subprocess smoke)."""

import os
import pathlib
import subprocess
import sys

import pytest

# Full train/serve launcher subprocesses — minutes of wall-clock each.
pytestmark = pytest.mark.slow

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_train_launcher():
    out = _run(["repro.launch.train", "--arch", "stablelm-3b",
                "--steps", "4", "--log-every", "2",
                "--seq-len", "32", "--global-batch", "2"])
    assert "loss" in out


def test_serve_launcher_disagg():
    out = _run(["repro.launch.serve", "--arch", "stablelm-3b",
                "--requests", "2", "--prompt-len", "24", "--decode", "3",
                "--disagg"])
    assert "disaggregated == monolithic for 2/2" in out


def test_train_launcher_moe():
    out = _run(["repro.launch.train", "--arch", "deepseek-moe-16b",
                "--steps", "3", "--log-every", "1",
                "--seq-len", "32", "--global-batch", "2"])
    assert "loss" in out
