"""Reliable-control-plane tests: idempotent ctrl RPCs under SEND loss,
epoch-fenced data writes, and partition/re-join reconciliation.

Covers the PR's three pillars end to end:

* **retryable ctrl RPCs** — golden unstamped wire bytes (the reliability
  envelope adds zero bytes until a sender opts in), the ``_rpc`` stamp
  round-trip, receiver-side dedup windows, JOIN-ack-loss recovery, and
  registry idempotency (epoch bumps exactly once per membership change,
  no matter how SENDs are duplicated);
* **epoch fencing** — a zombie prefiller (lease lapsed, process still
  computing) keeps WRITing after the scheduler re-routes; every late WRITE
  is rejected at the decoder's engine fence, the flight recorder dumps the
  fenced WR, and the re-routed request still produces monolithic-exact
  tokens;
* **partition re-join** — a peer cut off from the plane exhausts its renew
  retry budget, re-JOINs with ``prior_epoch`` advertised, and the registry
  reconciles under a fresh epoch; plus the full membership-churn storm
  (join + drain + crash + partition) under 10% ctrl-SEND loss with zero
  leaked pages and exactly-once adoption.

Property tests ride the optional-hypothesis shim (CI sets
``REQUIRE_HYPOTHESIS=1``; without the dev extra they skip-clean).
"""

import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Fabric, FaultPlan, NetAddr
from repro.ctrl import (Autoscaler, ControlClient, ControlPlane,
                        CtrlRetryPolicy, DedupWindow, MembershipView,
                        PeerRegistry, ScalingPolicy)
from repro.ctrl import messages as m
from test_ctrl import WirePeer as _Peer
from test_ctrl import _FakeCtrl, _FakeSched, _pf


@pytest.fixture(scope="module")
def model():
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("stablelm-3b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# wire codec: golden bytes, RPC envelope, forward compatibility
# ---------------------------------------------------------------------------

def test_unstamped_wire_bytes_golden():
    """The reliability envelope is pay-for-what-you-use: unstamped
    encodings are bit-exact with the pre-PR wire format (literal bytes
    pinned here so a codec change cannot slip through)."""
    assert m.encode(m.LeaseRenew("p0", 3, 12)) == (
        b'LEAS\x00{"peer_id":"p0","inflight":3,"free_pages":12}')
    assert m.encode(m.Leave("p0")) == b'LEAV\x00{"peer_id":"p0"}'
    assert m.encode(m.Drain("p0")) == (
        b'DRAN\x00{"peer_id":"p0","reason":"scale-down"}')
    # CANCEL omits its optional fence fields while None
    assert m.encode(m.CancelReq(9, 1)) == (
        b'CANC\x00{"request_id":9,"attempt":1}')


def test_rpc_envelope_roundtrip():
    msg = m.LeaseRenew("p0", 1, 2)
    raw = m.encode(msg, sender="p0", seq=7)
    assert b'"_rpc":["p0",7]' in raw
    back = m.decode(raw)
    assert back == msg                       # identity, not payload, differs
    assert back.wire_sender == "p0" and back.wire_seq == 7
    plain = m.decode(m.encode(msg))
    assert plain.wire_sender is None and plain.wire_seq is None
    with pytest.raises(ValueError, match="sender"):
        m.encode(msg, sender="p0")


def test_unknown_trailing_fields_tolerated():
    raw = b'LEAV\x00{"peer_id":"p0","future_field":{"x":1},"_rpc":["q",3]}'
    got = m.decode(raw)
    assert got == m.Leave("p0")
    assert got.wire_sender == "q" and got.wire_seq == 3


def test_cancel_fence_fields_roundtrip():
    c = m.CancelReq(4, 2, fence_node="p0", fence_epoch=9)
    assert m.decode(m.encode(c)) == c


def test_dedup_window_slides_per_sender():
    w = DedupWindow(depth=4)
    assert not w.seen("a", 1)
    assert w.seen("a", 1)                    # duplicate caught
    for s in range(2, 7):
        assert not w.seen("a", s)            # fresh seqs admitted
    assert not w.seen("a", 1)                # evicted past the window depth
    assert not w.seen("b", 6)                # windows are per-sender


# ---------------------------------------------------------------------------
# registry: duplicated/re-joined membership changes bump exactly once
# ---------------------------------------------------------------------------

_REG_KW = dict(role="prefill", addr=NetAddr("x", 0), nic="efa", kv_desc=None,
               geom={}, n_pages=4, lease_us=100.0)


def test_registry_duplicate_join_is_idempotent():
    reg = PeerRegistry()
    assert reg.join(peer_id="a", now=0.0, **_REG_KW) == 1
    # byte-identical retransmitted JOIN: lease refreshed, NO epoch bump
    assert reg.join(peer_id="a", now=10.0, **_REG_KW) == 1
    assert reg.epoch == 1
    assert reg.record("a").lease_expires_us == 110.0
    # a changed advertisement is a real membership change
    assert reg.join(peer_id="a", now=20.0, rejoin=True,
                    **dict(_REG_KW, n_pages=8)) == 2
    assert any(e == "rejoin:a" for _, e in reg.epoch_log)


@settings(max_examples=30, deadline=None)
@given(seqn=st.lists(st.sampled_from(["a", "b", "c"]), min_size=1,
                     max_size=10),
       dups=st.lists(st.integers(0, 2), min_size=10, max_size=10))
def test_fuzz_duplicated_joins_bump_epoch_once(seqn, dups):
    """For ANY join order with ANY duplication, the epoch advances exactly
    once per *distinct* membership change — retransmissions never bump."""
    reg = PeerRegistry()
    seen = set()
    for i, pid in enumerate(seqn):
        for _ in range(1 + dups[i]):
            reg.join(peer_id=pid, now=float(i), **_REG_KW)
        seen.add(pid)
        assert reg.epoch == len(seen)


@settings(max_examples=40, deadline=None)
@given(peer=st.text(max_size=12), inflight=st.integers(0, 2 ** 31 - 1),
       free=st.integers(0, 2 ** 31 - 1), sender=st.text(max_size=12),
       seq=st.integers(0, 2 ** 62))
def test_fuzz_codec_roundtrip_with_rpc_stamp(peer, inflight, free, sender,
                                             seq):
    msg = m.LeaseRenew(peer, inflight, free)
    back = m.decode(m.encode(msg, sender=sender, seq=seq))
    assert back == msg
    assert back.wire_sender == sender and back.wire_seq == seq


@settings(max_examples=40, deadline=None)
@given(extra=st.dictionaries(st.text(min_size=1, max_size=6),
                             st.integers(), max_size=4))
def test_fuzz_unknown_fields_never_crash_decode(extra):
    base = json.loads(m.encode(m.Leave("p0")).split(b"\0", 1)[1])
    base.update({"z_" + k: v for k, v in extra.items()})
    assert m.decode(b"LEAV\x00" + json.dumps(base).encode()) == m.Leave("p0")


# ---------------------------------------------------------------------------
# retry over the wire: JOIN-ack loss, partition detection, re-join
# ---------------------------------------------------------------------------

def test_join_ack_loss_recovered_by_retry(audited_fabrics):
    """Every JACK to pf0 is dropped for the first 500us: the client's JOIN
    chain retransmits, the plane dedups the duplicate JOINs (epoch bumps
    once) and re-acks, and the peer ends up joined."""
    fab = Fabric(seed=31)
    pol = CtrlRetryPolicy(max_retries=3, ack_timeout_us=200.0)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=30, retry=pol)
    plan = FaultPlan(fab, seed=5)
    plan.inject_ctrl("ctrl", "pf0", drop_prob=1.0)
    fab.loop.schedule(500.0, lambda: plan.clear("ctrl", "pf0"))
    a = _Peer(fab, ctrl, "pf0", "prefill", retry=pol, max_renewals=12)
    fab.run()
    assert a.client.joined and not a.client.join_exhausted
    assert a.client.join_resends >= 1
    assert ctrl.stats["acks_resent"] >= 1      # dup JOIN re-acked, not re-run
    assert plan.ctrl_stats["drops"] >= 1
    assert ctrl.registry.epoch == 1            # bumped exactly once


def test_partition_rejoin_reconciles(audited_fabrics):
    """pf0 is fully cut off from the plane: its lease lapses (epoch bump,
    scheduler-side eviction), its renew chain exhausts (client-side
    partition detector), and once healed it re-JOINs with ``prior_epoch``
    — fresh epoch, LIVE record, renewals resumed."""
    fab = Fabric(seed=32)
    pol = CtrlRetryPolicy(max_retries=2, ack_timeout_us=150.0)
    ctrl = ControlPlane(fab, nic="efa", lease_us=500.0, sweep_us=100.0,
                        max_sweeps=80, retry=pol)
    a = _Peer(fab, ctrl, "pf0", "prefill", retry=pol, renew_us=100.0,
              max_renewals=80)
    _Peer(fab, ctrl, "pf1", "prefill", retry=pol, renew_us=100.0,
          max_renewals=80)
    plan = FaultPlan(fab, seed=6)

    def partition():
        plan.inject_ctrl("pf0", "ctrl", drop_prob=1.0)
        plan.inject_ctrl("ctrl", "pf0", drop_prob=1.0)

    def heal():
        plan.clear("pf0", "ctrl")
        plan.clear("ctrl", "pf0")

    fab.loop.schedule(250.0, partition)
    fab.loop.schedule(1_700.0, heal)
    fab.run()
    assert a.client.rejoins == 1 and a.client.joined
    events = [e for _, e in ctrl.registry.epoch_log]
    assert "dead:pf0" in events and "rejoin:pf0" in events
    assert events.index("dead:pf0") < events.index("rejoin:pf0")
    rec = ctrl.registry.record("pf0")
    assert rec is not None and rec.status == "live"
    assert a.client.epoch == ctrl.registry.epoch
    assert a.client.renew_resends >= 1


def test_ctrl_faultplan_attached_inactive_is_byte_identical():
    """A FaultPlan with no ctrl knobs must not perturb the control plane:
    identical view payload bytes, identical virtual end time."""

    def scenario(with_plan):
        import itertools

        from repro.core.domain import MemoryRegion

        # region ids are process-global and leak into MrDesc wire bytes;
        # pin them so the two runs are comparable byte-for-byte
        MemoryRegion._ids = itertools.count()
        fab = Fabric(seed=33)
        ctrl = ControlPlane(fab, nic="efa", max_sweeps=12)
        if with_plan:
            FaultPlan(fab, seed=9)
        tap = []
        eng = fab.add_engine("tap", nic="efa")
        eng.submit_recvs(1 << 14, 16, lambda p: tap.append(bytes(p)))
        ctrl.subscribe(eng.address(0))
        _Peer(fab, ctrl, "pf0", "prefill", max_renewals=6)
        _Peer(fab, ctrl, "dc0", "decode", max_renewals=6)
        fab.run()
        return tap, fab.now

    bytes_a, end_a = scenario(False)
    bytes_b, end_b = scenario(True)
    assert bytes_a == bytes_b and end_a == end_b


# ---------------------------------------------------------------------------
# serving: lost REQ-DONE replayed, zombie writes fenced, churn storm
# ---------------------------------------------------------------------------

def test_lost_reqdone_replayed_by_submit_retry(model, audited_fabrics):
    """Every decoder->scheduler SEND is dropped until t=2.5ms: the DONE for
    the only request is lost, the scheduler's SUBMIT retry chain keeps
    retransmitting, and the decoder replays the terminal reply once the
    path heals — no request is ever re-executed."""
    from repro.serving import Decoder, Prefiller, Scheduler
    cfg, params = model
    fab = Fabric(seed=34)
    pol = CtrlRetryPolicy()
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=80, retry=pol)
    Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=80,
              ctrl_retry=pol)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 max_renewals=80, ctrl_retry=pol)
    sched = Scheduler(fab, ctrl, retry=pol)
    plan = FaultPlan(fab, seed=7)
    plan.inject_ctrl("d0", "sched", drop_prob=1.0)
    fab.loop.schedule(2_500.0, lambda: plan.clear("d0", "sched"))
    rng = np.random.default_rng(2)
    rid = sched.submit(rng.integers(0, cfg.vocab, size=24), n_decode=2)
    fab.run()
    assert rid in sched.completed and len(sched.completed) == 1
    assert sched.submit_resends >= 1
    assert d0.replayed_dones >= 1
    assert not sched.ctrl_retry_exhausted
    assert len(d0.pool._free) == d0.pool.n_pages and not d0._pending


def test_zombie_prefiller_writes_are_fenced(model, audited_fabrics,
                                            tmp_path):
    """q0's lease lapses while its process keeps computing and WRITing (a
    zombie, not a crash).  The scheduler re-routes with a fence-bearing
    CANCEL; every late WRITE from q0 is rejected at d0's engine fence
    (health ``fenced`` count, flight dump carrying the fenced WR and its
    stale epoch), and the re-routed requests produce monolithic-exact
    tokens from reallocated pages."""
    import jax.numpy as jnp

    from repro.models import decode_step, prefill
    from repro.serving import Decoder, Prefiller, Scheduler
    cfg, params = model
    fab = Fabric(seed=9)
    ctrl = ControlPlane(fab, nic="efa", lease_us=800.0, sweep_us=200.0,
                        max_sweeps=60)
    # slow layers: the handoff straddles the lease expiry, so q0 is still
    # WRITing when the fence goes up
    q0 = Prefiller(fab, "q0", cfg, params, nic="efa", ctrl=ctrl,
                   renew_us=200.0, max_renewals=60, layer_compute_us=400.0)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 renew_us=200.0, max_renewals=60)
    sched = Scheduler(fab, ctrl)
    rng = np.random.default_rng(2)
    ids = [rng.integers(0, cfg.vocab, size=24) for _ in range(2)]
    rids = [sched.submit(i, n_decode=2) for i in ids]
    # zombie: stop the lease heartbeat only — q0.alive stays True, so it
    # keeps serving the DispatchReqs it already accepted
    fab.loop.schedule(130.0,
                      lambda: setattr(q0.client, "alive_fn", lambda: False))
    spare = []
    fab.loop.schedule_at(500.0, lambda: spare.append(Prefiller(
        fab, "q1", cfg, params, nic="efa", ctrl=ctrl, renew_us=200.0,
        max_renewals=60)))
    fab.run()

    # eviction happened via lease expiry; q0 never re-joined (no retry
    # policy => no partition detector) and stayed a zombie
    assert ctrl.registry.record("q0") is None
    assert q0.alive and q0.client.rejoins == 0  # a zombie, not a re-joiner
    # every late WRITE was fenced, observable end to end
    assert fab.health.fault_counts.get("fenced", 0) > 0
    assert d0.engine.fences.get("q0", 0) >= 2
    dump = next(p for p in fab.recorder.dumps if "fence-rejected" in p)
    doc = json.load(open(dump))
    fenced_notes = [e for e in doc["events"]
                    if isinstance(e[2], str) and e[2] == "fenced:q0"]
    assert fenced_notes
    args = fenced_notes[0][3]
    assert args["epoch"] < args["fence"]       # the WR's stamp was stale
    # every request completed exactly once: work the zombie finished
    # *before* its lease lapsed stands (attempt 0 on q0); work that
    # straddled the eviction was fenced, cancelled, and re-ran on q1
    assert len(sched.completed) == 2 and not sched.inflight
    assert 1 <= len(sched.rerouted) <= 2
    for rid, seq in zip(rids, ids):
        r = sched.completed[rid]
        if rid in sched.rerouted:
            assert r["prefiller"] == "q1" and r["attempt"] >= 1
        else:
            assert r["prefiller"] == "q0" and r["attempt"] == 0
        # tokens are monolithic-exact either way — fenced WRs never
        # corrupted the pages the re-routed attempt decoded from
        lg, cache = prefill(params, jnp.asarray(seq)[None], cfg,
                            max_len=len(seq) + 64, moe_mode="dense")
        toks = [int(jnp.argmax(lg[0]))]
        lg, _ = decode_step(params, jnp.asarray([[toks[-1]]]),
                            jnp.asarray([len(seq)], jnp.int32), cache, cfg,
                            moe_mode="dense")
        toks.append(int(jnp.argmax(lg[0])))
        assert r["tokens"] == toks
    # nothing leaked on the surviving fleet
    assert len(d0.pool._free) == d0.pool.n_pages and not d0._pending
    assert len(spare[0].pool._free) == spare[0].pool.n_pages


@pytest.mark.slow
def test_churn_storm_zero_leaks_exactly_once(model):
    """Acceptance: the full membership-churn storm (join + drain + crash +
    partition/re-join) under 10% ctrl-SEND loss completes every request
    exactly once with zero leaked pages on every live peer."""
    from benchmarks.bench_chaos import ctrl_churn
    cfg, params = model
    row = ctrl_churn(0.10, cfg, params)
    assert row["n_completed"] == row["n_reqs"]
    assert row["n_failed"] == 0
    assert row["zero_leaked_pages"] is True
    assert row["exactly_once_adoption"] is True
    assert row["rejoins"] == 1                 # partition detector fired once
    assert row["recovery_us"] > 0              # p0 left and re-entered view
    assert row["ctrl_drops"] > 0               # faults actually fired


# ---------------------------------------------------------------------------
# autoscaler churn guard
# ---------------------------------------------------------------------------

def test_autoscaler_churn_guard_holds_during_epoch_churn():
    """Scale decisions are rate-limited while the view epoch churns: with
    ``churn_guard_epochs`` bumps inside ``churn_guard_window_us`` the step
    returns None (``churn_holds`` counts them); once the window drains the
    policy acts again.  Disabled by default."""
    assert ScalingPolicy().churn_guard_epochs == 0
    ctrl, sched = _FakeCtrl(MembershipView(1, (_pf("a"),))), _FakeSched()
    pol = ScalingPolicy(queue_high=3, cooldown_us=0.0, max_prefillers=5,
                        churn_guard_epochs=2, churn_guard_window_us=1_000.0)
    spawned = []
    sc = Autoscaler(ctrl, sched, spawned.append, policy=pol, auto=False,
                    next_index=1)
    sched.depth = 10                           # overloaded throughout
    assert sc.step(0.0) == "up"                # stable view: acts
    ctrl._view = MembershipView(2, (_pf("a"),))
    assert sc.step(100.0) == "up"              # 1 bump in window: still acts
    ctrl._view = MembershipView(3, (_pf("a"),))
    assert sc.step(200.0) is None              # 2 bumps in window: held
    assert sc.churn_holds == 1
    ctrl._view = MembershipView(4, (_pf("a"),))
    assert sc.step(300.0) is None and sc.churn_holds == 2
    assert sc.step(1_400.0) == "up"            # window drained: acts again
    assert spawned == [1, 2, 3]
