"""ImmCounter property tests: order-agnostic completion (hypothesis)."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import Fabric, ImmCounter, Pages, ScatterDst


@given(st.lists(st.integers(0, 4), min_size=1, max_size=60),
       st.randoms(use_true_random=False))
def test_counter_threshold_any_interleaving(imms, rnd):
    """expect(imm, k) fires exactly when the k-th event for imm lands,
    regardless of the interleaving of other imms."""
    order = list(imms)
    rnd.shuffle(order)
    c = ImmCounter()
    fired = {}
    for imm in set(imms):
        k = imms.count(imm)
        c.expect(imm, k, lambda imm=imm: fired.setdefault(imm, c.value(imm)))
    for i, imm in enumerate(order):
        c.increment(imm, now=float(i))
    for imm in set(imms):
        assert fired[imm] == imms.count(imm)  # fired exactly at threshold


@given(st.integers(1, 20), st.integers(0, 19))
def test_expect_after_events(k, pre):
    """Expectations registered AFTER events already landed must still fire."""
    c = ImmCounter()
    for i in range(pre):
        c.increment(7, now=float(i))
    fired = []
    c.expect(7, k, lambda: fired.append(True))
    for i in range(max(0, k - pre)):
        c.increment(7, now=float(i))
    assert fired == [True]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n_pages=st.integers(1, 24),
       n_writers=st.integers(1, 4))
def test_fabric_counter_under_srd_permutations(seed, n_pages, n_writers):
    """End-to-end: multiple writers x paged SRD writes; the receiver's
    expectation fires exactly once, after ALL payload bytes are visible."""
    page = 2048
    fab = Fabric(seed=seed)
    dstE = fab.add_engine("dst", nic="efa")
    dst = np.zeros(n_writers * n_pages * page, np.uint8)
    _, dd = dstE.reg_mr(dst)
    srcs = []
    for w in range(n_writers):
        e = fab.add_engine(f"w{w}", nic="efa")
        buf = np.full(n_pages * page, w + 1, np.uint8)
        h, _ = e.reg_mr(buf)
        srcs.append((e, h, buf))
    state = {}

    def on_done():
        state["ok"] = all(
            np.array_equal(dst[w * n_pages * page:(w + 1) * n_pages * page],
                           srcs[w][2])
            for w in range(n_writers))

    dstE.expect_imm_count(11, n_writers * n_pages, on_done)
    for w, (e, h, buf) in enumerate(srcs):
        e.submit_paged_writes(
            page, 11,
            (h, Pages(tuple(range(n_pages)), page)),
            (dd, Pages(tuple(range(w * n_pages, (w + 1) * n_pages)), page)))
    fab.run()
    assert state.get("ok") is True
