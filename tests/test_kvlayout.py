"""repro.kvlayout: schema derivation goldens, plan round-trips, ImmCounter
parity, exact-coverage property tests, and e2e disagg == monolithic for
every formerly guarded cache family."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config
from repro.core import Fabric
from repro.ctrl import ControlPlane
from repro.kvlayout import (DECODE_MARGIN, KvSchema, TransferPlan,
                            compile_plan, fill_cache, handoff_max_len,
                            schema_from_config, stage_cache)
from repro.models import init_cache, init_params
from repro.serving import Decoder, KvPool, Prefiller, Scheduler


# ---------------------------------------------------------------------------
# schema derivation goldens (one per ModelConfig family)
# ---------------------------------------------------------------------------

def _schema(arch):
    return schema_from_config(get_config(arch).reduced())


def test_schema_uniform_dense():
    s = _schema("stablelm-3b")
    assert [(c.name, c.kind, c.layers) for c in s.components] == [
        ("k", "token", (0, 1)), ("v", "token", (0, 1))]
    cfg = get_config("stablelm-3b").reduced()
    assert s.component("k").token_bytes == cfg.n_kv_heads * cfg.head_dim * 4


def test_schema_gemma3_pattern_split():
    s = _schema("gemma3-1b")
    assert [(c.name, c.kind, c.layers) for c in s.components] == [
        ("lk", "ring", (0,)), ("lv", "ring", (0,)),
        ("sk", "token", (1,)), ("sv", "token", (1,))]
    cfg = get_config("gemma3-1b").reduced()
    lk = s.component("lk")
    assert lk.window == cfg.window
    # ring transfers min(max_len, window) slots regardless of prompt length
    assert lk.tokens(4, handoff_max_len(4)) == cfg.window


def test_schema_vlm_cross():
    s = _schema("llama-3.2-vision-90b")
    assert [(c.name, c.kind) for c in s.components] == [
        ("lk", "token"), ("lv", "token"), ("sk", "fixed"), ("sv", "fixed")]
    cfg = get_config("llama-3.2-vision-90b").reduced()
    assert s.component("sk").fixed_tokens == cfg.vision_seq
    # cross K/V extent is vision-determined, independent of the prompt
    assert s.component("sk").tokens(3, handoff_max_len(3)) == cfg.vision_seq


def test_schema_ssm_and_hybrid():
    s = _schema("mamba2-780m")
    assert [(c.name, c.kind, c.layers) for c in s.components] == [
        ("conv", "blob", (0, 1)), ("ssd", "blob", (0, 1))]
    cfg = get_config("mamba2-780m").reduced()
    assert s.component("ssd").blob_bytes == (
        cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4)
    h = _schema("zamba2-1.2b")
    assert [(c.name, c.kind) for c in h.components] == [
        ("conv", "blob"), ("ssd", "blob"), ("ak", "ring"), ("av", "ring")]
    # the shared-attn ring unlocks after its group's LAST mamba layer
    assert h.component("ak").layers == (1,)


def test_schema_first_k_dense():
    s = _schema("deepseek-moe-16b")
    assert [(c.name, c.layers) for c in s.components] == [
        ("k0", (0,)), ("v0", (0,)), ("k", (1,)), ("v", (1,))]


def test_schema_wire_roundtrip_and_mismatch():
    for arch in ARCH_IDS:
        s = _schema(arch)
        assert KvSchema.from_wire(s.to_wire()) == s
    a, b = _schema("gemma3-1b"), _schema("stablelm-3b")
    assert a.mismatch(a) is None
    assert "component sets differ" in a.mismatch(b)
    assert "no KvSchema" in a.mismatch(None)
    c = schema_from_config(get_config("gemma3-1b").reduced(), page_tokens=8)
    assert "page_tokens" in a.mismatch(c)
    with pytest.raises(ValueError, match="incompatible"):
        compile_plan(a, b, 16)


def test_schema_matches_init_cache_shapes():
    """Every component's byte geometry equals the model's actual cache
    arrays — the schema IS init_cache, declaratively."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        s = _schema(arch)
        S = 11
        ml = handoff_max_len(S)
        cache = init_cache(cfg, 1, ml)
        assert set(s.names()) <= set(cache.keys())
        for comp in s.components:
            arr = np.asarray(cache[comp.name])
            assert arr.shape[0] == comp.n_stack, (arch, comp.name)
            assert arr.dtype == np.dtype(comp.dtype), (arch, comp.name)
            if comp.kind == "blob":
                assert arr[0, 0].nbytes == comp.blob_bytes, (arch, comp.name)
            else:
                # token axis is 2; per-token bytes must match
                assert arr[0, 0, 0].nbytes == comp.token_bytes, (arch, comp.name)
                assert arr.shape[2] >= comp.tokens(S, ml), (arch, comp.name)
            # every producing layer is a real model layer
            assert all(0 <= l < cfg.n_layers for l in comp.layers)


# ---------------------------------------------------------------------------
# plan round-trip over the fabric: bytes conservation + ImmCounter parity
# ---------------------------------------------------------------------------

def _random_cache(cfg, max_len, rng):
    return {k: rng.normal(size=v.shape).astype(np.asarray(v).dtype)
            for k, v in init_cache(cfg, 1, max_len).items()}


@pytest.mark.parametrize("arch", ["stablelm-3b", "gemma3-1b", "mamba2-780m",
                                  "zamba2-1.2b", "deepseek-moe-16b",
                                  "llama-3.2-vision-90b"])
def test_plan_roundtrip_conserves_bytes(arch):
    """stage -> span-scatter over the simulated fabric -> fill reproduces
    every valid component byte; ImmCounter expectations match the writes
    a monolithic full-state copy would count."""
    cfg = get_config(arch).reduced()
    schema = _schema(arch)
    S = 37
    plan = compile_plan(schema, schema, S)
    rng = np.random.default_rng(7)
    src_cache = _random_cache(cfg, plan.max_len, rng)

    fab = Fabric(seed=1)
    a = fab.add_engine("a", nic="efa")
    b = fab.add_engine("b", nic="efa")
    pa, pb = KvPool(a, schema, 64), KvPool(b, schema, 64)
    src_pages, dst_pages = pa.alloc(plan.n_slots), pb.alloc(plan.n_slots)
    stage_cache(plan, pa, src_pages, src_cache)

    fired = []
    for off, count in plan.expected_counts():
        b.expect_imm_count(100 + off, count, lambda off=off: fired.append(off))
    # submit layer-by-layer (worst-case span fragmentation): per span the
    # submission is still ONE WrBatch no matter how many components ride it
    sent = 0
    for l in range(cfg.n_layers):
        before = a.batch_stats.batches
        n = plan.submit_span(a, pa.handle, src_pages, pb.desc, dst_pages,
                             100, l, l + 1)
        sent += n
        assert a.batch_stats.batches == before + (1 if n else 0)
    assert sent == plan.total_writes
    fab.run()
    # ImmCounter parity: every component completed exactly at its count
    assert sorted(fired) == [off for off, _ in plan.expected_counts()]
    for off, count in plan.expected_counts():
        assert b.counters[0].value(100 + off) == count

    got = fill_cache(plan, pb, dst_pages, init_cache(cfg, 1, plan.max_len))
    total_valid = 0
    for comp in schema.components:
        t = comp.tokens(S, plan.max_len)
        src, dst = src_cache[comp.name], got[comp.name]
        if comp.kind == "blob":
            np.testing.assert_array_equal(src, dst)
            total_valid += src.nbytes
        else:
            np.testing.assert_array_equal(src[:, :, :t], dst[:, :, :t])
            total_valid += comp.n_stack * t * comp.token_bytes
    # bytes conservation vs a monolithic copy of the same state
    assert total_valid == schema.total_bytes(S)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["stablelm-3b", "gemma3-1b", "mamba2-780m",
                        "zamba2-1.2b", "deepseek-moe-16b",
                        "llama-3.2-vision-90b"]),
       st.integers(1, 70), st.sampled_from([4, 8, 16]))
def test_plan_covers_every_component_byte_exactly_once(arch, S, page_tokens):
    """Property: for any schema, the union of all layer spans covers every
    component's valid byte range exactly once — no slot repeated, no byte
    of any component skipped or double-written."""
    cfg = get_config(arch).reduced()
    schema = schema_from_config(cfg, page_tokens)
    plan = TransferPlan(schema, S)
    seen = set()
    per_comp = {ci: 0 for ci in range(len(schema.components))}
    for l in range(cfg.n_layers):
        for ci, slot in plan.span_writes(l, l + 1):
            assert slot not in seen            # exactly once
            seen.add(slot)
            per_comp[ci] += 1
    assert len(seen) == plan.n_slots == plan.total_writes
    for ci, comp in enumerate(schema.components):
        t = comp.tokens(S, plan.max_len)
        covered = per_comp[ci] * comp.page_len(page_tokens)
        need = comp.n_stack * comp.layer_bytes(S, plan.max_len)
        assert covered >= need                 # pages cover all valid bytes
        if comp.kind == "blob":
            assert covered == need             # blobs are exact
        else:
            # padding never exceeds one page per stack layer
            assert covered - need < comp.n_stack * comp.page_len(page_tokens)
    # expectation map totals the same writes
    assert sum(c for _, c in plan.expected_counts()) == plan.total_writes


def test_hand_wired_schema_mismatch_raises_before_any_write():
    """Peers wired without the control plane (no routing-time gate) still
    fail loudly: the prefiller validates the DispatchReq's schema before
    the first WRITE instead of hanging on unmet expectations."""
    cfg = get_config("stablelm-3b").reduced()
    fab = Fabric(seed=2)
    pf = Prefiller(fab, "p0", cfg, None, nic="efa", page_tokens=16)
    dec = Decoder(fab, "d0", cfg, None, nic="efa", page_tokens=8)
    dec.submit(0, np.arange(20) % cfg.vocab, pf.address(), n_decode=2)
    with pytest.raises(ValueError, match="page_tokens"):
        fab.run()


def test_n_decode_beyond_margin_rejected():
    cfg = get_config("stablelm-3b").reduced()
    fab = Fabric(seed=2)
    pf = Prefiller(fab, "p0", cfg, None, nic="efa")
    dec = Decoder(fab, "d0", cfg, None, nic="efa")
    with pytest.raises(ValueError, match="DECODE_MARGIN"):
        dec.submit(0, np.arange(8), pf.address(), n_decode=DECODE_MARGIN + 1)


def test_pool_shared_allocator_across_components():
    """One free list serves every component: slots are interchangeable."""
    schema = _schema("zamba2-1.2b")
    fab = Fabric(seed=0)
    e = fab.add_engine("n", nic="efa")
    pool = KvPool(e, schema, 8)
    assert pool.slot_bytes == schema.slot_bytes
    a = pool.alloc(5)
    pool.free(a)
    b = pool.alloc(8)                # drains the whole pool
    assert set(a) <= set(b)          # recycled slots serve any component
    assert pool._free == []
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1)
    pool.free(b)
    assert len(pool._free) == pool.n_pages


# ---------------------------------------------------------------------------
# e2e: disagg == monolithic for every formerly guarded family
# ---------------------------------------------------------------------------

def _mono_generate(cfg, params, ids, n_decode, vision_emb=None):
    # the launcher's reference loop — deliberately shared, and it uses a
    # DIFFERENT max_len than the handoff convention, proving the outputs
    # are invariant to the cache headroom
    from repro.launch.serve import monolithic
    return monolithic(cfg, params, [ids], n_decode, vision_emb)[0]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b",            # pattern-split
                                  "mamba2-780m",          # SSM
                                  "zamba2-1.2b",          # hybrid
                                  "deepseek-moe-16b",     # first-k-dense
                                  "llama-3.2-vision-90b"  # vlm cross
                                  ])
def test_disagg_equals_monolithic_all_families(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    vis = (rng.normal(size=(cfg.vision_seq, cfg.vision_dim))
           .astype(np.float32) if cfg.family == "vlm" else None)
    fab = Fabric(seed=3)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=64)
    pf = Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl,
                   max_renewals=64)
    dec = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                  max_renewals=64)
    sched = Scheduler(fab, ctrl)
    ids = rng.integers(0, cfg.vocab, size=37)
    rid = sched.submit(ids, n_decode=5, vision_emb=vis)
    fab.run()
    sched.check_drained()
    r = sched.completed[rid]
    assert r["tokens"] == _mono_generate(cfg, params, ids, 5, vis)
    assert r["ttft_us"] > 0
    # hot-path contract: ONE WrBatch enqueue per completed layer span plus
    # one for the tail write, regardless of schema complexity
    assert len(pf.span_log) >= 1
    assert pf.engine.batch_stats.batches == len(pf.span_log) + 1
    assert sum(n for _, _, _, n in pf.span_log) == \
        sum(c for _, c in dec._plan(len(ids)).expected_counts())
    # nothing leaked on either side
    assert len(pf.pool._free) == pf.pool.n_pages
    assert len(dec.pool._free) == dec.pool.n_pages
