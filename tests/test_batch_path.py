"""Batched zero-copy WR submission: conservation, ImmCounter parity,
payload-aliasing and single-enqueue guarantees of the WrBatch fast path."""

import numpy as np
import pytest

from repro.core import Fabric, Flag, Pages, ScatterDst

@pytest.fixture(autouse=True)
def _audit_fabrics(audited_fabrics):
    """Leak-free teardown: every quiescent fabric must pass the obs audit."""
    yield



def _pair(nic: str, seed: int = 0):
    fab = Fabric(seed=seed)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    return fab, a, b


# ---------------------------------------------------------------------------
# bytes conservation across NIC striping / rotation
# ---------------------------------------------------------------------------

def test_striped_write_conserves_bytes_across_nics():
    """A large WRITE striped over 4 EFA NICs moves exactly len(src) bytes,
    split evenly, and lands bit-exact."""
    fab, a, b = _pair("efa4")
    size = 1 << 20
    src = (np.arange(size) % 241).astype(np.uint8)
    dst = np.zeros(size, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_single_write(size, 1, (hs, 0), (dd, 0))
    fab.run()
    assert np.array_equal(src, dst)
    per_nic = [d.nic.bytes_sent for d in a.groups[0].domains]
    assert sum(per_nic) == size
    assert all(n == size // 4 for n in per_nic)


def test_paged_rotation_conserves_bytes_per_nic():
    """Batched paged writes rotate pages round-robin: each NIC carries an
    equal share and the total equals the payload."""
    fab, a, b = _pair("efa")  # 2 NICs
    n_pages, page = 8, 4096
    src = np.random.default_rng(0).integers(0, 255, n_pages * page, dtype=np.uint8)
    dst = np.zeros_like(src)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = Pages(tuple(range(n_pages)), page)
    a.submit_paged_writes(page, 2, (hs, idx), (dd, idx))
    fab.run()
    assert np.array_equal(src, dst)
    per_nic = [d.nic.bytes_sent for d in a.groups[0].domains]
    assert sum(per_nic) == n_pages * page
    assert per_nic[0] == per_nic[1] == n_pages * page // 2


# ---------------------------------------------------------------------------
# ImmCounter parity: batched path == per-op path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nic", ["cx7", "efa"])
def test_batched_paged_imm_equals_per_op_path(nic):
    """The batched paged-write submission must produce exactly the same
    receiver-side ImmCounter state (one increment per fully-landed page)
    as issuing every page as its own single WRITE."""
    n_pages, page, imm = 16, 2048, 9
    rng = np.random.default_rng(3)
    payload = rng.integers(0, 255, n_pages * page, dtype=np.uint8)

    # batched path
    fab1, a1, b1 = _pair(nic, seed=11)
    dst1 = np.zeros_like(payload)
    hs1, _ = a1.reg_mr(payload.copy())
    _, dd1 = b1.reg_mr(dst1)
    idx = Pages(tuple(range(n_pages)), page)
    a1.submit_paged_writes(page, imm, (hs1, idx), (dd1, idx))
    fab1.run()

    # per-op path: one submit per page
    fab2, a2, b2 = _pair(nic, seed=11)
    dst2 = np.zeros_like(payload)
    hs2, _ = a2.reg_mr(payload.copy())
    _, dd2 = b2.reg_mr(dst2)
    for i in range(n_pages):
        a2.submit_single_write(page, imm, (hs2, i * page), (dd2, i * page))
    fab2.run()

    assert b1.imm_value(imm) == b2.imm_value(imm) == n_pages
    assert len(b1.counters[0].events) == len(b2.counters[0].events) == n_pages
    assert np.array_equal(dst1, dst2)


# ---------------------------------------------------------------------------
# zero-copy payload handling must never alias live buffers
# ---------------------------------------------------------------------------

def test_no_payload_aliasing_after_submit():
    """WRITE payloads are snapshotted at submission: mutating the source
    buffer after submit (while chunks are still 'in flight' in virtual
    time) must not change what lands, even though all chunk slicing is
    zero-copy memoryview."""
    fab, a, b = _pair("efa", seed=42)
    size = 1 << 18
    src = (np.arange(size) % 199).astype(np.uint8)
    want = src.copy()
    dst = np.zeros(size, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_single_write(size, 1, (hs, 0), (dd, 0))
    src[:] = 0xFF  # scribble over the live region before the run
    fab.run()
    assert np.array_equal(dst, want)


def test_no_payload_aliasing_paged_and_after_delivery():
    fab, a, b = _pair("cx7", seed=1)
    n_pages, page = 4, 4096
    src = np.random.default_rng(9).integers(0, 255, n_pages * page, dtype=np.uint8)
    want = src.copy()
    dst = np.zeros_like(src)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = Pages(tuple(range(n_pages)), page)
    a.submit_paged_writes(page, 5, (hs, idx), (dd, idx))
    src[:] = 0  # mutate before the event loop runs
    fab.run()
    assert np.array_equal(dst, want)
    src[:] = 77  # and after delivery: dst must hold its own storage
    assert np.array_equal(dst, want)


# ---------------------------------------------------------------------------
# batched submission APIs
# ---------------------------------------------------------------------------

def test_submit_write_batch_contents_imm_and_on_done():
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic="cx7")
    b = fab.add_engine("b", nic="cx7")
    c = fab.add_engine("c", nic="cx7")
    src = np.arange(3 * 1024, dtype=np.uint8) % 97
    hs, _ = a.reg_mr(src)
    dstb = np.zeros(2048, np.uint8)
    dstc = np.zeros(1024, np.uint8)
    _, db = b.reg_mr(dstb)
    _, dc = c.reg_mr(dstc)
    flag = Flag()
    a.submit_write_batch([
        (1024, 3, (hs, 0), (db, 0)),
        (1024, 3, (hs, 1024), (db, 1024)),
        (1024, None, (hs, 2048), (dc, 0)),
    ], on_done=flag)
    fab.run()
    assert flag.is_set()
    assert np.array_equal(dstb[:1024], src[:1024])
    assert np.array_equal(dstb[1024:], src[1024:2048])
    assert np.array_equal(dstc, src[2048:])
    assert b.imm_value(3) == 2
    assert c.imm_value(3) == 0


def test_submit_write_batch_empty_fires_immediately():
    fab, a, _ = _pair("cx7")
    flag = Flag()
    a.submit_write_batch([], on_done=flag)
    assert flag.is_set()


def test_submit_scatters_multi_imm_one_batch():
    """Several scatter groups with distinct immediates share one WrBatch:
    per-imm counting and per-group on_done survive the coalescing."""
    fab, a, b = _pair("efa", seed=2)
    src = np.random.default_rng(1).integers(0, 255, 4096, dtype=np.uint8)
    hs, _ = a.reg_mr(src)
    dst = np.zeros(4096, np.uint8)
    _, dd = b.reg_mr(dst)
    f1, f2 = Flag(), Flag()
    a.submit_scatters([
        (hs, [ScatterDst(len=1024, src=0, dst=(dd, 0)),
              ScatterDst(len=1024, src=1024, dst=(dd, 1024))], 21, f1),
        (hs, [ScatterDst(len=2048, src=2048, dst=(dd, 2048))], 22, f2),
    ])
    fab.run()
    assert f1.is_set() and f2.is_set()
    assert np.array_equal(dst, src)
    assert b.imm_value(21) == 2
    assert b.imm_value(22) == 1


def test_batched_submission_is_one_event_loop_entry():
    """N WRs across several scatter groups cost ONE app->worker enqueue."""
    fab, a, b = _pair("cx7")
    src = np.zeros(4096, np.uint8)
    hs, _ = a.reg_mr(src)
    dst = np.zeros(4096, np.uint8)
    _, dd = b.reg_mr(dst)
    calls = []
    orig = fab.loop.schedule
    fab.loop.schedule = lambda d, fn: (calls.append(d), orig(d, fn))
    try:
        a.submit_scatters([
            (hs, [ScatterDst(len=512, src=i * 512, dst=(dd, i * 512))
                  for i in range(4)], 1, None),
            (hs, [ScatterDst(len=512, src=2048 + i * 512, dst=(dd, 2048 + i * 512))
                  for i in range(4)], 2, None),
        ])
    finally:
        fab.loop.schedule = orig
    assert len(calls) == 1  # one ENQUEUE for all 8 WRs of both groups
    fab.run()
    assert b.imm_value(1) == 4 and b.imm_value(2) == 4


# ---------------------------------------------------------------------------
# per-batch submission stats (WRs per enqueue, bytes per batch)
# ---------------------------------------------------------------------------

def test_batch_stats_count_wrs_and_bytes_per_enqueue():
    fab, a, b = _pair("cx7")      # 1 NIC: one WR per logical write
    src = np.zeros(4096, np.uint8)
    dst = np.zeros(4096, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_write_batch([(256, 1, (hs, i * 256), (dd, i * 256))
                          for i in range(3)])
    fab.run()
    s = a.batch_stats
    assert (s.batches, s.wrs, s.nbytes) == (1, 3, 768)
    assert s.wrs_per_enqueue == 3.0 and s.bytes_per_batch == 768.0
    a.submit_single_write(512, 2, (hs, 0), (dd, 0))
    fab.run()
    assert (s.batches, s.wrs, s.nbytes) == (2, 4, 768 + 512)
    assert s.as_dict()["wrs_per_enqueue"] == 2.0


def test_batch_stats_striping_counts_per_nic_wrs():
    """Striping multiplies WRs, not logical writes: one 1 MiB write over
    4 NICs is 4 WRs in one enqueue."""
    fab, a, b = _pair("efa4")
    size = 1 << 20
    src = np.zeros(size, np.uint8)
    dst = np.zeros(size, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    a.submit_single_write(size, 1, (hs, 0), (dd, 0))
    fab.run()
    assert a.batch_stats.batches == 1
    assert a.batch_stats.wrs == 4
    assert a.batch_stats.nbytes == size


# ---------------------------------------------------------------------------
# gather-into-snapshot payload scatters (PayloadDst)
# ---------------------------------------------------------------------------

def test_payload_scatter_delivers_caller_snapshot():
    """PayloadDst bytes are used AS the snapshot: exact delivery, imm
    parity with the MR-sourced path, and no re-read of any source region
    (the caller's gather is the only copy)."""
    from repro.core import PayloadDst
    fab, a, b = _pair("efa", seed=5)
    rng = np.random.default_rng(2)
    table = rng.integers(0, 255, size=(8, 512), dtype=np.uint8)
    dst = np.zeros(4096, np.uint8)
    _, dd = b.reg_mr(dst)
    rows = np.asarray([5, 1, 6, 2])
    gathered = table[rows]                  # the gather IS the snapshot
    f = Flag()
    a.submit_scatters([(None, [
        PayloadDst(payload=gathered[i:i + 1].reshape(-1),
                   dst=(dd, i * 512)) for i in range(4)], 31, f)])
    # mutating the table after submit must not change what lands
    table[:] = 0
    fab.run()
    assert f.is_set()
    assert b.imm_value(31) == 4
    assert np.array_equal(dst[:2048].reshape(4, 512), gathered)
    assert np.array_equal(dst[2048:], np.zeros(2048, np.uint8))


def test_payload_and_mr_groups_share_one_batch():
    """A payload-sourced group and an MR-sourced group coalesce into ONE
    WrBatch/enqueue, each keeping its own imm."""
    from repro.core import PayloadDst
    fab, a, b = _pair("cx7")
    src = np.random.default_rng(3).integers(0, 255, 1024, dtype=np.uint8)
    hs, _ = a.reg_mr(src)
    dst = np.zeros(2048, np.uint8)
    _, dd = b.reg_mr(dst)
    payload = np.arange(1024, dtype=np.uint32).view(np.uint8)[:1024].copy()
    before = a.batch_stats.batches
    a.submit_scatters([
        (hs, [ScatterDst(len=1024, src=0, dst=(dd, 0))], 41, None),
        (None, [PayloadDst(payload=payload, dst=(dd, 1024))], 42, None),
    ])
    assert a.batch_stats.batches == before + 1
    fab.run()
    assert np.array_equal(dst[:1024], src)
    assert np.array_equal(dst[1024:], payload)
    assert b.imm_value(41) == 1 and b.imm_value(42) == 1


# ---------------------------------------------------------------------------
# two-sided SENDs ride a WrBatch
# ---------------------------------------------------------------------------

def test_sends_in_same_loop_entry_coalesce_into_one_enqueue():
    """N SENDs submitted in the same event-loop entry share one WrBatch
    flush (one app->worker enqueue), preserve submission order, and SENDs
    from a later entry open a fresh batch."""
    fab, a, b = _pair("cx7")
    got = []
    b.submit_recvs(256, 8, got.append)
    calls = []
    orig = fab.loop.schedule
    fab.loop.schedule = lambda d, fn: (calls.append(d), orig(d, fn))
    try:
        a.submit_send(b.address(0), b"one")
        a.submit_send(b.address(0), b"two")
        a.submit_send(b.address(0), b"three")
    finally:
        fab.loop.schedule = orig
    assert len(calls) == 1      # ONE flush event for all three SENDs
    fab.run()
    assert got == [b"one", b"two", b"three"]
    # a later loop entry gets its own batch and still delivers
    a.submit_send(b.address(0), b"four")
    fab.run()
    assert got == [b"one", b"two", b"three", b"four"]


def test_send_batch_callbacks_fire_per_send():
    from repro.core import Flag
    fab, a, b = _pair("efa")
    b.submit_recvs(64, 4, lambda p: None)
    f1, f2 = Flag(), Flag()
    a.submit_send(b.address(0), b"x", cb=f1)
    a.submit_send(b.address(0), b"y", cb=f2)
    fab.run()
    assert f1.is_set() and f2.is_set()
