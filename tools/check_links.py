#!/usr/bin/env python3
"""Validate relative markdown links (and heading anchors) in repo docs.

Scans a fixed set of markdown files for inline links ``[text](target)``
and checks that every *relative* target resolves:

* ``path`` — the file or directory exists relative to the linking file;
* ``path#anchor`` — the file exists AND contains a heading whose GitHub
  slug equals ``anchor``;
* ``#anchor`` — the linking file itself contains that heading.

External links (``http://``, ``https://``, ``mailto:``) are ignored —
this is a repo-consistency check, not a web crawler.  Exit 0 when every
link resolves, 1 otherwise (one line per broken link).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Files whose internal links must resolve.  docs/*.md is globbed so new
# documents are covered without editing this list.
CHECKED = ["README.md", "ISSUE.md", "CHANGES.md", "ROADMAP.md", "PAPER.md"]

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    """All heading anchors a markdown file exposes."""
    body = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    seen: dict = {}
    out = set()
    for mm in HEADING_RE.finditer(body):
        slug = slugify(mm.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_file(path: Path) -> list:
    """Return broken-link descriptions for one markdown file."""
    body = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    errors = []
    for mm in LINK_RE.finditer(body):
        target = mm.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, anchor = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if base and not dest.exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
            continue
        if anchor:
            if dest.is_dir() or dest.suffix.lower() != ".md":
                errors.append(
                    f"{path.relative_to(REPO)}: anchor on non-markdown -> {target}")
            elif anchor not in anchors_of(dest):
                errors.append(
                    f"{path.relative_to(REPO)}: missing anchor -> {target}")
    return errors


def main() -> int:
    files = [REPO / name for name in CHECKED if (REPO / name).exists()]
    files += sorted((REPO / "docs").glob("*.md"))
    errors = []
    for path in files:
        errors.extend(check_file(path))
    if errors:
        print("\n".join(errors))
        print(f"{len(errors)} broken link(s)")
        return 1
    print(f"all links ok across {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
