#!/usr/bin/env python3
"""Perf gate: compare fresh ``BENCH_*.json`` runs against checked-in baselines.

The simulator is deterministic (virtual time, seeded RNG), so a bench row
only moves when the code's *behaviour* moves — which makes a tight
tolerance meaningful.  The CI ``perf-gate`` job runs the full-scale
benches into a scratch dir and calls::

    python tools/bench_check.py --baseline benchmarks/out --new /tmp/out \
        BENCH_moe.json BENCH_rlweights.json

For every numeric value under ``rows`` the relative delta
``|new - old| / max(|old|, eps)`` must stay within ``--tolerance``
(booleans must match exactly).  A per-row delta table is printed either
way; violations, rows missing from the fresh run, and smoke/full scale
mismatches exit 1.  New rows or keys (a bench learned a new measurement)
are reported but never fail — baselines get refreshed by committing the
fresh file.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Tuple

EPS = 1e-9
DEFAULT_FILES = ["BENCH_moe.json", "BENCH_rlweights.json",
                 "BENCH_p2p.json", "BENCH_kvcache.json",
                 "BENCH_scaling.json"]


def flat_rows(doc: dict) -> dict:
    """``rows`` flattened to {"row.key": value} over numeric/bool leaves."""
    out = {}
    for row, kv in doc.get("rows", {}).items():
        if not isinstance(kv, dict):
            continue
        for k, v in kv.items():
            if isinstance(v, (int, float, bool)):
                out[f"{row}.{k}"] = v
    return out


def compare_file(base_path: str, new_path: str, tol: float
                 ) -> Tuple[List[str], List[str]]:
    """Returns (violations, info_lines) for one bench JSON pair."""
    with open(base_path) as f:
        base = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    name = os.path.basename(base_path)
    bad: List[str] = []
    info: List[str] = []

    if base.get("smoke") != new.get("smoke"):
        bad.append(f"{name}: smoke={new.get('smoke')} run compared against "
                   f"smoke={base.get('smoke')} baseline — scales differ")
        return bad, info

    b, n = flat_rows(base), flat_rows(new)
    width = max((len(k) for k in b | n), default=3)
    info.append(f"\n{name} (tolerance {100 * tol:.0f}%):")
    info.append(f"  {'row.key':<{width}} {'baseline':>14} {'new':>14} "
                f"{'delta':>9}")
    for k in sorted(b | n):
        if k not in n:
            bad.append(f"{name}: {k} missing from the fresh run")
            info.append(f"  {k:<{width}} {b[k]!s:>14} {'MISSING':>14}")
            continue
        if k not in b:
            info.append(f"  {k:<{width}} {'(new)':>14} {n[k]!s:>14}")
            continue
        bv, nv = b[k], n[k]
        if isinstance(bv, bool) or isinstance(nv, bool):
            mark = "" if bv == nv else "  VIOLATION"
            if mark:
                bad.append(f"{name}: {k} flipped {bv} -> {nv}")
            info.append(f"  {k:<{width}} {bv!s:>14} {nv!s:>14} {'':>9}{mark}")
            continue
        delta = (nv - bv) / max(abs(bv), EPS)
        mark = "" if abs(delta) <= tol else "  VIOLATION"
        if mark:
            bad.append(f"{name}: {k} moved {100 * delta:+.1f}% "
                       f"({bv:.6g} -> {nv:.6g}, tol {100 * tol:.0f}%)")
        info.append(f"  {k:<{width}} {bv:>14.6g} {nv:>14.6g} "
                    f"{100 * delta:>+8.1f}%{mark}")
    return bad, info


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=DEFAULT_FILES,
                    help=f"bench JSON filenames (default {DEFAULT_FILES})")
    ap.add_argument("--baseline", default="benchmarks/out",
                    help="dir with the checked-in baseline JSONs")
    ap.add_argument("--new", dest="new_dir", required=True,
                    help="dir with the freshly produced JSONs")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max relative delta per numeric value")
    args = ap.parse_args(argv)

    violations: List[str] = []
    for fname in args.files or DEFAULT_FILES:
        base_path = os.path.join(args.baseline, fname)
        new_path = os.path.join(args.new_dir, fname)
        for p, which in ((base_path, "baseline"), (new_path, "fresh")):
            if not os.path.exists(p):
                violations.append(f"{fname}: {which} file {p} missing")
                p = None
                break
        if p is None:
            continue
        bad, info = compare_file(base_path, new_path, args.tolerance)
        print("\n".join(info))
        violations += bad

    if violations:
        print(f"\nFAIL: {len(violations)} violation(s)", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print("\nOK: all rows within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
