#!/usr/bin/env python3
"""Stall attribution + phase coverage from an obs Chrome trace.

Reads a trace exported by :func:`repro.obs.export_chrome_trace` and
answers the question the raw Perfetto view makes you eyeball: *where did
the virtual time go?*

Per destination, every complete WR lifecycle span is split into its three
serial segments (the stamps ride in the async ``b`` event's ``args``, so
this script needs nothing but the trace file):

* ``enqueue`` — ``t_enqueue - t_submit``: time from logical submission to
  the WrBatch hitting the posting thread (batch windowing, proxy delay);
* ``post``    — ``t_wire - t_enqueue``: waiting for the serialised
  per-group posting thread plus the NIC queue (doorbell cost, queue
  backlog);
* ``wire``    — ``t_deliver - t_wire``: serialisation + flight + (SRD)
  jitter until the last chunk lands.

A destination is then labelled post-limited / wire-limited /
enqueue-limited by its dominant segment.  The report also aggregates per
phase (the ``tracer.phase(...)`` tag active at submit time) and checks
**coverage**: the union of all WR spans and compute/engine spans must
explain at least ``--min-coverage`` (default 0.95) of the end-to-end
virtual time, else exit 1 — untraced gaps mean the instrumentation lost
track of something.

When the trace embeds a ``"health"`` document (exported from a fabric with
the always-on :class:`~repro.obs.health.HealthMonitor` attached), the
report prints the per-channel health/deviation table, and ``--live-parity``
cross-checks the monitor's *streaming* per-pair segment counters against
the attribution recomputed post-hoc from the retained spans: every pair's
enqueue/post/wire sums must agree within 1% (counts and bytes exactly), or
exit 1 — the two implementations watch the same hook points, so any drift
is an instrumentation bug.

Usage::

    python tools/trace_report.py benchmarks/out/trace_moe.json
    python tools/trace_report.py trace.json --min-coverage 0.9 --top 8
    python tools/trace_report.py benchmarks/out/trace_moe.json --live-parity
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

PARITY_TOL = 0.01


def load_doc(path: str) -> dict:
    """Read a Chrome trace file; bare arrays are wrapped as traceEvents."""
    with open(path) as f:
        doc = json.load(f)
    return doc if isinstance(doc, dict) else {"traceEvents": doc}


def load_events(path: str) -> List[dict]:
    """Read a Chrome trace file (object-with-traceEvents or bare array)."""
    return load_doc(path)["traceEvents"]


def wr_segments(events: List[dict]) -> List[dict]:
    """Complete WR spans: [{src, dst, phase, nbytes, enqueue, post, wire},
    ...]."""
    out = []
    for ev in events:
        if ev.get("ph") != "b" or ev.get("cat") != "wr":
            continue
        a = ev.get("args", {})
        stamps = (a.get("t_submit"), a.get("t_enqueue"), a.get("t_wire"),
                  a.get("t_deliver"))
        if any(s is None for s in stamps):
            continue        # orphan / never-posted span: excluded, reported
        t_submit, t_enqueue, t_wire, t_deliver = stamps
        out.append({
            "src": a.get("src", ""),
            "dst": a.get("dst", "?"), "phase": a.get("phase") or "(none)",
            "nbytes": a.get("nbytes", 0),
            "t0": t_submit, "t1": t_deliver,
            "enqueue": max(0.0, t_enqueue - t_submit),
            "post": max(0.0, t_wire - t_enqueue),
            "wire": max(0.0, t_deliver - t_wire),
        })
    return out


def interval_union(ivs: List[Tuple[float, float]]) -> float:
    """Total length of the union of [t0, t1] intervals."""
    total = 0.0
    end = float("-inf")
    for t0, t1 in sorted(ivs):
        if t1 <= end:
            continue
        total += t1 - max(t0, end)
        end = t1
    return total


def coverage(events: List[dict], segs: List[dict]) -> Tuple[float, float, float]:
    """(covered_us, span_us, fraction): how much of [first, last] virtual
    time is inside at least one WR span or compute/engine span."""
    ivs = [(s["t0"], s["t1"]) for s in segs]
    ts = [s["t0"] for s in segs] + [s["t1"] for s in segs]
    for ev in events:
        if ev.get("ph") == "X":
            t0, t1 = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            ivs.append((t0, t1))
            ts += [t0, t1]
        elif ev.get("ph") in ("i", "C"):
            ts.append(ev["ts"])
    if not ivs or not ts:
        return 0.0, 0.0, 0.0
    span = max(ts) - min(ts)
    covered = interval_union(ivs)
    return covered, span, (covered / span if span > 0 else 1.0)


def attribute(segs: List[dict], key: str) -> Dict[str, dict]:
    """Aggregate segment sums grouped by ``key`` ('dst' or 'phase')."""
    by: Dict[str, dict] = {}
    for s in segs:
        d = by.setdefault(s[key], {"n": 0, "nbytes": 0, "enqueue": 0.0,
                                   "post": 0.0, "wire": 0.0})
        d["n"] += 1
        d["nbytes"] += s["nbytes"]
        for part in ("enqueue", "post", "wire"):
            d[part] += s[part]
    for d in by.values():
        total = d["enqueue"] + d["post"] + d["wire"]
        d["total"] = total
        d["limited_by"] = max(("enqueue", "post", "wire"),
                              key=lambda p: d[p]) if total else "-"
    return by


def render(by: Dict[str, dict], label: str, top: int) -> None:
    """Print one attribution table, largest total first."""
    rows = sorted(by.items(), key=lambda kv: -kv[1]["total"])[:top]
    if not rows:
        return
    w = max(len(label), max(len(k) for k, _ in rows))
    print(f"\n{label:<{w}}  {'wrs':>6} {'MiB':>8} {'enq%':>6} {'post%':>6} "
          f"{'wire%':>6} {'total us':>10}  limited by")
    for k, d in rows:
        t = d["total"] or 1.0
        print(f"{k:<{w}}  {d['n']:>6} {d['nbytes'] / (1 << 20):>8.1f} "
              f"{100 * d['enqueue'] / t:>5.1f}% {100 * d['post'] / t:>5.1f}% "
              f"{100 * d['wire'] / t:>5.1f}% {d['total']:>10.1f}  "
              f"{d['limited_by']}-limited")


def pair_sums(segs: List[dict]) -> Dict[str, dict]:
    """Post-hoc per-(src>dst) segment sums recomputed from retained spans —
    the ground truth --live-parity checks the streaming counters against."""
    by: Dict[str, dict] = {}
    for s in segs:
        d = by.setdefault(f"{s['src']}>{s['dst']}",
                          {"n": 0, "nbytes": 0, "enqueue_us": 0.0,
                           "post_us": 0.0, "wire_us": 0.0})
        d["n"] += 1
        d["nbytes"] += s["nbytes"]
        d["enqueue_us"] += s["enqueue"]
        d["post_us"] += s["post"]
        d["wire_us"] += s["wire"]
    return by


def render_health(health: dict, top: int) -> None:
    """Per-channel health/deviation table from the embedded monitor doc."""
    pairs = health.get("pairs", {})
    if not pairs:
        return
    rows = sorted(pairs.items(), key=lambda kv: -kv[1]["wire_us"])[:top]
    w = max(len("channel"), max(len(k) for k, _ in rows))
    print(f"\n{'channel':<{w}}  {'wrs':>6} {'MiB':>8} {'wire us':>10} "
          f"{'model us':>10} {'dev':>6} {'win':>4}  status")
    for k, d in rows:
        exp = d["expected_wire_us"]
        dev = d["wire_us"] / exp if exp else 0.0
        status = "DEGRADED" if d["flagged"] else "ok"
        print(f"{k:<{w}}  {d['n']:>6} {d['nbytes'] / (1 << 20):>8.1f} "
              f"{d['wire_us']:>10.1f} {exp:>10.1f} {dev:>6.2f} "
              f"{d['windows']:>4}  {status}")
    for f in health.get("flags", []):
        print(f"  flag @{f['t']:.1f}us {f['src']}>{f['dst']} "
              f"ratio={f['ratio']:.2f} window={f['window']}")


def check_live_parity(health: dict, segs: List[dict],
                      tol: float = PARITY_TOL) -> List[str]:
    """Streaming (HealthMonitor) vs post-hoc (span) attribution: counts and
    bytes must match exactly, segment sums within ``tol`` relative."""
    bad: List[str] = []
    post_hoc = pair_sums(segs)
    live = health.get("pairs", {})
    for key in sorted(set(post_hoc) | set(live)):
        if key not in live:
            bad.append(f"pair {key}: in spans but not in live counters")
            continue
        if key not in post_hoc:
            bad.append(f"pair {key}: in live counters but not in spans")
            continue
        a, b = post_hoc[key], live[key]
        for fld in ("n", "nbytes"):
            if a[fld] != b[fld]:
                bad.append(f"pair {key}: {fld} live={b[fld]} "
                           f"post-hoc={a[fld]}")
        for fld in ("enqueue_us", "post_us", "wire_us"):
            ref = max(abs(a[fld]), 1e-9)
            if abs(a[fld] - b[fld]) / ref > tol:
                bad.append(f"pair {key}: {fld} live={b[fld]:.3f} "
                           f"post-hoc={a[fld]:.3f} "
                           f"({100 * abs(a[fld] - b[fld]) / ref:.2f}% "
                           f"> {100 * tol:.0f}%)")
    return bad


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON from export_chrome_trace")
    ap.add_argument("--min-coverage", type=float, default=0.95,
                    help="fail if less of the timeline is attributed")
    ap.add_argument("--top", type=int, default=16,
                    help="rows per table (largest first)")
    ap.add_argument("--live-parity", action="store_true",
                    help="require the embedded health counters to match the "
                         "span-recomputed attribution within 1%%")
    args = ap.parse_args(argv)

    doc = load_doc(args.trace)
    events = doc["traceEvents"]
    segs = wr_segments(events)
    n_b = sum(1 for ev in events
              if ev.get("ph") == "b" and ev.get("cat") == "wr")
    print(f"{args.trace}: {len(events)} events, {n_b} WR spans "
          f"({n_b - len(segs)} incomplete)")

    render(attribute(segs, "dst"), "destination", args.top)
    render(attribute(segs, "phase"), "phase", args.top)

    health: Optional[dict] = doc.get("health")
    if health is not None:
        render_health(health, args.top)

    rc = 0
    if args.live_parity:
        if health is None:
            print("FAIL: --live-parity needs a trace exported with a "
                  "HealthMonitor attached (no embedded health doc)",
                  file=sys.stderr)
            rc = 1
        else:
            bad = check_live_parity(health, segs)
            if bad:
                print(f"FAIL: live/post-hoc parity: {len(bad)} mismatches",
                      file=sys.stderr)
                for m in bad:
                    print(f"  {m}", file=sys.stderr)
                rc = 1
            else:
                print(f"\nlive parity: {len(segs)} spans across "
                      f"{len(health.get('pairs', {}))} pairs agree with the "
                      f"streaming counters (tol {100 * PARITY_TOL:.0f}%)")

    covered, span, frac = coverage(events, segs)
    print(f"\ncoverage: {covered:.1f} of {span:.1f} virtual us attributed "
          f"to named spans ({100 * frac:.1f}%, floor "
          f"{100 * args.min_coverage:.0f}%)")
    if frac < args.min_coverage:
        print("FAIL: timeline has untraced gaps", file=sys.stderr)
        return 1
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
