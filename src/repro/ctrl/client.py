"""ControlClient: the peer-side half of the control-plane protocol.

Embedded in every elastic peer (Prefiller / Decoder).  Owns the JOIN
handshake, the periodic LEASE-RENEW loop (with piggybacked load signals),
and LEAVE.  Incoming control messages arrive on the peer's *single* recv
pool interleaved with data-plane traffic; the owner decodes each payload
and offers it to :meth:`handle`, which consumes control messages and
returns False for everything else.

A crash is modeled by the owner's ``alive`` flag going False: the renew
loop checks ``alive_fn`` before every beat, so a crashed peer simply stops
renewing and its lease lapses at the control plane — no goodbye message,
exactly like a real process death.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from ..core import Fabric, MrDesc, NetAddr, TransferEngine
from . import messages as m
from .registry import MembershipView

DEFAULT_RENEW_US = 500.0


class ControlClient:
    """Peer-side control-plane endpoint: JOIN/renew/LEAVE plus inbound
    drain and view-update dispatch for one engine."""

    def __init__(self, engine: TransferEngine, fabric: Fabric,
                 ctrl_addr: NetAddr, peer_id: str, role: str, *,
                 renew_us: float = DEFAULT_RENEW_US, max_renewals: int = 256,
                 alive_fn: Callable[[], bool] = lambda: True,
                 inflight_fn: Callable[[], int] = lambda: 0,
                 free_pages_fn: Callable[[], int] = lambda: 0,
                 on_drain: Optional[Callable[[m.Drain], None]] = None,
                 on_view: Optional[Callable[[MembershipView], None]] = None):
        self.engine = engine
        self.fabric = fabric
        self.ctrl_addr = ctrl_addr
        self.peer_id = peer_id
        self.role = role
        self.renew_us = renew_us
        self.max_renewals = max_renewals
        self.alive_fn = alive_fn
        self.inflight_fn = inflight_fn
        self.free_pages_fn = free_pages_fn
        self.on_drain = on_drain
        self.on_view = on_view
        self.joined = False          # JOIN-ACK received
        self.left = False
        self.epoch: Optional[int] = None
        self.lease_us: Optional[float] = None
        self._renewals = 0

    # -- outbound ------------------------------------------------------------
    def join(self, *, nic: str, kv_desc: Optional[MrDesc],
             geom: Dict[str, Any], n_pages: int,
             lease_us: float = 0.0,
             schema: Optional[Dict[str, Any]] = None,
             host: Optional[str] = None,
             nvlink: Optional[bool] = None) -> None:
        """Send JOIN; registers this peer with the control plane.

        ``host``/``nvlink`` (the node-identity fields of the heterogeneous-
        fabric refactor) default to the owning engine's values, so peers
        advertise their NVLink domain without every call site changing."""
        if host is None:
            host = getattr(self.engine, "host", None)
        if nvlink is None:
            nvlink = bool(getattr(self.engine, "nvlink", False))
        self.engine.submit_send(self.ctrl_addr, m.encode(m.Join(
            peer_id=self.peer_id, role=self.role,
            addr=self.engine.address(0), nic=nic, kv_desc=kv_desc,
            geom=geom, n_pages=n_pages, lease_us=lease_us, schema=schema,
            host=host, nvlink=nvlink)))
        self._schedule_renew()

    def leave(self) -> None:
        """Send LEAVE (clean departure); stops future renewals."""
        if self.left:
            return
        self.left = True
        self.engine.submit_send(self.ctrl_addr,
                                m.encode(m.Leave(self.peer_id)))

    # -- inbound -------------------------------------------------------------
    def handle(self, msg: Any) -> bool:
        """Consume a decoded control message; False if it's not ours."""
        if isinstance(msg, m.JoinAck):
            self.joined = True
            self.epoch = msg.epoch
            self.lease_us = msg.lease_us
            return True
        if isinstance(msg, m.Drain):
            if self.on_drain is not None:
                self.on_drain(msg)
            return True
        if isinstance(msg, m.ViewUpdate):
            if self.on_view is not None:
                self.on_view(MembershipView.from_wire(msg.epoch, msg.peers))
            return True
        return False

    # -- lease renewals ------------------------------------------------------
    def _schedule_renew(self) -> None:
        if self.left or self._renewals >= self.max_renewals:
            return
        self._renewals += 1

        def renew() -> None:
            if self.left or not self.alive_fn():
                return     # crashed or departed: lease lapses at the ctrl
            self.engine.submit_send(self.ctrl_addr, m.encode(m.LeaseRenew(
                self.peer_id, inflight=self.inflight_fn(),
                free_pages=self.free_pages_fn())))
            self._schedule_renew()

        self.fabric.loop.schedule(self.renew_us, renew)
