"""ControlClient: the peer-side half of the control-plane protocol.

Embedded in every elastic peer (Prefiller / Decoder).  Owns the JOIN
handshake, the periodic LEASE-RENEW loop (with piggybacked load signals),
and LEAVE.  Incoming control messages arrive on the peer's *single* recv
pool interleaved with data-plane traffic; the owner decodes each payload
and offers it to :meth:`handle`, which consumes control messages and
returns False for everything else.

A crash is modeled by the owner's ``alive`` flag going False: the renew
loop checks ``alive_fn`` before every beat, so a crashed peer simply stops
renewing and its lease lapses at the control plane — no goodbye message,
exactly like a real process death.

Reliability (reliable-control-plane PR): constructed with a
:class:`~repro.ctrl.retry.CtrlRetryPolicy`, the client stamps its JOINs
and LEASE-RENEWs with a ``(sender, seq)`` identity and retransmits each on
a bounded backoff chain until acked (JOIN-ACK / LEASE-ACK).  A renew chain
that exhausts its budget is the client-side *partition detector*: the
plane has (as far as this peer can tell) stopped acking, its lease has
probably lapsed, so the client drops to un-joined and re-JOINs with
``prior_epoch`` advertised — the plane reconciles with a fresh epoch and
the peer resumes.  ``retry=None`` (default) is the fire-and-forget PR-9
client, byte-identical on the wire.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Optional

from ..core import Fabric, MrDesc, NetAddr, TransferEngine
from . import messages as m
from .registry import MembershipView
from .retry import CtrlRetryPolicy, DedupWindow

DEFAULT_RENEW_US = 500.0


class ControlClient:
    """Peer-side control-plane endpoint: JOIN/renew/LEAVE plus inbound
    drain and view-update dispatch for one engine."""

    def __init__(self, engine: TransferEngine, fabric: Fabric,
                 ctrl_addr: NetAddr, peer_id: str, role: str, *,
                 renew_us: float = DEFAULT_RENEW_US, max_renewals: int = 256,
                 alive_fn: Callable[[], bool] = lambda: True,
                 inflight_fn: Callable[[], int] = lambda: 0,
                 free_pages_fn: Callable[[], int] = lambda: 0,
                 on_drain: Optional[Callable[[m.Drain], None]] = None,
                 on_view: Optional[Callable[[MembershipView], None]] = None,
                 retry: Optional[CtrlRetryPolicy] = None):
        self.engine = engine
        self.fabric = fabric
        self.ctrl_addr = ctrl_addr
        self.peer_id = peer_id
        self.role = role
        self.renew_us = renew_us
        self.max_renewals = max_renewals
        self.alive_fn = alive_fn
        self.inflight_fn = inflight_fn
        self.free_pages_fn = free_pages_fn
        self.on_drain = on_drain
        self.on_view = on_view
        self.joined = False          # JOIN-ACK received
        self.left = False
        self.epoch: Optional[int] = None
        self.lease_us: Optional[float] = None
        self._renewals = 0
        # reliability: None => fire-and-forget PR-9 behaviour, bit-exact
        self.retry = retry
        self._seq = itertools.count(1)
        self._dedup = DedupWindow()     # inbound stamped DRAINs
        self._renew_ack = 0             # highest LEASE-ACKed renew seq
        self._incarnation = 0           # bumped on every partition re-JOIN
        self._join_kwargs: Optional[Dict[str, Any]] = None
        self.rejoins = 0                # partition-detector firings
        self.join_resends = 0
        self.renew_resends = 0
        self.join_exhausted = False     # JOIN chain spent with no ack

    # -- outbound ------------------------------------------------------------
    def join(self, *, nic: str, kv_desc: Optional[MrDesc],
             geom: Dict[str, Any], n_pages: int,
             lease_us: float = 0.0,
             schema: Optional[Dict[str, Any]] = None,
             host: Optional[str] = None,
             nvlink: Optional[bool] = None) -> None:
        """Send JOIN; registers this peer with the control plane.

        ``host``/``nvlink`` (the node-identity fields of the heterogeneous-
        fabric refactor) default to the owning engine's values, so peers
        advertise their NVLink domain without every call site changing."""
        if host is None:
            host = getattr(self.engine, "host", None)
        if nvlink is None:
            nvlink = bool(getattr(self.engine, "nvlink", False))
        # kept for partition re-JOINs: the advertisement must be identical
        # so the registry can recognise a pure retransmission
        self._join_kwargs = dict(nic=nic, kv_desc=kv_desc, geom=geom,
                                 n_pages=n_pages, lease_us=lease_us,
                                 schema=schema, host=host, nvlink=nvlink)
        self._send_join(prior_epoch=None)
        self._schedule_renew()

    def _send_join(self, *, prior_epoch: Optional[int]) -> None:
        msg = m.Join(peer_id=self.peer_id, role=self.role,
                     addr=self.engine.address(0), prior_epoch=prior_epoch,
                     **self._join_kwargs)
        if self.retry is None:
            self.engine.submit_send(self.ctrl_addr, m.encode(msg))
            return
        payload = m.encode(msg, sender=self.engine.address(0).node,
                           seq=next(self._seq))
        self.engine.submit_send(self.ctrl_addr, payload)
        self._arm_join_retry(payload, 0)

    def _arm_join_retry(self, payload: bytes, attempt: int) -> None:
        pol = self.retry

        def check() -> None:
            if self.joined or self.left or not self.alive_fn():
                return
            if attempt >= pol.max_retries:
                self.join_exhausted = True
                recorder = getattr(self.fabric, "recorder", None)
                if recorder is not None:
                    recorder.dump("ctrl-retry-exhausted")
                return
            self.join_resends += 1
            self.engine.submit_send(self.ctrl_addr, payload)
            self._arm_join_retry(payload, attempt + 1)

        self.fabric.loop.schedule(pol.timeout_us(attempt), check)

    def _on_partition(self) -> None:
        """A renew chain exhausted its budget: assume the lease lapsed.

        Drops to un-joined and re-JOINs with ``prior_epoch`` advertised;
        the plane reconciles (fresh epoch, old lease invalidated) and the
        peer resumes under the new view.  Re-entrancy-safe: while a re-JOIN
        is already in flight (``joined`` False) further exhaustions no-op."""
        if self.left or not self.alive_fn() or not self.joined:
            return
        self.joined = False
        self.rejoins += 1
        # invalidate every renew chain armed under the old incarnation: a
        # pre-partition renew whose exhaustion check lands *after* the
        # re-JOIN completes must not re-trigger the detector
        self._incarnation += 1
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("ctrl", f"partition:{self.peer_id}",
                       {"prior_epoch": self.epoch})
        recorder = getattr(self.fabric, "recorder", None)
        if recorder is not None:
            recorder.dump("ctrl-retry-exhausted")
        self._send_join(prior_epoch=self.epoch)

    def leave(self) -> None:
        """Send LEAVE (clean departure); stops future renewals.

        Under a retry policy the LEAVE gets a couple of blind bounded
        retransmits — processing is idempotent at the plane (a second
        LEAVE for a departed peer is a no-op), so no ack is needed."""
        if self.left:
            return
        self.left = True
        payload = m.encode(m.Leave(self.peer_id))
        self.engine.submit_send(self.ctrl_addr, payload)
        if self.retry is not None:
            for k in range(min(2, self.retry.max_retries)):
                self.fabric.loop.schedule(
                    self.retry.timeout_us(k),
                    lambda: self.engine.submit_send(self.ctrl_addr, payload))

    # -- inbound -------------------------------------------------------------
    def handle(self, msg: Any) -> bool:
        """Consume a decoded control message; False if it's not ours."""
        if isinstance(msg, m.JoinAck):
            self.joined = True
            # max(): a delayed duplicate ack from an *earlier* join must
            # never roll the epoch back below what a re-JOIN granted
            self.epoch = msg.epoch if self.epoch is None \
                else max(self.epoch, msg.epoch)
            self.lease_us = msg.lease_us
            return True
        if isinstance(msg, m.LeaseAck):
            self._renew_ack = max(self._renew_ack, msg.seq)
            return True
        if isinstance(msg, m.Drain):
            # stamped DRAINs (retry-enabled plane) are retransmitted until
            # we LEAVE — dedup so the owner's drain logic runs exactly once
            if msg.wire_seq is not None and self._dedup.seen(
                    msg.wire_sender, msg.wire_seq):
                return True
            if self.on_drain is not None:
                self.on_drain(msg)
            return True
        if isinstance(msg, m.ViewUpdate):
            if self.on_view is not None:
                self.on_view(MembershipView.from_wire(msg.epoch, msg.peers))
            return True
        return False

    # -- lease renewals ------------------------------------------------------
    def _schedule_renew(self) -> None:
        if self.left or self._renewals >= self.max_renewals:
            return
        self._renewals += 1

        def renew() -> None:
            if self.left or not self.alive_fn():
                return     # crashed or departed: lease lapses at the ctrl
            msg = m.LeaseRenew(self.peer_id, inflight=self.inflight_fn(),
                               free_pages=self.free_pages_fn())
            if self.retry is None:
                self.engine.submit_send(self.ctrl_addr, m.encode(msg))
            elif self.joined:
                seq = next(self._seq)
                payload = m.encode(msg, sender=self.engine.address(0).node,
                                   seq=seq)
                self.engine.submit_send(self.ctrl_addr, payload)
                self._arm_renew_retry(payload, seq, 0)
            # else: a (re-)JOIN is still in flight — skip this beat but
            # keep beating so renewals resume once the ack lands
            self._schedule_renew()

        self.fabric.loop.schedule(self.renew_us, renew)

    def _arm_renew_retry(self, payload: bytes, seq: int,
                         attempt: int) -> None:
        pol = self.retry
        inc = self._incarnation

        def check() -> None:
            # a newer renew's ack also proves liveness (seqs are ordered);
            # a chain from a previous join incarnation is void
            if (self.left or not self.alive_fn() or not self.joined
                    or self._renew_ack >= seq
                    or self._incarnation != inc):
                return
            if attempt >= pol.max_retries:
                self._on_partition()
                return
            self.renew_resends += 1
            self.engine.submit_send(self.ctrl_addr, payload)
            self._arm_renew_retry(payload, seq, attempt + 1)

        self.fabric.loop.schedule(pol.timeout_us(attempt), check)
