"""Autoscaler: elastic prefill capacity from queue-depth and TTFT signals.

A small policy object evaluated on a periodic tick (bounded, like every
other background loop in the simulator).  Signals:

* ``scheduler.queue_depth()`` — backlog + in-flight requests;
* ``scheduler.ttft_ema`` — exponential moving average of time-to-first-
  token, pushed by decoders via REQ-DONE;
* per-peer ``inflight`` from the registry (piggybacked on LEASE-RENEWs),
  used to pick the least-loaded peer as the scale-down victim.

Decisions:

* **scale up** when demand outruns capacity (queue depth at/above
  ``queue_high``, or TTFT EMA above ``ttft_high_us``) — calls the injected
  ``spawn(index)`` factory, which constructs a new peer; the peer JOINs the
  control plane itself, so the autoscaler never touches the registry.
* **scale down** when the system has been idle for ``idle_ticks_down``
  consecutive ticks — asks the control plane to *drain* the least-loaded
  live prefiller (never an outright removal: in-flight work finishes and
  KV pages are freed before the peer LEAVEs).

Both directions respect ``cooldown_us`` and the [min, max] size bounds.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .plane import ControlPlane

ROLE = "prefill"


@dataclass
class ScalingPolicy:
    """Thresholds governing when the autoscaler adds or drains prefillers."""

    queue_high: int = 3            # depth that triggers scale-up
    ttft_high_us: float = float("inf")   # TTFT SLO (optional signal)
    # percentile used when the scheduler carries an SloTracker; with no
    # tracker the signal stays the legacy single EMA
    ttft_percentile: float = 95.0
    idle_ticks_down: int = 3       # consecutive idle ticks before scale-down
    min_prefillers: int = 1
    max_prefillers: int = 8
    cooldown_us: float = 600.0     # min time between scaling actions
    # churn guard: hold all scaling actions while membership epochs are
    # churning (>= churn_guard_epochs changes inside the trailing window),
    # so a failover storm's transient queue spikes / idle dips can't drive
    # scale-up/scale-down oscillation.  0 disables the guard (seed default).
    churn_guard_epochs: int = 0
    churn_guard_window_us: float = 1_000.0


class Autoscaler:
    """Periodic scaling loop: watches queue depth / idleness through the
    control plane's views and spawns or drains prefillers per policy."""

    def __init__(self, ctrl: ControlPlane, scheduler, spawn: Callable[[int], object],
                 *, policy: Optional[ScalingPolicy] = None,
                 tick_us: float = 150.0, max_ticks: int = 200,
                 next_index: int = 1, auto: bool = True):
        self.ctrl = ctrl
        self.scheduler = scheduler
        self.spawn = spawn
        self.policy = policy or ScalingPolicy()
        self.tick_us = tick_us
        self.max_ticks = max_ticks
        self._ticks = 0
        self._running = True
        self._idle_ticks = 0
        self._next_index = next_index
        self._last_action_us = float("-inf")
        # churn guard state: view epochs observed and when they changed
        self._last_epoch: Optional[int] = None
        self._epoch_events: List[float] = []
        self.churn_holds = 0
        # (virtual time, action, detail) audit trail
        self.decisions: List[Tuple[float, str, str]] = []
        if auto:
            self._schedule_tick()

    # -- policy evaluation ---------------------------------------------------
    def step(self, now: float) -> Optional[str]:
        """Evaluate the policy once; returns the action taken (or None)."""
        pol = self.policy
        view = self.ctrl.view()
        live = view.routable(ROLE)
        draining = [p for p in view.by_role(ROLE) if p.status == "draining"]
        depth = self.scheduler.queue_depth()
        # latency signal: sliding-window percentile when the scheduler has
        # an SloTracker (PR 8), the legacy single EMA otherwise
        slo = getattr(self.scheduler, "slo", None)
        if slo is not None and len(slo.ttfts):
            ttft_sig: Optional[float] = slo.ttft_percentile(
                pol.ttft_percentile)
        else:
            ttft_sig = self.scheduler.ttft_ema

        self._idle_ticks = self._idle_ticks + 1 if depth == 0 else 0

        # churn guard: track how often the membership epoch has moved in
        # the trailing window; a storm of changes means the signals below
        # (queue spikes from re-routes, idle dips from drains) are
        # transient — hold rather than oscillate
        if pol.churn_guard_epochs > 0:
            if self._last_epoch is None:
                self._last_epoch = view.epoch
            elif view.epoch != self._last_epoch:
                self._epoch_events.append(now)
                self._last_epoch = view.epoch
            self._epoch_events = [
                t for t in self._epoch_events
                if now - t <= pol.churn_guard_window_us]
            if len(self._epoch_events) >= pol.churn_guard_epochs:
                self.churn_holds += 1
                return None

        if now - self._last_action_us < pol.cooldown_us:
            return None

        overloaded = depth >= pol.queue_high or (
            ttft_sig is not None and ttft_sig > pol.ttft_high_us)
        if overloaded and len(live) + len(draining) < pol.max_prefillers:
            idx = self._next_index
            self._next_index += 1
            self._last_action_us = now
            self.decisions.append((now, "up", f"spawn#{idx} depth={depth}"))
            tr = getattr(getattr(self.ctrl, "fabric", None), "tracer", None)
            if tr is not None:
                tr.instant("autoscale", f"up:spawn#{idx}", {"depth": depth})
            self.spawn(idx)
            return "up"

        if (self._idle_ticks >= pol.idle_ticks_down and not draining
                and len(live) > pol.min_prefillers):
            victim = min(live, key=lambda p: (p.inflight, p.peer_id))
            self._last_action_us = now
            self._idle_ticks = 0
            self.decisions.append((now, "down", f"drain {victim.peer_id}"))
            tr = getattr(getattr(self.ctrl, "fabric", None), "tracer", None)
            if tr is not None:
                tr.instant("autoscale", f"down:{victim.peer_id}",
                           {"inflight": victim.inflight})
            self.ctrl.drain(victim.peer_id)
            return "down"
        return None

    # -- tick loop -----------------------------------------------------------
    def stop(self) -> None:
        """Stop scheduling further ticks (in-flight ones become no-ops)."""
        self._running = False

    def _schedule_tick(self) -> None:
        if not self._running or self._ticks >= self.max_ticks:
            return
        self._ticks += 1

        def tick() -> None:
            self.step(self.ctrl.fabric.now)
            self._schedule_tick()

        self.ctrl.fabric.loop.schedule(self.tick_us, tick)
