"""Bounded retry policy + dedup window for ctrl RPCs over lossy SENDs.

PR-9 made the data plane fault-tolerant but left the control plane
fire-and-forget: ``core/faults.py`` never retries SENDs because replaying
one is not idempotent *at the transport*.  This module supplies the two
pieces that make replay safe one layer up:

* :class:`CtrlRetryPolicy` — a frozen knob bundle (attempt budget,
  ack timeout, exponential backoff) shared by ``ControlClient``,
  ``ControlPlane``, and the serving ``Scheduler``.  ``None`` everywhere
  means "PR-9 behaviour": no stamping, no retransmits, byte-identical
  wire traffic.
* :class:`DedupWindow` — a per-sender sliding window of recently seen
  ``(sender, seq)`` RPC identities.  Receivers consult it before acting
  on a stamped message, which turns at-least-once delivery (sender
  retransmits until acked) into effectively-once processing.

Both are pure bookkeeping: no RNG, no event scheduling — determinism
guarantees are untouched.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Set, Tuple


@dataclass(frozen=True)
class CtrlRetryPolicy:
    """Knobs for the bounded exponential-backoff ctrl retransmit chain.

    A retry-enabled sender transmits once, then re-checks for the ack at
    ``ack_timeout_us``, ``ack_timeout_us * backoff_factor``, ... — one
    retransmit per unacked check, at most ``max_retries`` retransmits
    (so ``1 + max_retries`` sends total).  Exhaustion is terminal for
    that RPC: the sender surfaces it (partition handling, recorder dump)
    rather than retrying forever.
    """

    max_retries: int = 4
    ack_timeout_us: float = 400.0
    backoff_factor: float = 2.0

    def timeout_us(self, attempt: int) -> float:
        """Backoff delay before re-checking after send number ``attempt``."""
        return self.ack_timeout_us * (self.backoff_factor ** attempt)


class DedupWindow:
    """Per-sender sliding window of recently processed RPC seqs.

    ``seen(sender, seq)`` returns True when the identity was already
    recorded (a retransmission of something this receiver acted on) and
    records it otherwise.  The window keeps the last ``depth`` seqs per
    sender — deep enough that a retransmit chain (a handful of sends)
    can never outrun it, shallow enough that a long-lived plane doesn't
    grow without bound.
    """

    def __init__(self, depth: int = 64):
        self.depth = depth
        self._seen: Dict[str, Set[int]] = {}
        self._order: Dict[str, Deque[int]] = {}

    def seen(self, sender: str, seq: int) -> bool:
        """Record ``(sender, seq)``; True iff it was already in the window."""
        seqs = self._seen.get(sender)
        if seqs is None:
            seqs = self._seen[sender] = set()
            self._order[sender] = deque()
        if seq in seqs:
            return True
        seqs.add(seq)
        order = self._order[sender]
        order.append(seq)
        if len(order) > self.depth:
            seqs.discard(order.popleft())
        return False

    def snapshot(self) -> Tuple[Tuple[str, int], ...]:
        """Window sizes per sender (for tests / debugging)."""
        return tuple(sorted((s, len(v)) for s, v in self._seen.items()))
