"""Typed wire messages for the control plane (and the serving data plane).

The paper's fabric-lib pairs its one-sided data plane with *out-of-band
address exchange*: peers learn each other's ``NetAddr``/``MrDesc`` over a
side channel before any WRITE can be posted.  The seed repo skipped that —
peers swapped descriptors by direct Python object reference, and the one
struct that did cross the wire (``DispatchReq``) was an ad-hoc pickle.

This module replaces both with a small typed protocol carried over the
fabric's own two-sided ``submit_send``/``submit_recvs`` path:

* every message is a dataclass registered under a 4-byte tag via ``@wire``;
* ``encode``/``decode`` produce a tagged, JSON-based, process-portable
  byte string (no pickle — the wire format is inspectable and versionable);
* fabric value types (``NetAddr``, ``MrDesc``, numpy arrays) round-trip
  through explicit markers, so a ``MrDesc`` received over the wire is
  usable as a WRITE destination exactly like a locally constructed one.

Reliability envelope (reliable-control-plane PR): ``encode`` can stamp a
``(sender, seq)`` RPC identity into a reserved ``_rpc`` top-level key —
receivers keep per-sender dedup windows keyed on it, which is what makes
retransmitting a lost ctrl SEND safe.  Unstamped encodings are
byte-identical to the pre-PR wire format.  ``decode`` is forward-
compatible: unknown top-level keys are ignored (never a crash), and a
message class may mark trailing fields ``_WIRE_OPTIONAL`` so they are
omitted from the encoding while ``None`` — existing payloads stay
bit-exact until a sender actually sets them.

Control-plane verbs (paper §4 "dynamic scaling", Holmes-style capability
registry): JOIN / JOIN-ACK / LEASE-RENEW / LEASE-ACK / DRAIN / LEAVE /
VIEW-UPDATE.
Data-plane verbs used by the elastic scheduler: SUBMIT / CANCEL / DONE.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..core import MrDesc, NetAddr

_REGISTRY: Dict[str, type] = {}


def wire(tag: str):
    """Class decorator: register a dataclass as a wire message under ``tag``."""
    if len(tag) != 4:
        raise ValueError(f"wire tag must be 4 chars: {tag!r}")

    def deco(cls):
        if tag in _REGISTRY:
            raise ValueError(f"duplicate wire tag {tag!r}")
        cls._WIRE_TAG = tag
        # known field names, for forward-compatible decoding (unknown
        # trailing keys from newer senders are dropped, never a crash)
        cls._WIRE_FIELDS = frozenset(f.name for f in dataclasses.fields(cls))
        # RPC identity attached by decode() when the payload was stamped
        # via encode(sender=..., seq=...); class-level None = unstamped
        cls.wire_sender = None
        cls.wire_seq = None
        _REGISTRY[tag] = cls
        return cls

    return deco


# -- value encoding -----------------------------------------------------------

def enc_value(v: Any) -> Any:
    """Recursively encode a field value into JSON-safe form."""
    if isinstance(v, NetAddr):
        return {"__na__": [v.node, v.dev]}
    if isinstance(v, MrDesc):
        return {"__mr__": [v.region_id, v.owner.node, v.owner.dev, v.nbytes,
                           [list(rk) for rk in v.rkeys]]}
    if isinstance(v, np.ndarray):
        return {"__nd__": [v.dtype.str, v.tolist()]}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [enc_value(x) for x in v]
    if isinstance(v, dict):
        return {k: enc_value(x) for k, x in v.items()}
    return v


def dec_value(v: Any) -> Any:
    """Inverse of :func:`enc_value`."""
    if isinstance(v, dict):
        if "__na__" in v:
            node, dev = v["__na__"]
            return NetAddr(node, int(dev))
        if "__mr__" in v:
            region_id, node, dev, nbytes, rkeys = v["__mr__"]
            return MrDesc(int(region_id), NetAddr(node, int(dev)), int(nbytes),
                          tuple((int(i), int(k)) for i, k in rkeys))
        if "__nd__" in v:
            dt, data = v["__nd__"]
            return np.asarray(data, dtype=np.dtype(dt))
        return {k: dec_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return [dec_value(x) for x in v]
    return v


def encode(msg: Any, *, sender: Optional[str] = None,
           seq: Optional[int] = None) -> bytes:
    """Serialize a registered message: ``<tag>\\0<json fields>``.

    ``sender``/``seq`` (always together) stamp the payload with an RPC
    identity in the reserved ``_rpc`` key — the retry machinery uses it so
    receivers can dedup retransmissions.  Unstamped encodings carry no
    extra bytes.  Fields listed in the class's ``_WIRE_OPTIONAL`` are
    omitted while ``None`` (wire back-compat for late-added fields)."""
    tag = getattr(msg, "_WIRE_TAG", None)
    if tag is None:
        raise TypeError(f"{type(msg).__name__} is not a @wire message")
    optional = getattr(msg, "_WIRE_OPTIONAL", ())
    fields = {}
    for f in dataclasses.fields(msg):
        v = getattr(msg, f.name)
        if v is None and f.name in optional:
            continue
        fields[f.name] = enc_value(v)
    if sender is not None:
        if seq is None:
            raise ValueError("encode: sender stamped without a seq")
        fields["_rpc"] = [sender, int(seq)]
    return tag.encode() + b"\0" + json.dumps(
        fields, separators=(",", ":")).encode()


def decode(payload: bytes) -> Any:
    """Parse a wire payload back into its registered message dataclass.

    Forward-compatible: top-level keys the class does not declare are
    ignored (a newer sender's trailing fields never crash an older
    receiver).  A stamped ``_rpc`` identity is surfaced as the decoded
    message's ``wire_sender``/``wire_seq`` attributes (None when absent)."""
    tag, _, body = bytes(payload).partition(b"\0")
    cls = _REGISTRY.get(tag.decode("ascii", "replace"))
    if cls is None:
        raise ValueError(f"unknown wire tag {tag!r}")
    raw = json.loads(body.decode())
    rpc = raw.pop("_rpc", None)
    known = cls._WIRE_FIELDS
    msg = cls(**{k: dec_value(v) for k, v in raw.items() if k in known})
    if rpc is not None:
        msg.wire_sender = str(rpc[0])
        msg.wire_seq = int(rpc[1])
    return msg


# -- control-plane messages ---------------------------------------------------

@wire("JOIN")
@dataclass
class Join:
    """Peer -> ctrl: register for membership.

    Publishes everything a remote needs to target this peer: wire address,
    the KV pool's ``MrDesc``, pool geometry, and the NIC kind (Holmes-style
    per-peer capability so mixed CX7/EFA pools can share one registry).
    ``host`` + ``nvlink`` extend that with node identity — two peers
    advertising the same host with ``nvlink`` reach each other over NVLink,
    so schedulers can prefer intra-node pairings (paper §6).
    """

    peer_id: str
    role: str                      # "prefill" | "decode"
    addr: NetAddr
    nic: str
    kv_desc: Optional[MrDesc]
    geom: Dict[str, Any]           # JSON-safe pool geometry fields
    n_pages: int
    lease_us: float                # requested lease duration
    # KvSchema wire form (kvlayout.KvSchema.to_wire()) — the Scheduler
    # refuses to pair peers whose schemas differ, at routing time
    schema: Optional[Dict[str, Any]] = None
    # physical-host identity + NVLink reach (heterogeneous fabrics):
    # defaulted so pre-PR joiners stay wire-compatible
    host: Optional[str] = None
    nvlink: bool = False
    # partition re-join: the view epoch this peer last held before its
    # lease lapsed / it stopped hearing the plane.  Omitted from the wire
    # while None (first JOIN), so pre-PR payloads stay bit-exact; the
    # plane uses it to log the reconciliation.
    prior_epoch: Optional[int] = None

    _WIRE_OPTIONAL = ("prior_epoch",)


@wire("JACK")
@dataclass
class JoinAck:
    """Ctrl -> peer: admission + the granted lease."""

    peer_id: str
    epoch: int
    lease_us: float


@wire("LEAS")
@dataclass
class LeaseRenew:
    """Peer -> ctrl: liveness + piggybacked load signals (for autoscaling)."""

    peer_id: str
    inflight: int = 0
    free_pages: int = 0


@wire("LACK")
@dataclass
class LeaseAck:
    """Ctrl -> peer: one LEASE-RENEW landed (echoes the renew's seq).

    Only sent for *stamped* renews (a retry-enabled client), so plain
    fire-and-forget clients see no new traffic.  A client whose renews
    stop being acked treats the plane as partitioned and re-JOINs once its
    retry budget is spent."""

    peer_id: str
    seq: int


@wire("DRAN")
@dataclass
class Drain:
    """Ctrl -> peer: stop accepting work, finish in-flight, then LEAVE."""

    peer_id: str
    reason: str = "scale-down"


@wire("LEAV")
@dataclass
class Leave:
    """Peer -> ctrl: clean departure (drain complete or voluntary)."""

    peer_id: str


@wire("VIEW")
@dataclass
class ViewUpdate:
    """Ctrl -> subscribers: epoch-numbered membership view snapshot."""

    epoch: int
    peers: List[Dict[str, Any]]    # registry.MembershipView wire form


# -- elastic data-plane messages (scheduler <-> decoder) ----------------------

@wire("SUBM")
@dataclass
class SubmitReq:
    """Scheduler -> decoder: route one request to (prefiller, decoder).

    ``attempt`` disambiguates re-routes: a failover re-submission of the
    same request id carries a higher attempt, so a late CANCEL for an older
    attempt can never kill the replacement (SEND delivery is unordered).
    """

    request_id: int
    input_ids: np.ndarray
    prefiller: NetAddr
    n_decode: int
    reply_to: NetAddr
    attempt: int = 0
    # (vision_seq, vision_dim) patch embeddings for vlm archs (optional)
    vision_emb: Optional[np.ndarray] = None


@wire("CANC")
@dataclass
class CancelReq:
    """Scheduler -> decoder: abandon one attempt; free its pages.

    ``fence_node``/``fence_epoch`` piggyback the zombie-writer guard: when
    the cancel was triggered by a peer vanishing from the view (lease
    expiry), the scheduler names the gone peer's node and the epoch at
    which it vanished — the decoder installs an engine-level fence so any
    WRITE that peer still has in flight (stamped with its stale join-time
    epoch) is rejected before its bytes land in reallocated KV pages.
    Both fields are omitted from the wire while None, so cancels that are
    not fence-bearing stay byte-identical to the pre-PR encoding."""

    request_id: int
    attempt: int = 0
    fence_node: Optional[str] = None
    fence_epoch: Optional[int] = None

    _WIRE_OPTIONAL = ("fence_node", "fence_epoch")


@wire("DONE")
@dataclass
class ReqDone:
    """Decoder -> scheduler: request completed (TTFT + generated tokens)."""

    request_id: int
    attempt: int
    peer_id: str
    ttft_us: float
    tokens: List[int] = field(default_factory=list)


@wire("XFLR")
@dataclass
class XferFail:
    """Structured mid-transfer failure notification (fault injection).

    Prefiller -> decoder: a KV handoff WRITE exhausted its retry budget —
    the decoder frees the attempt's pages and immediate expectations, then
    forwards the message to the scheduler (``reply_to`` of the attempt),
    which re-routes with a bumped attempt number.  ``peer_id`` names the
    failing prefiller.  The prefiller sends ``attempt=-1`` (DispatchReq
    carries no attempt number, keeping fault-free wire bytes bit-exact);
    the decoder stamps the authoritative attempt from its pending state
    before forwarding, and the scheduler uses it to drop notifications
    that raced a re-route (same contract as CANCEL)."""

    request_id: int
    attempt: int
    peer_id: str
    reason: str = ""
