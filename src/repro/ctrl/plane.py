"""ControlPlane: the membership service, running over the fabric itself.

The control plane owns one :class:`~repro.core.TransferEngine` and speaks
only the typed wire protocol of :mod:`repro.ctrl.messages` over the two-
sided SEND/RECV path — the same transport the data plane uses, mirroring
fabric-lib's out-of-band exchange running in-band once the fabric is up.

Responsibilities:

* admit JOINs into the :class:`~repro.ctrl.registry.PeerRegistry` and grant
  leases;
* expire lapsed leases on a periodic sweep (this subsumes the Scheduler's
  old hand-rolled heartbeat loop — liveness is now lease-based and peers
  push their own renewals);
* push epoch-numbered VIEW-UPDATEs to subscribers on every membership
  change;
* orchestrate scale-down: ``drain(peer_id)`` flips the registry state (so
  schedulers stop routing there at the next view) and sends the peer a
  DRAIN; the peer finishes in-flight work, frees its pages, and LEAVEs.

The sweep loop is bounded (``max_sweeps``) so ``run_until_idle`` stays
finite, exactly like the seed's bounded heartbeat train; ``stop()`` ends it
early.
"""

from __future__ import annotations

from typing import Callable, List

from ..core import Fabric, NetAddr
from . import messages as m
from .registry import MembershipView, PeerRegistry

DEFAULT_LEASE_US = 2_000.0
DEFAULT_SWEEP_US = 250.0


class ControlPlane:
    """The ctrl node: owns the PeerRegistry, answers JOIN/renew/LEAVE,
    sweeps expired leases, and broadcasts epoch-stamped view updates."""

    def __init__(self, fabric: Fabric, *, node: str = "ctrl",
                 nic: str = "efa", lease_us: float = DEFAULT_LEASE_US,
                 sweep_us: float = DEFAULT_SWEEP_US, max_sweeps: int = 256):
        self.fabric = fabric
        self.engine = fabric.add_engine(node, nic=nic)
        self.nic = nic
        self.registry = PeerRegistry()
        self.lease_us = lease_us
        self.sweep_us = sweep_us
        self.max_sweeps = max_sweeps
        self._sweeps = 0
        self._running = True
        self._subs: List[NetAddr] = []
        # peer_id -> cb(record) invoked when a lease expiry kills the peer
        self.on_death: List[Callable] = []
        self.engine.submit_recvs(1 << 16, 32, self._on_msg)
        self._schedule_sweep()

    # -- identity -----------------------------------------------------------
    def address(self) -> NetAddr:
        """Wire address peers SEND control messages to."""
        return self.engine.address(0)

    def view(self) -> MembershipView:
        """Current epoch-stamped membership snapshot."""
        return self.registry.view()

    # -- subscriptions -------------------------------------------------------
    def subscribe(self, addr: NetAddr) -> None:
        """Register a VIEW-UPDATE subscriber; pushes the current view."""
        if addr not in self._subs:
            self._subs.append(addr)
        self._send_view(addr)

    def _send_view(self, addr: NetAddr) -> None:
        view = self.registry.view()
        self.engine.submit_send(
            addr, m.encode(m.ViewUpdate(view.epoch, view.to_wire())))

    def _broadcast(self) -> None:
        for addr in self._subs:
            self._send_view(addr)

    # -- message handling ----------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        msg = m.decode(payload)
        tr = self.fabric.tracer
        if isinstance(msg, m.Join):
            # a peer may request a shorter lease; the server's is the cap
            lease = min(msg.lease_us, self.lease_us) if msg.lease_us \
                else self.lease_us
            self.registry.join(
                peer_id=msg.peer_id, role=msg.role, addr=msg.addr,
                nic=msg.nic, kv_desc=msg.kv_desc, geom=msg.geom,
                n_pages=msg.n_pages, lease_us=lease, now=self.fabric.now,
                schema=msg.schema, host=msg.host, nvlink=msg.nvlink)
            if tr is not None:
                tr.instant("ctrl", f"join:{msg.peer_id}",
                           {"role": msg.role, "epoch": self.registry.epoch})
            self.engine.submit_send(
                msg.addr,
                m.encode(m.JoinAck(msg.peer_id, self.registry.epoch, lease)))
            self._broadcast()
        elif isinstance(msg, m.LeaseRenew):
            self.registry.renew(
                msg.peer_id, now=self.fabric.now, lease_us=self.lease_us,
                inflight=msg.inflight, free_pages=msg.free_pages)
        elif isinstance(msg, m.Leave):
            if self.registry.leave(msg.peer_id) is not None:
                if tr is not None:
                    tr.instant("ctrl", f"leave:{msg.peer_id}",
                               {"epoch": self.registry.epoch})
                self._broadcast()
        else:
            raise ValueError(f"control plane got unexpected {type(msg).__name__}")

    # -- scale-down orchestration -------------------------------------------
    def drain(self, peer_id: str, reason: str = "scale-down") -> bool:
        """Start draining ``peer_id``: registry flip + DRAIN to the peer."""
        rec = self.registry.record(peer_id)
        if rec is None or self.registry.start_drain(peer_id) is None:
            return False
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("ctrl", f"drain:{peer_id}",
                       {"reason": reason, "epoch": self.registry.epoch})
        self._broadcast()
        self.engine.submit_send(rec.addr, m.encode(m.Drain(peer_id, reason)))
        return True

    # -- lease sweep ---------------------------------------------------------
    def stop(self) -> None:
        """Stop scheduling further lease sweeps."""
        self._running = False

    def _schedule_sweep(self) -> None:
        if not self._running or self._sweeps >= self.max_sweeps:
            return
        self._sweeps += 1

        def sweep() -> None:
            died = self.registry.expire(self.fabric.now)
            if died:
                tr = self.fabric.tracer
                for rec in died:
                    if tr is not None:
                        tr.instant("ctrl", f"lease_expired:{rec.peer_id}",
                                   {"epoch": self.registry.epoch})
                    for cb in self.on_death:
                        cb(rec)
                self._broadcast()
            self._schedule_sweep()

        self.fabric.loop.schedule(self.sweep_us, sweep)
