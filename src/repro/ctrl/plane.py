"""ControlPlane: the membership service, running over the fabric itself.

The control plane owns one :class:`~repro.core.TransferEngine` and speaks
only the typed wire protocol of :mod:`repro.ctrl.messages` over the two-
sided SEND/RECV path — the same transport the data plane uses, mirroring
fabric-lib's out-of-band exchange running in-band once the fabric is up.

Responsibilities:

* admit JOINs into the :class:`~repro.ctrl.registry.PeerRegistry` and grant
  leases;
* expire lapsed leases on a periodic sweep (this subsumes the Scheduler's
  old hand-rolled heartbeat loop — liveness is now lease-based and peers
  push their own renewals);
* push epoch-numbered VIEW-UPDATEs to subscribers on every membership
  change;
* orchestrate scale-down: ``drain(peer_id)`` flips the registry state (so
  schedulers stop routing there at the next view) and sends the peer a
  DRAIN; the peer finishes in-flight work, frees its pages, and LEAVEs.

The sweep loop is bounded (``max_sweeps``) so ``run_until_idle`` stays
finite, exactly like the seed's bounded heartbeat train; ``stop()`` ends it
early.

Reliability (reliable-control-plane PR): constructed with a
:class:`~repro.ctrl.retry.CtrlRetryPolicy`, the plane becomes safe under
ctrl-SEND loss/duplication — stamped inbound RPCs are deduped per sender
(duplicate JOIN/LEASE-RENEW re-send their ack instead of re-acting),
outbound DRAINs are retransmitted on a bounded backoff chain until the peer
leaves, and each lease sweep re-broadcasts the current view so a lost
final VIEW-UPDATE heals (views are full snapshots, so any later broadcast
subsumes a missed one).  With ``retry=None`` (the default) every byte on
the wire is identical to the fire-and-forget plane.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, List, Optional

from ..core import Fabric, NetAddr
from . import messages as m
from .registry import DRAINING, MembershipView, PeerRegistry
from .retry import CtrlRetryPolicy, DedupWindow

DEFAULT_LEASE_US = 2_000.0
DEFAULT_SWEEP_US = 250.0


class ControlPlane:
    """The ctrl node: owns the PeerRegistry, answers JOIN/renew/LEAVE,
    sweeps expired leases, and broadcasts epoch-stamped view updates."""

    def __init__(self, fabric: Fabric, *, node: str = "ctrl",
                 nic: str = "efa", lease_us: float = DEFAULT_LEASE_US,
                 sweep_us: float = DEFAULT_SWEEP_US, max_sweeps: int = 256,
                 retry: Optional[CtrlRetryPolicy] = None):
        self.fabric = fabric
        self.engine = fabric.add_engine(node, nic=nic)
        self.nic = nic
        self.registry = PeerRegistry()
        self.lease_us = lease_us
        self.sweep_us = sweep_us
        self.max_sweeps = max_sweeps
        self._sweeps = 0
        self._running = True
        self._subs: List[NetAddr] = []
        # peer_id -> cb(record) invoked when a lease expiry kills the peer
        self.on_death: List[Callable] = []
        # reliability: None => fire-and-forget PR-9 behaviour, bit-exact
        self.retry = retry
        self._dedup = DedupWindow()
        self.stats = {"dup_dropped": 0, "acks_resent": 0,
                      "drain_resends": 0, "rebroadcasts": 0}
        self._seq = itertools.count(1)   # outbound RPC seqs (stamped sends)
        self.engine.submit_recvs(1 << 16, 32, self._on_msg)
        self._schedule_sweep()

    # -- identity -----------------------------------------------------------
    def address(self) -> NetAddr:
        """Wire address peers SEND control messages to."""
        return self.engine.address(0)

    def view(self) -> MembershipView:
        """Current epoch-stamped membership snapshot."""
        return self.registry.view()

    # -- subscriptions -------------------------------------------------------
    def subscribe(self, addr: NetAddr) -> None:
        """Register a VIEW-UPDATE subscriber; pushes the current view."""
        if addr not in self._subs:
            self._subs.append(addr)
        self._send_view(addr)

    def _send_view(self, addr: NetAddr) -> None:
        view = self.registry.view()
        self.engine.submit_send(
            addr, m.encode(m.ViewUpdate(view.epoch, view.to_wire())))

    def _broadcast(self) -> None:
        for addr in self._subs:
            self._send_view(addr)

    # -- message handling ----------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        msg = m.decode(payload)
        tr = self.fabric.tracer
        if msg.wire_seq is not None and self._dedup.seen(
                msg.wire_sender, msg.wire_seq):
            # retransmission of an RPC we already acted on: re-send the ack
            # (it may have been the lost half) but never re-apply the effect
            self._on_dup(msg)
            return
        if isinstance(msg, m.Join):
            # a peer may request a shorter lease; the server's is the cap
            lease = min(msg.lease_us, self.lease_us) if msg.lease_us \
                else self.lease_us
            before = self.registry.epoch
            self.registry.join(
                peer_id=msg.peer_id, role=msg.role, addr=msg.addr,
                nic=msg.nic, kv_desc=msg.kv_desc, geom=msg.geom,
                n_pages=msg.n_pages, lease_us=lease, now=self.fabric.now,
                schema=msg.schema, host=msg.host, nvlink=msg.nvlink,
                rejoin=msg.prior_epoch is not None)
            if tr is not None:
                args = {"role": msg.role, "epoch": self.registry.epoch}
                if msg.prior_epoch is not None:
                    args["prior_epoch"] = msg.prior_epoch
                tr.instant("ctrl", ("rejoin:" if msg.prior_epoch is not None
                                    else "join:") + msg.peer_id, args)
            self.engine.submit_send(
                msg.addr,
                m.encode(m.JoinAck(msg.peer_id, self.registry.epoch, lease)))
            if self.registry.epoch != before:
                self._broadcast()
        elif isinstance(msg, m.LeaseRenew):
            ok = self.registry.renew(
                msg.peer_id, now=self.fabric.now, lease_us=self.lease_us,
                inflight=msg.inflight, free_pages=msg.free_pages)
            # ack only *stamped* renews (retry-enabled client) and only on
            # success — a client whose renews stop acking treats the plane
            # as partitioned and re-JOINs once its budget is spent
            if ok and msg.wire_seq is not None:
                rec = self.registry.record(msg.peer_id)
                if rec is not None:
                    self.engine.submit_send(rec.addr, m.encode(
                        m.LeaseAck(msg.peer_id, msg.wire_seq)))
        elif isinstance(msg, m.Leave):
            if self.registry.leave(msg.peer_id) is not None:
                if tr is not None:
                    tr.instant("ctrl", f"leave:{msg.peer_id}",
                               {"epoch": self.registry.epoch})
                self._broadcast()
        else:
            raise ValueError(f"control plane got unexpected {type(msg).__name__}")

    def _on_dup(self, msg: Any) -> None:
        """Handle a deduped retransmission: re-ack, never re-apply."""
        if isinstance(msg, m.Join):
            rec = self.registry.record(msg.peer_id)
            if rec is not None:
                lease = min(msg.lease_us, self.lease_us) if msg.lease_us \
                    else self.lease_us
                self.stats["acks_resent"] += 1
                self.engine.submit_send(msg.addr, m.encode(
                    m.JoinAck(msg.peer_id, self.registry.epoch, lease)))
                return
        elif isinstance(msg, m.LeaseRenew):
            rec = self.registry.record(msg.peer_id)
            if rec is not None:
                self.stats["acks_resent"] += 1
                self.engine.submit_send(rec.addr, m.encode(
                    m.LeaseAck(msg.peer_id, msg.wire_seq)))
                return
        self.stats["dup_dropped"] += 1

    # -- scale-down orchestration -------------------------------------------
    def drain(self, peer_id: str, reason: str = "scale-down") -> bool:
        """Start draining ``peer_id``: registry flip + DRAIN to the peer.

        Under a retry policy the DRAIN is stamped (so the peer dedups
        retransmissions) and retransmitted on the backoff chain until the
        peer's record leaves the DRAINING state (it LEAVEd, or its lease
        lapsed) or the budget is spent — a lost DRAIN no longer strands a
        peer serving into a view that excludes it."""
        rec = self.registry.record(peer_id)
        if rec is None or self.registry.start_drain(peer_id) is None:
            return False
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("ctrl", f"drain:{peer_id}",
                       {"reason": reason, "epoch": self.registry.epoch})
        self._broadcast()
        if self.retry is None:
            self.engine.submit_send(rec.addr, m.encode(m.Drain(peer_id, reason)))
        else:
            payload = m.encode(m.Drain(peer_id, reason),
                               sender=self.engine.node, seq=next(self._seq))
            self.engine.submit_send(rec.addr, payload)
            self._arm_drain_retry(peer_id, rec.addr, payload, 0)
        return True

    def _arm_drain_retry(self, peer_id: str, addr: NetAddr,
                         payload: bytes, attempt: int) -> None:
        pol = self.retry

        def check() -> None:
            rec = self.registry.record(peer_id)
            if rec is None or rec.status != DRAINING:
                return     # peer left (or died) — chain done
            if attempt >= pol.max_retries:
                recorder = getattr(self.fabric, "recorder", None)
                if recorder is not None:
                    recorder.dump("ctrl-retry-exhausted")
                return
            self.stats["drain_resends"] += 1
            self.engine.submit_send(addr, payload)
            self._arm_drain_retry(peer_id, addr, payload, attempt + 1)

        self.fabric.loop.schedule(pol.timeout_us(attempt), check)

    # -- lease sweep ---------------------------------------------------------
    def stop(self) -> None:
        """Stop scheduling further lease sweeps."""
        self._running = False

    def _schedule_sweep(self) -> None:
        if not self._running or self._sweeps >= self.max_sweeps:
            return
        self._sweeps += 1

        def sweep() -> None:
            died = self.registry.expire(self.fabric.now)
            if died:
                tr = self.fabric.tracer
                for rec in died:
                    if tr is not None:
                        tr.instant("ctrl", f"lease_expired:{rec.peer_id}",
                                   {"epoch": self.registry.epoch})
                    for cb in self.on_death:
                        cb(rec)
                self._broadcast()
            elif self.retry is not None and self._subs:
                # lossy-ctrl healing: views are full snapshots, so
                # periodically re-pushing the current one subsumes any
                # VIEW-UPDATE a subscriber missed (including the *last*
                # one, which no later membership change would re-send)
                self.stats["rebroadcasts"] += 1
                self._broadcast()
            self._schedule_sweep()

        self.fabric.loop.schedule(self.sweep_us, sweep)
