"""Control plane for dynamic peer membership (paper §4 "dynamic scaling").

A typed wire protocol (JOIN / LEASE-RENEW / DRAIN / LEAVE / VIEW-UPDATE)
carried over the fabric's own two-sided SEND/RECV path, an epoch-numbered
:class:`PeerRegistry`, lease-based liveness, and an :class:`Autoscaler`
policy — the layer that lets prefillers and decoders join, drain, and fail
mid-run while the scheduler routes only against the current epoch's view.
"""

from . import messages
from .autoscaler import Autoscaler, ScalingPolicy
from .client import ControlClient
from .plane import ControlPlane
from .registry import (DEAD, DRAINING, LEFT, LIVE, MembershipView,
                       PeerRegistry, PeerView)
from .retry import CtrlRetryPolicy, DedupWindow

__all__ = [
    "messages", "ControlPlane", "ControlClient", "PeerRegistry",
    "MembershipView", "PeerView", "Autoscaler", "ScalingPolicy",
    "CtrlRetryPolicy", "DedupWindow",
    "LIVE", "DRAINING", "DEAD", "LEFT",
]
