"""PeerRegistry: epoch-numbered membership views for elastic serving.

The registry is the control plane's single source of truth.  Every
membership *change* (join, drain start, death, departure) bumps the epoch
by exactly one; lease renewals refresh liveness without bumping.  Consumers
(the Scheduler, the Autoscaler) never see the mutable records — they get
immutable :class:`MembershipView` snapshots stamped with the epoch, and all
routing decisions are made against a view, never against peer objects.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

from ..core import MrDesc, NetAddr
from .messages import dec_value, enc_value

# peer lifecycle states
LIVE = "live"
DRAINING = "draining"
DEAD = "dead"
LEFT = "left"


@dataclass
class PeerRecord:
    """Mutable registry-internal record for one registered peer."""

    peer_id: str
    role: str                           # "prefill" | "decode"
    addr: NetAddr
    nic: str
    kv_desc: Optional[MrDesc]
    geom: Dict[str, Any]
    n_pages: int
    schema: Optional[Dict[str, Any]] = None   # KvSchema wire form
    host: Optional[str] = None          # physical machine (NVLink domain)
    nvlink: bool = False                # host-local peers reachable via NVLink
    status: str = LIVE
    lease_expires_us: float = 0.0
    joined_us: float = 0.0
    # piggybacked load signals from the last LEASE-RENEW
    inflight: int = 0
    free_pages: int = 0


@dataclass(frozen=True)
class PeerView:
    """Immutable per-peer slice of a membership view."""

    peer_id: str
    role: str
    addr: NetAddr
    nic: str
    status: str
    kv_desc: Optional[MrDesc]
    geom: Mapping[str, Any]
    n_pages: int
    inflight: int
    schema: Optional[Mapping[str, Any]] = None   # KvSchema wire form
    host: Optional[str] = None          # physical machine (NVLink domain)
    nvlink: bool = False                # host-local peers reachable via NVLink


@dataclass(frozen=True)
class MembershipView:
    """An epoch-stamped snapshot of the live membership.

    Views include LIVE and DRAINING peers (so consumers can observe drains)
    but never DEAD or LEFT ones.  ``routable`` additionally excludes
    draining peers — the scheduler must never place new work on them.
    """

    epoch: int
    peers: Tuple[PeerView, ...] = ()

    def routable(self, role: str) -> Tuple[PeerView, ...]:
        """LIVE peers of ``role`` — the only valid routing targets."""
        return tuple(p for p in self.peers
                     if p.role == role and p.status == LIVE)

    def by_role(self, role: str) -> Tuple[PeerView, ...]:
        """All view peers of ``role`` (including DRAINING)."""
        return tuple(p for p in self.peers if p.role == role)

    def peer(self, peer_id: str) -> Optional[PeerView]:
        """The view slice for ``peer_id``, or None if absent."""
        for p in self.peers:
            if p.peer_id == peer_id:
                return p
        return None

    def ids(self) -> Tuple[str, ...]:
        """Peer ids in view order."""
        return tuple(p.peer_id for p in self.peers)

    # -- wire form (carried inside a VIEW-UPDATE message) -------------------
    def to_wire(self) -> List[Dict[str, Any]]:
        """JSON-safe per-peer dicts for a VIEW-UPDATE payload."""
        return [{
            "peer_id": p.peer_id, "role": p.role,
            "addr": enc_value(p.addr), "nic": p.nic, "status": p.status,
            "kv_desc": enc_value(p.kv_desc), "geom": enc_value(dict(p.geom)),
            "n_pages": p.n_pages, "inflight": p.inflight,
            "schema": enc_value(dict(p.schema) if p.schema else None),
            "host": p.host, "nvlink": p.nvlink,
        } for p in self.peers]

    @staticmethod
    def from_wire(epoch: int, peers: List[Dict[str, Any]]) -> "MembershipView":
        """Rebuild a view from its wire form (tolerates pre-PR payloads)."""
        return MembershipView(epoch, tuple(
            PeerView(peer_id=e["peer_id"], role=e["role"],
                     addr=dec_value(e["addr"]), nic=e["nic"],
                     status=e["status"], kv_desc=dec_value(e["kv_desc"]),
                     geom=dec_value(e["geom"]), n_pages=int(e["n_pages"]),
                     inflight=int(e["inflight"]),
                     schema=dec_value(e.get("schema")),
                     host=e.get("host"), nvlink=bool(e.get("nvlink", False)))
            for e in peers))


class PeerRegistry:
    """Membership record store with strictly monotonic epochs."""

    def __init__(self) -> None:
        self._epoch = 0
        self._peers: Dict[str, PeerRecord] = {}
        # (epoch, event) audit trail — every bump leaves exactly one entry
        self.epoch_log: List[Tuple[int, str]] = []

    @property
    def epoch(self) -> int:
        """Current (strictly monotonic) membership epoch."""
        return self._epoch

    def _bump(self, event: str) -> int:
        self._epoch += 1
        self.epoch_log.append((self._epoch, event))
        return self._epoch

    # -- membership transitions ---------------------------------------------
    def join(self, *, peer_id: str, role: str, addr: NetAddr, nic: str,
             kv_desc: Optional[MrDesc], geom: Dict[str, Any], n_pages: int,
             lease_us: float, now: float,
             schema: Optional[Dict[str, Any]] = None,
             host: Optional[str] = None, nvlink: bool = False,
             rejoin: bool = False) -> int:
        """Admit (or re-admit) a peer; returns the current epoch.

        Idempotent for retransmitted JOINs: if an *identical* LIVE record
        already exists, the lease is refreshed and the current epoch is
        returned without a bump — a duplicated JOIN SEND is a membership
        no-op, so epochs bump exactly once per real change.  Any difference
        (new addr, changed capability, non-LIVE status) is a real
        (re-)registration and bumps.  ``rejoin=True`` labels the bump as a
        partition re-join in the epoch log.
        """
        old = self._peers.get(peer_id)
        if (old is not None and old.status == LIVE
                and old.role == role and old.addr == addr and old.nic == nic
                and old.kv_desc == kv_desc and old.geom == dict(geom)
                and old.n_pages == n_pages and old.schema == schema
                and old.host == host and old.nvlink == nvlink):
            old.lease_expires_us = now + lease_us
            return self._epoch
        self._peers[peer_id] = PeerRecord(
            peer_id=peer_id, role=role, addr=addr, nic=nic, kv_desc=kv_desc,
            geom=dict(geom), n_pages=n_pages, schema=schema,
            host=host, nvlink=nvlink, status=LIVE,
            lease_expires_us=now + lease_us, joined_us=now,
            free_pages=n_pages)
        return self._bump(("rejoin:" if rejoin else "join:") + peer_id)

    def renew(self, peer_id: str, *, now: float, lease_us: float,
              inflight: int = 0, free_pages: int = 0) -> bool:
        """Refresh a peer's lease; no epoch bump.  False if unknown/ended."""
        rec = self._peers.get(peer_id)
        if rec is None or rec.status in (DEAD, LEFT):
            return False
        rec.lease_expires_us = now + lease_us
        rec.inflight = inflight
        rec.free_pages = free_pages
        return True

    def start_drain(self, peer_id: str) -> Optional[int]:
        """LIVE -> DRAINING; returns the new epoch (None if not live)."""
        rec = self._peers.get(peer_id)
        if rec is None or rec.status != LIVE:
            return None
        rec.status = DRAINING
        return self._bump(f"drain:{peer_id}")

    def leave(self, peer_id: str) -> Optional[int]:
        """Clean departure: record removed from views; returns new epoch."""
        rec = self._peers.pop(peer_id, None)
        if rec is None:
            return None
        rec.status = LEFT
        return self._bump(f"leave:{peer_id}")

    def expire(self, now: float) -> List[PeerRecord]:
        """Mark peers whose lease has lapsed as DEAD (one bump per death)."""
        died = []
        for rec in list(self._peers.values()):
            if rec.status in (LIVE, DRAINING) and rec.lease_expires_us < now:
                rec.status = DEAD
                del self._peers[rec.peer_id]
                self._bump(f"dead:{rec.peer_id}")
                died.append(rec)
        return died

    # -- introspection -------------------------------------------------------
    def record(self, peer_id: str) -> Optional[PeerRecord]:
        """The mutable internal record for ``peer_id`` (tests/ctrl only)."""
        return self._peers.get(peer_id)

    def view(self) -> MembershipView:
        """Immutable epoch-stamped snapshot of LIVE + DRAINING peers."""
        return MembershipView(self._epoch, tuple(
            PeerView(peer_id=r.peer_id, role=r.role, addr=r.addr, nic=r.nic,
                     status=r.status, kv_desc=r.kv_desc, geom=dict(r.geom),
                     n_pages=r.n_pages, inflight=r.inflight, schema=r.schema,
                     host=r.host, nvlink=r.nvlink)
            for r in self._peers.values()))
