"""repro: fabric-lib (RDMA P2P for LLM systems) reproduced as a JAX/TPU framework."""

__version__ = "1.0.0"
