"""Training loop: data pipeline -> jitted train step -> metrics/checkpoints."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs.shapes import InputShape
from ..data.pipeline import Batcher, SyntheticCorpus
from ..models import init_params, loss_fn
from ..optim import AdamWConfig, adamw_update, cosine_with_warmup, init_adamw


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_path: str = ""
    warmup: int = 20
    moe_mode: str = "scatter"
    use_kernel: bool = False
    remat: bool = True
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def train(cfg, tcfg: TrainConfig, *, params=None,
          log_fn: Optional[Callable[[Dict], None]] = None) -> Dict:
    """Single-host training driver (examples + tests).  Returns history."""
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = init_params(cfg, key)
    opt_state = init_adamw(params)
    corpus = SyntheticCorpus(cfg.vocab, seed=tcfg.seed)
    batcher = Batcher(corpus, tcfg.global_batch, tcfg.seq_len)

    @jax.jit
    def step_fn(params, opt_state, batch, step):
        def loss(p):
            return loss_fn(p, batch, cfg, moe_mode=tcfg.moe_mode,
                           use_kernel=tcfg.use_kernel, remat=tcfg.remat)
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        lr_scale = cosine_with_warmup(step, warmup=tcfg.warmup, total=tcfg.steps)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             tcfg.opt, lr_scale)
        return params, opt_state, dict(metrics, loss=l, **om)

    history: List[Dict] = []
    t0 = time.time()
    for step in range(tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in batcher.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.asarray(step))
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=step, wall_s=time.time() - t0)
            history.append(rec)
            if log_fn:
                log_fn(rec)
        if tcfg.ckpt_every and tcfg.ckpt_path and step and step % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_path, {"params": params}, step=step,
                      meta={"arch": cfg.name})
    return {"history": history, "params": params, "opt_state": opt_state}
