from .trainer import TrainConfig, train

__all__ = ["TrainConfig", "train"]
