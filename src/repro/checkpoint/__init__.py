from . import ckpt
from .ckpt import restore, save

__all__ = ["ckpt", "save", "restore"]
