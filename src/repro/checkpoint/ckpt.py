"""Checkpointing: save/restore arbitrary pytrees of arrays (npz-based).

Tree structure is flattened to path-keyed arrays; metadata (step, config
name) rides in a JSON sidecar.  Sharded arrays are gathered on save and
re-sharded on restore by the caller's in_shardings — on a real cluster this
would be a per-host sharded save; the format keeps per-leaf addressing so
that upgrade is a local change.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, *, step: int = 0, meta: Optional[Dict] = None) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(p.with_suffix(".npz"), **flat)
    sidecar = {"step": step, "meta": meta or {}, "keys": sorted(flat)}
    p.with_suffix(".json").write_text(json.dumps(sidecar))


def restore(path: str, tree_like) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    p = pathlib.Path(path)
    data = np.load(p.with_suffix(".npz"))
    sidecar = json.loads(p.with_suffix(".json").read_text())
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path_k, leaf in leaves_with_path:
        key = "/".join(str(getattr(pp, "key", getattr(pp, "idx", pp))) for pp in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), sidecar["step"]
