"""TPU-native mappings of the paper's point-to-point patterns.

``moe_a2a``   — §6 dispatch/combine as shard_map all-to-all (+ Pallas pack)
``reshard``   — §5 weight-transfer schedules as collective-permute plans
``context``   — ambient mesh plumbing
"""

from .context import current_mesh, data_axes, use_mesh
from .moe_a2a import moe_a2a, moe_ep_psum
from .reshard import build_reshard, fsdp_to_tp, reshard_plan

__all__ = ["use_mesh", "current_mesh", "data_axes", "moe_a2a", "moe_ep_psum",
           "fsdp_to_tp", "reshard_plan", "build_reshard"]
