"""Expert-parallel MoE dispatch/combine — the fabric-lib pattern on TPU.

This is the TPU-native mapping of the paper's §6 host-proxy protocol:

  paper (RDMA)                          | here (XLA/ICI under shard_map)
  --------------------------------------+--------------------------------
  exchange per-expert token counts      | counts travel WITH the payload
  ("routes" scatter to all peers)       | (expert-id + gate appended as
                                        | feature channels — route and
                                        | token transfer fused, the same
                                        | "parallel token and route
                                        | transfer" trick §1)
  WRITE tokens into a contiguous,       | jax.lax.all_to_all into a
  bounded receive buffer per peer       | bounded (n_ranks, cap, D+2)
  (paper: N*T*max(R, E/N) bound)        | buffer; overflow tokens dropped
                                        | (capacity semantics, GShard)
  receiver shuffles tokens into a       | moe_pack Pallas kernel +
  Grouped-GEMM layout                   | capacity scatter to (E_loc, Ce)
  combine: single scatter re-using      | reverse all_to_all into the
  dispatch routing info                 | SAME slots (routing reused)
  fp32 accumulation (vs DeepEP bf16)    | moe_combine accumulates fp32

Tokens enter sharded over the data axes and are *locally* re-sharded over
the expert-parallel ('model') axis first — the zero-cost sequence-parallel
split — so the all-to-all runs only on the EP axis; GSPMD re-gathers the
output activations afterwards.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..models.common import rms_norm
from .context import current_mesh, data_axes

# capacity head-room over perfectly-balanced routing
DISPATCH_FACTOR = 2.0


def _capacity_scatter(rows: jax.Array, eids: jax.Array, valid: jax.Array,
                      n_experts: int, cap: int):
    """Scatter rows into (n_experts, cap, D) by expert id.

    Returns (buf, slot) where slot[i] is the row's landing slot (-1 dropped).
    """
    Tl, D = rows.shape
    oh = jax.nn.one_hot(eids, n_experts, dtype=jnp.int32) * valid[:, None]
    pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh, eids[:, None], 1)[:, 0]
    keep = (pos < cap) & valid.astype(bool)
    slot = jnp.where(keep, pos, cap)
    buf = jnp.zeros((n_experts, cap + 1, D), rows.dtype).at[eids, slot].add(
        jnp.where(keep[:, None], rows, 0))
    return buf[:, :cap], jnp.where(keep, slot, -1)


def moe_a2a(p, h: jax.Array, cfg, ep_axis: str = "model",
            mesh: Optional[jax.sharding.Mesh] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Paper-style expert-parallel MoE layer.  h: (T, D) normalised tokens.

    Must run inside a mesh context with ``ep_axis`` present.  Falls back to
    the scatter path when no mesh is active (single-device tests).
    """
    mesh = mesh or current_mesh()
    if mesh is None or ep_axis not in mesh.axis_names:
        from ..models.moe import moe_scatter
        return moe_scatter(p, h, cfg)

    import math

    T, D = h.shape
    E, k = cfg.n_routed, cfg.top_k
    m = mesh.shape[ep_axis]
    E_loc = E // m
    daxes = data_axes(mesh)
    nd = math.prod(mesh.shape[a] for a in daxes)
    if T % (m * nd) != 0:
        # Token count does not split over the EP axis (small decode batches):
        # fall back to replicated-token EP — each EP rank computes only its
        # local experts' contributions and the combine is a psum, the
        # "collective combine" the paper contrasts against.  For tiny T this
        # moves comparable bytes to a ragged dispatch.
        return moe_ep_psum(p, h, cfg, ep_axis, mesh)
    T_lm = T // (m * nd)
    cap = max(1, int(T_lm * k / m * DISPATCH_FACTOR))
    Ce = max(1, (m * cap) // max(E_loc, 1))

    def local(h_l, router, wg, wu, wd, *shared):
        # h_l: (T_lm, D) — sharded over data axes AND the EP axis.
        Tl = h_l.shape[0]
        logits = h_l.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        me = jax.lax.pmean(probs.mean(0), daxes + (ep_axis,))
        ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0)
        ce = jax.lax.pmean(ce / jnp.maximum(ce.sum(), 1.0), daxes + (ep_axis,))
        aux = E * jnp.sum(me * ce)

        # ---- dispatch: pack per-destination-rank send buffer ----------------
        fe = eids.reshape(-1)                                # (Tl*k,) global expert
        fg = gates.reshape(-1)
        ft = jnp.repeat(jnp.arange(Tl), k)
        dest = fe // E_loc                                   # destination EP rank
        # slot within each destination block (same cumsum trick as capacity)
        oh = jax.nn.one_hot(dest, m, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh, dest[:, None], 1)[:, 0]
        keep = pos < cap
        slot = jnp.where(keep, pos, -1)
        flat_slot = jnp.where(keep, dest * cap + pos, -1)    # (Tl*k,)

        # route info rides with the payload: [token | local-expert-id | gate]
        aug = jnp.concatenate([
            h_l, jnp.zeros((Tl, 2), h_l.dtype)], axis=1)     # (Tl, D+2)
        perm = jnp.full((m * cap,), -1, jnp.int32).at[
            jnp.where(keep, flat_slot, m * cap)].set(ft, mode="drop")
        from ..kernels import ops as kops
        send = kops.moe_pack_auto(aug, perm)                 # (m*cap, D+2)
        meta_e = jnp.full((m * cap,), -1.0, jnp.float32).at[
            jnp.where(keep, flat_slot, m * cap)].set(
                (fe % E_loc).astype(jnp.float32), mode="drop")
        meta_g = jnp.zeros((m * cap,), jnp.float32).at[
            jnp.where(keep, flat_slot, m * cap)].set(fg, mode="drop")
        send = send.at[:, D].set(meta_e.astype(send.dtype))
        send = send.at[:, D + 1].set(meta_g.astype(send.dtype))

        recv = jax.lax.all_to_all(send.reshape(m, cap, D + 2), ep_axis,
                                  split_axis=0, concat_axis=0, tiled=False)
        recv = recv.reshape(m * cap, D + 2)

        # ---- expert compute (grouped, capacity Ce) -------------------------
        r_eid = recv[:, D].astype(jnp.int32)
        r_gate = recv[:, D + 1].astype(jnp.float32)
        r_valid = (r_eid >= 0).astype(jnp.int32)
        r_tok = recv[:, :D]
        buf, r_slot = _capacity_scatter(r_tok, jnp.maximum(r_eid, 0),
                                        r_valid, E_loc, Ce)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)   # (E_loc,Ce,D)
        # gather back into receive-buffer row order
        ye_pad = jnp.concatenate([ye, jnp.zeros((E_loc, 1, D), ye.dtype)], 1)
        rows = ye_pad[jnp.maximum(r_eid, 0), jnp.where(r_slot >= 0, r_slot, Ce)]
        rows = jnp.where((r_slot >= 0)[:, None], rows, 0)
        # apply gate on the expert side (combine then only sums) — keeps the
        # return payload D-wide
        rows = rows * r_gate[:, None].astype(rows.dtype)

        # ---- combine: reverse all_to_all into the SAME slots ----------------
        back = jax.lax.all_to_all(rows.reshape(m, cap, D), ep_axis,
                                  split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(m * cap, D)
        inv = jnp.where(keep, flat_slot, -1).reshape(Tl, k)
        ones = jnp.ones((Tl, k), jnp.float32)                # gates pre-applied
        y = kops.moe_combine_auto(back, inv, ones)

        if shared:
            swg, swu, swd = shared
            y = y + (jax.nn.silu(h_l @ swg) * (h_l @ swu)) @ swd
        return y, aux

    in_specs = (P((*daxes, ep_axis), None),                  # h: fully sharded T
                P(None, None),                               # router replicated
                P(ep_axis, None, None),                      # experts EP-sharded
                P(ep_axis, None, None),
                P(ep_axis, None, None))
    args = [h, p["router"], p["wg"], p["wu"], p["wd"]]
    if "swg" in p:
        in_specs = in_specs + (P(None, None),) * 3
        args += [p["swg"], p["swu"], p["swd"]]
    out_specs = (P((*daxes, ep_axis), None), P())

    y, aux = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)(*args)
    return y, aux


def moe_ep_psum(p, h: jax.Array, cfg, ep_axis: str,
                mesh: jax.sharding.Mesh) -> Tuple[jax.Array, jax.Array]:
    """Replicated-token expert parallelism (collective-style combine).

    Tokens stay sharded over the data axes and replicated over the EP axis;
    each EP rank runs ONLY its local experts over all its tokens and the
    partial outputs are psum'ed.  No token movement — the communication is
    one all-reduce of the activations, the pattern the paper's P2P dispatch
    replaces.  Used as (a) the decode fallback and (b) the §Perf baseline.
    """
    T, D = h.shape
    E, k = cfg.n_routed, cfg.top_k
    m = mesh.shape[ep_axis]
    E_loc = E // m
    daxes = data_axes(mesh)
    cap = max(1, int(T // math_prod(mesh, daxes) * k / max(E_loc, 1) * DISPATCH_FACTOR))

    def local(h_l, router, wg, wu, wd, *shared):
        Tl = h_l.shape[0]
        rank = jax.lax.axis_index(ep_axis)
        logits = h_l.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        me = jax.lax.pmean(probs.mean(0), daxes)
        ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0)
        ce = jax.lax.pmean(ce / jnp.maximum(ce.sum(), 1.0), daxes)
        aux = E * jnp.sum(me * ce)

        fe = eids.reshape(-1)
        fg = gates.reshape(-1)
        ft = jnp.repeat(jnp.arange(Tl), k)
        mine = (fe // E_loc) == rank
        le = jnp.where(mine, fe % E_loc, 0)
        buf, slot = _capacity_scatter(h_l[ft], le, mine.astype(jnp.int32),
                                      E_loc, cap)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        ye = jnp.concatenate([ye, jnp.zeros((E_loc, 1, D), ye.dtype)], 1)
        rows = ye[le, jnp.where(slot >= 0, slot, cap)]
        rows = jnp.where((slot >= 0)[:, None], rows, 0) * fg[:, None].astype(ye.dtype)
        y = jnp.zeros((Tl, D), h_l.dtype).at[ft].add(rows.astype(h_l.dtype))
        y = jax.lax.psum(y, ep_axis)
        if shared:
            swg, swu, swd = shared
            y = y + (jax.nn.silu(h_l @ swg) * (h_l @ swu)) @ swd
        return y, aux

    in_specs = (P(daxes if daxes else None, None),
                P(None, None),
                P(ep_axis, None, None), P(ep_axis, None, None), P(ep_axis, None, None))
    args = [h, p["router"], p["wg"], p["wu"], p["wd"]]
    if "swg" in p:
        in_specs = in_specs + (P(None, None),) * 3
        args += [p["swg"], p["swu"], p["swd"]]
    out_specs = (P(daxes if daxes else None, None), P())
    y, aux = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)(*args)
    return y, aux


def math_prod(mesh, axes) -> int:
    import math
    return max(1, math.prod(mesh.shape[a] for a in axes))
