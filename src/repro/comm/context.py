"""Mesh context: lets deeply-nested layers (MoE a2a) find the active mesh."""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

_state = threading.local()


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def data_axes(mesh: jax.sharding.Mesh):
    """Batch-sharding axes of a production mesh ((pod,)data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
