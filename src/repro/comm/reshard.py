"""TPU-native weight resharding: §5's static schedule as an XLA program.

On TPU, the paper's P2P weight push (training sharding -> inference
sharding) is a *resharding*: a jitted identity whose input sharding is the
trainer's (FSDP-style, data-axis sharded) and whose output sharding is the
server's (TP, model-axis sharded).  GSPMD emits the minimal
collective-permute/all-to-all schedule — the XLA analogue of the paper's
controller-computed route table — while the baseline gathers to a fully
replicated copy first (the rank0 pattern).

``reshard_plan`` compiles both and reports the collective bytes each moves,
giving the P2P-vs-rank0 comparison in HLO terms.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..roofline.hlo_cost import analyze_hlo


def _identity(tree):
    return jax.tree.map(lambda x: x, tree)


def build_reshard(mesh: Mesh, shapes, src_specs, dst_specs):
    """Compile tree-reshard(src sharding -> dst sharding).  Returns
    (compiled, collective_bytes_per_device)."""
    src = jax.tree.map(lambda s: NamedSharding(mesh, s), src_specs,
                       is_leaf=lambda x: isinstance(x, P))
    dst = jax.tree.map(lambda s: NamedSharding(mesh, s), dst_specs,
                       is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(_identity, in_shardings=(src,), out_shardings=dst)
    compiled = fn.lower(shapes).compile()
    cost = analyze_hlo(compiled.as_text())
    return compiled, cost


def fsdp_to_tp(x, mesh: Mesh, *, daxes=("data",), ep_axis: str = "model"):
    """Explicit FSDP(row-sharded over all axes) -> TP(col-sharded) reshard.

    GSPMD's fallback for this transpose is full rematerialisation (it warns
    'Involuntary full rematerialization'): replicate, then re-slice — every
    device receives the whole tensor.  The paper's insight applies on TPU
    too: an explicit schedule (slice the destination column block locally,
    then all-gather only those rows) moves ``1/tp`` of the bytes.

    x: (R, C) row-sharded over (daxes..., ep_axis); returns (R, C)
    col-sharded over ep_axis (replicated over daxes).
    """
    import jax.numpy as jnp
    tp = mesh.shape[ep_axis]
    all_axes = tuple(daxes) + (ep_axis,)

    def local(x_l):
        # 1. all_to_all on the TP axis: send each destination ITS column
        #    block; receive my column block's rows from every TP peer
        r, c = x_l.shape
        blocks = x_l.reshape(r, tp, c // tp).transpose(1, 0, 2)   # (tp, r, c/tp)
        mine = jax.lax.all_to_all(blocks, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        mine = mine.reshape(tp * r, c // tp)
        # 2. all_gather the remaining row shards over the data axes
        if daxes:
            mine = jax.lax.all_gather(mine, tuple(daxes), axis=0, tiled=True)
        return mine

    return shard_map(
        local, mesh=mesh,
        in_specs=P((*daxes, ep_axis), None),
        out_specs=P(None, ep_axis), check_vma=False)(x)


def reshard_plan(mesh: Mesh, shapes, train_specs, infer_specs) -> Dict:
    """P2P reshard vs gather-to-replicated baseline, in collective bytes."""
    _, direct = build_reshard(mesh, shapes, train_specs, infer_specs)
    repl = jax.tree.map(lambda s: P(*([None] * len(s))), train_specs,
                        is_leaf=lambda x: isinstance(x, P))
    _, gather = build_reshard(mesh, shapes, train_specs, repl)
    _, scatter = build_reshard(mesh, shapes, repl, infer_specs)
    # explicit fabric-lib-style schedule for the 2D FSDP->TP leaves
    import jax.numpy as jnp
    daxes = tuple(a for a in mesh.axis_names if a != "model")
    smart_bytes = 0.0
    try:
        two_d = {k: v for k, v in shapes.items()
                 if len(getattr(v, "shape", ())) == 2}
        if two_d:
            fn = jax.jit(lambda t: {k: fsdp_to_tp(v, mesh, daxes=daxes)
                                    for k, v in t.items()})
            comp = fn.lower(two_d).compile()
            smart_bytes = analyze_hlo(comp.as_text()).coll_wire_bytes
    except Exception:
        smart_bytes = float("nan")
    return {
        "gspmd_wire_bytes": direct.coll_wire_bytes,
        "gspmd_breakdown": direct.coll_breakdown,
        "smart_wire_bytes": smart_bytes,
        "rank0_wire_bytes": gather.coll_wire_bytes + scatter.coll_wire_bytes,
        "smart_vs_gspmd": direct.coll_wire_bytes / max(smart_bytes, 1.0),
    }
