"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs in Python for correctness validation; on TPU the same
``pl.pallas_call`` lowers to Mosaic.  ``INTERPRET`` can be forced for tests.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe_combine as _combine
from . import moe_pack as _pack
from . import paged_copy as _paged
from . import ssd_scan as _ssd

INTERPRET: Optional[bool] = None  # None => auto (CPU -> True)


def _interp() -> bool:
    if INTERPRET is not None:
        return INTERPRET
    return jax.default_backend() == "cpu"


@jax.custom_vjp
def moe_pack(x: jax.Array, perm: jax.Array) -> jax.Array:
    """Differentiable row gather (Pallas); -1 rows emit zeros.

    Linear in x: the VJP scatter-adds cotangent rows back (pure jnp — the
    backward is bandwidth-trivial compared to the expert GEMMs).
    """
    return _pack.moe_pack(x, perm, interpret=_interp())


def _pack_fwd(x, perm):
    return moe_pack(x, perm), (perm, x.shape[0])


def _pack_bwd(res, dy):
    perm, T = res
    keep = perm >= 0
    dx = jnp.zeros((T, dy.shape[1]), dy.dtype).at[
        jnp.where(keep, perm, T)].add(
            jnp.where(keep[:, None], dy, 0), mode="drop")
    return dx, None


moe_pack.defvjp(_pack_fwd, _pack_bwd)


@jax.custom_vjp
def moe_combine(ye: jax.Array, inv: jax.Array, gates: jax.Array) -> jax.Array:
    """Differentiable weighted combine (Pallas), fp32 accumulation."""
    return _combine.moe_combine(ye, inv, gates, interpret=_interp())


def _combine_fwd(ye, inv, gates):
    return moe_combine(ye, inv, gates), (ye, inv, gates)


def _combine_bwd(res, dy):
    ye, inv, gates = res
    T, K = inv.shape
    M = ye.shape[0]
    keep = inv >= 0
    safe = jnp.where(keep, inv, M)
    w = jnp.where(keep, gates, 0.0).astype(dy.dtype)
    # d_ye[inv[t,k]] += gates[t,k] * dy[t]
    contrib = jnp.einsum("td,tk->tkd", dy, w)
    d_ye = jnp.zeros((M, ye.shape[1]), ye.dtype).at[safe.reshape(-1)].add(
        contrib.reshape(T * K, -1).astype(ye.dtype), mode="drop")
    # d_gates[t,k] = <ye[inv[t,k]], dy[t]>
    rows = jnp.take(ye, jnp.minimum(safe, M - 1), axis=0)
    d_g = jnp.einsum("tkd,td->tk", rows.astype(dy.dtype), dy)
    d_g = jnp.where(keep, d_g, 0.0).astype(gates.dtype)
    return d_ye, None, d_g


moe_combine.defvjp(_combine_fwd, _combine_bwd)


# Host-proxy entry points (moekit's receiver shuffle and combine reduce):
# numpy-first wrappers living in the jax-free `kernels.host` module; they
# delegate to the Pallas kernels above when an accelerator backend is live.
from .host import moe_combine_host, moe_pack_host  # noqa: E402,F401


def moe_pack_auto(x: jax.Array, perm: jax.Array) -> jax.Array:
    """Backend-adaptive pack: the Pallas kernel on TPU, the pure-jnp oracle
    (an XLA gather) elsewhere.  Interpret-mode Pallas inside a compiled hot
    path lowers to millions of row-sized loop ops — fine for validating the
    kernel, catastrophic inside the 48-layer dry-run (§Perf iteration E)."""
    if jax.default_backend() == "cpu":
        from . import ref
        return ref.moe_pack(x, perm)
    return moe_pack(x, perm)


def moe_combine_auto(ye: jax.Array, inv: jax.Array, gates: jax.Array) -> jax.Array:
    if jax.default_backend() == "cpu":
        from . import ref
        return ref.moe_combine(ye, inv, gates)
    return moe_combine(ye, inv, gates)


@functools.partial(jax.jit, static_argnames=("block_e",))
def paged_copy(src: jax.Array, src_idx: jax.Array, dst: jax.Array,
               dst_idx: jax.Array, *, block_e: int = 2048) -> jax.Array:
    return _paged.paged_copy(src, src_idx, dst, dst_idx, block_e=block_e,
                             interpret=_interp())


def ssd_intra(xw: jax.Array, cum: jax.Array, Br: jax.Array, Cr: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """SSD intra-chunk block in model layout.

    xw: (b,nc,cl,h,p); cum: (b,nc,cl,h); Br, Cr: (b,nc,cl,h,n).
    Returns (y (b,nc,cl,h,p), states (b,nc,h,p,n)) fp32, matching ref.
    """
    b, nc, cl, h, p = xw.shape
    n = Br.shape[-1]
    flat = lambda t: t.transpose(0, 1, 3, 2, 4).reshape(b * nc, h, cl, t.shape[-1])
    xw_f = flat(xw)
    cum_f = cum.transpose(0, 1, 3, 2).reshape(b * nc, h, cl, 1)
    y, st = _ssd.ssd_intra_flat(flat(jnp.asarray(xw)), cum_f,
                                flat(Br), flat(Cr), interpret=_interp())
    y = y.reshape(b, nc, h, cl, p).transpose(0, 1, 3, 2, 4)
    st = st.reshape(b, nc, h, p, n)
    return y, st


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    from . import flash_attention as _fa
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interp())
