"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (shape/dtype
sweeps + hypothesis property tests assert allclose).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def moe_pack(x: jax.Array, perm: jax.Array) -> jax.Array:
    """Gather rows of ``x`` into packed order.

    x: (T, D); perm: (M,) int32, source row per packed row, -1 => zero row.
    Returns (M, D).
    """
    gathered = jnp.take(x, jnp.maximum(perm, 0), axis=0)
    return jnp.where((perm >= 0)[:, None], gathered, 0).astype(x.dtype)


def moe_combine(ye: jax.Array, inv: jax.Array, gates: jax.Array) -> jax.Array:
    """Weighted combine of expert outputs back into token order.

    ye: (M, D) packed expert outputs; inv: (T, K) packed-row index of token
    t's k-th expert output (-1 => dropped); gates: (T, K) combine weights.
    Returns (T, D) = sum_k gates[t,k] * ye[inv[t,k]].
    """
    T, K = inv.shape
    rows = jnp.take(ye, jnp.maximum(inv, 0), axis=0)          # (T,K,D)
    w = jnp.where(inv >= 0, gates, 0.0).astype(ye.dtype)
    return jnp.einsum("tkd,tk->td", rows, w)


def paged_copy(src: jax.Array, src_idx: jax.Array, dst: jax.Array,
               dst_idx: jax.Array) -> jax.Array:
    """dst[dst_idx[i]] = src[src_idx[i]] for each page i.

    src: (Ps, E); dst: (Pd, E); indices: (P,). Returns updated dst.
    """
    pages = jnp.take(src, src_idx, axis=0)
    return dst.at[dst_idx].set(pages)


def ssd_intra(xw: jax.Array, cum: jax.Array, Br: jax.Array, Cr: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD block (matches models.ssm.ssd_chunked's intra term).

    xw:  (b, nc, cl, h, p)  dt-weighted inputs
    cum: (b, nc, cl, h)     cumulative dt*A within the chunk (<= 0)
    Br, Cr: (b, nc, cl, h, n)
    Returns (y_intra (b,nc,cl,h,p), states (b,nc,h,p,n)).
    """
    cl = xw.shape[2]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    ii, jj = jnp.arange(cl)[:, None], jnp.arange(cl)[None, :]
    L = jnp.where((ii >= jj)[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br)
    y = jnp.einsum("bcijh,bcjhp->bcihp", CB * L, xw)
    decay = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bcjhn,bcjhp->bchpn", Br * decay[..., None], xw)
    return y, states


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """Dense oracle for the flash kernel.  q,k,v: (B, H, S, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = jnp.arange(q.shape[2])[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((q.shape[2], k.shape[2]), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
