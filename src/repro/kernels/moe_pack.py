"""Pallas TPU kernel: MoE dispatch pack (token gather by permutation).

The TPU-native analogue of the paper's §6 dispatch *send* kernel: tokens are
copied from their natural order into a contiguous per-expert send buffer so
each peer receives one dense slab (paper Fig. 7: "dispatch into private and
contiguous buffers").  On TPU the "peers" are expert-parallel shards and the
slab is handed to ``ragged_all_to_all``; this kernel produces it.

Layout: rows are gathered with a scalar-prefetched permutation; the feature
dimension is tiled at 128 lanes so copies are VPU/VREG aligned.  ``perm``
rows of -1 emit zeros (capacity padding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _pack_kernel(perm_ref, x_ref, o_ref, *, block_m: int):
    """Grid: (M // block_m, D // block_d).

    perm_ref: (M,) scalar-prefetch; x_ref: (T, block_d) — all rows of x for
    the current feature tile; o_ref: (block_m, block_d).
    """
    m0 = pl.program_id(0) * block_m

    def body(i, _):
        row = perm_ref[m0 + i]
        safe = jnp.maximum(row, 0)
        data = x_ref[safe, :]
        o_ref[i, :] = jnp.where(row >= 0, data, jnp.zeros_like(data))
        return 0

    jax.lax.fori_loop(0, block_m, body, 0)


def moe_pack(x: jax.Array, perm: jax.Array, *, block_m: int = 128,
             block_d: int = 512, interpret: bool = False) -> jax.Array:
    """x: (T, D), perm: (M,) -> (M, D) packed rows (−1 ⇒ zeros)."""
    T, D = x.shape
    M = perm.shape[0]
    pm = (-M) % block_m
    pd = (-D) % LANE
    if pd:
        x = jnp.pad(x, ((0, 0), (0, pd)))
    if pm:
        perm = jnp.pad(perm, ((0, pm),), constant_values=-1)
    Dp, Mp = x.shape[1], perm.shape[0]
    bd = min(block_d, Dp)
    while Dp % bd:
        bd //= 2
    bm = min(block_m, Mp)

    grid = (Mp // bm, Dp // bd)
    out = pl.pallas_call(
        functools.partial(_pack_kernel, block_m=bm),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((T, bd), lambda i, j, perm: (0, j))],
            out_specs=pl.BlockSpec((bm, bd), lambda i, j, perm: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Dp), x.dtype),
        interpret=interpret,
    )(perm, x)
    return out[:M, :D]
