"""Pallas TPU kernel: flash attention (causal / sliding-window).

The §Perf analysis showed flash-block temporaries (scores, selects,
accumulator updates) dominating the memory term of every attention-heavy
combo when expressed as plain-XLA chunked attention — on TPU those tensors
belong in VMEM.  This kernel keeps the (block_q x block_k) score tile, the
running (m, l) statistics and the output accumulator in VMEM scratch; HBM
traffic is exactly q/k/v blocks in + output out.

Grid: (batch*kv_heads*q_groups, Sq/block_q, Sk/block_k), kv-block
innermost so the accumulator carries across the k dimension in scratch.
Causality and the optional static window skip fully-masked tiles via
@pl.when.  MXU-aligned tiles: block_q=block_k=128 minimum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  n_kb: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q0 = qi * block_q
    k0 = ki * block_k
    # tile-level skip: fully-masked tiles cost nothing
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k0 <= q0 + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k0 + block_k - 1 >= q0 - window + 1)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_kb - 1)
    def _emit():
        denom = jnp.maximum(l_sc[...], 1e-20)
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) (kv heads pre-broadcast).

    Returns (B, H, Sq, D).  ``window``: 0 => full; >0 => sliding window.
    Scale (1/sqrt(D)) is applied inside.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = D ** -0.5
    q = (q * scale).reshape(B * H, Sq, D)
    k = k.reshape(B * H, Sk, D)
    v = v.reshape(B * H, Sk, D)
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    n_kb = Sk // bk

    grid = (B * H, Sq // bq, n_kb)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq, block_k=bk,
                          causal=causal, window=window, n_kb=n_kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out.reshape(B, H, Sq, D)
