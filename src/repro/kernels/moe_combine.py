"""Pallas TPU kernel: MoE combine (weighted gather-reduce to token order).

The TPU-native analogue of the paper's §6 combine *receiver*: every token
gathers its top-k expert outputs from the packed receive buffer and reduces
them with the router gates.  Formulating combine as an inverse-permutation
gather (rather than a scatter-add) keeps it deterministic and atomics-free —
the same trick the paper uses by centralising routing info at dispatch so
combine needs a single contiguous scatter.

Accumulation is fp32 regardless of the payload dtype (the paper calls out
DeepEP's bf16 accumulation as an accuracy trade-off; we keep fp32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _combine_kernel(inv_ref, gates_ref, ye_ref, o_ref, *, block_t: int, top_k: int):
    """Grid: (T // block_t, D // block_d).

    inv_ref: (T*K,) int32 scalar-prefetch (row of ye for token t's k-th pick,
    -1 => dropped); gates_ref: (T*K,) fp32 scalar-prefetch; ye_ref:
    (M, block_d); o_ref: (block_t, block_d).
    """
    t0 = pl.program_id(0) * block_t

    def token(i, _):
        acc = jnp.zeros((o_ref.shape[1],), jnp.float32)

        def pick(j, acc):
            flat = (t0 + i) * top_k + j
            row = inv_ref[flat]
            g = gates_ref[flat]
            safe = jnp.maximum(row, 0)
            contrib = ye_ref[safe, :].astype(jnp.float32) * g
            return acc + jnp.where(row >= 0, contrib, 0.0)

        acc = jax.lax.fori_loop(0, top_k, pick, acc)
        o_ref[i, :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_t, token, 0)


def moe_combine(ye: jax.Array, inv: jax.Array, gates: jax.Array, *,
                block_t: int = 128, block_d: int = 512,
                interpret: bool = False) -> jax.Array:
    """ye: (M, D); inv, gates: (T, K) -> (T, D) fp32-accumulated combine."""
    M, D = ye.shape
    T, K = inv.shape
    pd = (-D) % LANE
    if pd:
        ye = jnp.pad(ye, ((0, 0), (0, pd)))
    Dp = ye.shape[1]
    bt = min(block_t, T)
    while T % bt:
        bt //= 2
    bd = min(block_d, Dp)
    while Dp % bd:
        bd //= 2

    grid = (T // bt, Dp // bd)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, block_t=bt, top_k=K),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((M, bd), lambda i, j, inv, g: (0, j))],
            out_specs=pl.BlockSpec((bt, bd), lambda i, j, inv, g: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, Dp), ye.dtype),
        interpret=interpret,
    )(inv.reshape(-1), gates.reshape(-1).astype(jnp.float32), ye)
    return out[:, :D]
