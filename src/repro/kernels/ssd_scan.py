"""Pallas TPU kernel: Mamba2 SSD intra-chunk block.

Compute hotspot of the SSM/hybrid architectures: for each (batch x chunk,
head) tile it forms the decay-masked score matrix (C B^T) ⊙ L on the MXU,
applies it to the dt-weighted inputs, and emits the chunk-final state
contribution — the block-diagonal half of the state-space-duality algorithm
(arXiv:2405.21060).  Chunk length and head width are chosen MXU-aligned
(cl=128, p=64|128, n=128 by default).

The inter-chunk recurrence stays a lax.scan outside the kernel (it is O(nc)
sequential and tiny).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xw_ref, cum_ref, b_ref, c_ref, y_ref, st_ref):
    """Blocks (one (batch*chunk, head) tile):
    xw: (cl, p); cum: (cl, 1); b, c: (cl, n); y: (cl, p); st: (p, n)."""
    cum = cum_ref[0, 0].astype(jnp.float32)                # (cl, 1)
    xw = xw_ref[0, 0].astype(jnp.float32)
    b = b_ref[0, 0].astype(jnp.float32)
    c = c_ref[0, 0].astype(jnp.float32)
    cl = cum.shape[0]

    seg = cum - cum.T                                       # (cl, cl) = cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cl, cl)
    y_ref[0, 0] = ((cb * L) @ xw).astype(y_ref.dtype)

    decay = jnp.exp(cum[-1:] - cum)                          # (cl, 1)
    bw = b * decay                                           # (cl, n)
    st = jax.lax.dot_general(xw, bw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (p, n)
    st_ref[0, 0] = st.astype(st_ref.dtype)


def ssd_intra_flat(xw: jax.Array, cum: jax.Array, Br: jax.Array, Cr: jax.Array,
                   *, interpret: bool = False):
    """Flat layout: xw (BC, H, cl, P); cum (BC, H, cl, 1); Br/Cr (BC, H, cl, N).
    Returns (y (BC,H,cl,P), states (BC,H,P,N)), both fp32."""
    BC, H, cl, P = xw.shape
    N = Br.shape[-1]
    grid = (BC, H)
    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, cl, P), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, cl, 1), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, cl, N), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, cl, N), lambda i, h: (i, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cl, P), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, H, cl, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xw, cum, Br, Cr)
    return y, st
