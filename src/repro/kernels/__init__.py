"""Pallas TPU kernels for the compute hotspots the paper optimises.

Each kernel ships with a pure-jnp oracle (``ref.py``) and a jit'd wrapper
(``ops.py``).  On CPU the kernels run in ``interpret=True`` mode.

Submodules are loaded lazily (PEP 562): the fabric-side host proxy imports
``repro.kernels.host`` (numpy-only) on its hot path and must not pay the
jax import that ``ops``/``ref`` drag in.
"""

import importlib

__all__ = ["ops", "ref", "host"]


def __getattr__(name):
    if name in __all__:
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
