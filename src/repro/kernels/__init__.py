"""Pallas TPU kernels for the compute hotspots the paper optimises.

Each kernel ships with a pure-jnp oracle (``ref.py``) and a jit'd wrapper
(``ops.py``).  On CPU the kernels run in ``interpret=True`` mode.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
