"""Host-proxy kernel entry points (numpy-first, Pallas when it pays).

The moekit host proxy runs on plain numpy byte buffers and must stay
importable — and fast to import — without dragging in jax: these wrappers
execute the numpy reference implementation unless jax is ALREADY loaded
with an accelerator backend, in which case they delegate to the Pallas
kernels in :mod:`repro.kernels.ops` (same math, fp32 accumulation).
"""

from __future__ import annotations

import sys

import numpy as np


def _accel_backend() -> bool:
    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.default_backend() != "cpu"
    except Exception:
        return False


def moe_pack_host(rows: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Row gather for the moekit receiver shuffle / combine re-pack.

    ``rows``: (M, B) byte rows; ``perm``: (P,) int row indices (-1 => zero
    row).  One fancy-index gather on CPU; the Pallas pack kernel on an
    accelerator backend.
    """
    perm = np.asarray(perm)
    if _accel_backend():
        from . import ops
        import jax.numpy as jnp
        return np.asarray(ops.moe_pack(jnp.asarray(rows),
                                       jnp.asarray(perm.astype(np.int32))))
    rows = np.asarray(rows)
    out = rows[np.maximum(perm, 0)]
    neg = perm < 0
    if neg.any():
        out[neg] = 0
    return out


def moe_combine_host(ye: np.ndarray, inv: np.ndarray,
                     gates: np.ndarray) -> np.ndarray:
    """Weighted combine (fp32 accumulation) for the moekit source half.

    ``ye``: (M, D) packed expert-output rows; ``inv``: (T, K) packed-row
    index per (token, slot), -1 => dropped; ``gates``: (T, K) weights.
    Slots accumulate in ascending ``k`` order — callers that pre-sort the
    slots by expert id get bit-identical fp32 sums to a dense
    ascending-expert oracle.
    """
    if _accel_backend():
        from . import ops
        import jax.numpy as jnp
        return np.asarray(ops.moe_combine(
            jnp.asarray(ye), jnp.asarray(np.asarray(inv, np.int32)),
            jnp.asarray(gates)))
    ye = np.asarray(ye)
    inv = np.asarray(inv)
    gates = np.asarray(gates, np.float32)
    T, K = inv.shape
    y = np.zeros((T, ye.shape[1]), np.float32)
    for k in range(K):
        idx = inv[:, k]
        rows = ye[np.maximum(idx, 0)].astype(np.float32)
        contrib = rows * gates[:, k:k + 1]
        y += np.where((idx >= 0)[:, None], contrib, 0.0)
    return y
