"""Pallas TPU kernel: paged KV copy (indirect page gather/scatter).

The TPU-native analogue of the paper's ``submit_paged_writes`` (§3.3): KV
pages selected by indirect indices are copied from a source pool layout to a
destination pool layout.  The page tables ride in scalar-prefetch (SMEM) and
drive the BlockSpec index maps directly, so each grid step DMAs one
(page x lane-tile) block HBM->VMEM->HBM with no gather flops at all — the
TPU equivalent of a zero-copy RDMA WRITE per page.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _copy_kernel(src_idx_ref, dst_idx_ref, src_ref, dst_ref, o_ref):
    o_ref[...] = src_ref[...]


def paged_copy(src: jax.Array, src_idx: jax.Array, dst: jax.Array,
               dst_idx: jax.Array, *, block_e: int = 2048,
               interpret: bool = False) -> jax.Array:
    """dst[dst_idx[i]] = src[src_idx[i]].

    src: (Ps, E); dst: (Pd, E); src_idx/dst_idx: (P,) int32.
    Returns the updated destination pool.  Pages not addressed by
    ``dst_idx`` keep their previous contents (input/output aliasing).
    """
    Ps, E = src.shape
    Pd, Ed = dst.shape
    if E != Ed:
        raise ValueError("src/dst page sizes differ")
    P = src_idx.shape[0]
    pe = (-E) % LANE
    if pe:
        src = jnp.pad(src, ((0, 0), (0, pe)))
        dst = jnp.pad(dst, ((0, 0), (0, pe)))
    Ep = src.shape[1]
    be = min(block_e, Ep)
    while Ep % be:
        be //= 2

    grid = (P, Ep // be)
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, be), lambda i, j, sidx, didx: (sidx[i], j)),
                pl.BlockSpec((1, be), lambda i, j, sidx, didx: (didx[i], j)),
            ],
            out_specs=pl.BlockSpec((1, be), lambda i, j, sidx, didx: (didx[i], j)),
        ),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_idx, dst_idx, src, dst)
    return out[:, :E] if pe else out
