"""DeepSeekMoE-16B: fine-grained MoE, 2 shared + 64 routed top-6.
[arXiv:2401.06066]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,             # dense FFN of the first (dense) layer, per the model card
    vocab=102400,
    n_routed=64,
    n_shared=2,
    top_k=6,
    d_ff_expert=1408,
    first_k_dense=1,
)
