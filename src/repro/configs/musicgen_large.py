"""MusicGen-large: decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

Backbone only (assignment carve-out): the EnCodec audio codec is a stub —
``input_specs()`` supplies token ids of the codec vocabulary directly.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
)
