"""Granite-8B (code): llama-architecture dense GQA decoder. [arXiv:2405.04324]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
)
