"""Architecture configuration schema.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG`` built from :class:`ModelConfig`.  ``reduced()`` derives the
CPU-smoke-test variant (<=2 layers, d_model<=512, <=4 experts) of the same
family, as required by the assignment.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ---------------------------------------------------------
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    source: str                     # citation from the assignment table

    # -- transformer backbone ----------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int = 0                # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    d_ff: int = 0                   # dense FFN width (0 => no FFN, e.g. mamba)
    vocab: int = 0
    d_head: int = 0                 # 0 => d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True

    # -- MoE ----------------------------------------------------------------
    n_routed: int = 0               # routed experts (0 => dense FFN)
    n_shared: int = 0               # always-on shared experts
    top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0          # leading dense layers (DeepSeekMoE)
    router_aux_coef: float = 0.01   # load-balance loss coefficient

    # -- SSM (Mamba2 / SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_dconv: int = 4
    ssm_chunk: int = 128

    # -- hybrid (Zamba2-style) ---------------------------------------------
    attn_every: int = 0             # shared attention block after every N ssm layers

    # -- sliding-window pattern (Gemma3-style) ---------------------------------
    window: int = 0                 # local window size (0 => full attention)
    global_every: int = 0           # 1 global layer per N (5:1 => 6)

    # -- cross-attention (VLM) -------------------------------------------------
    cross_every: int = 0            # 1 cross-attn layer per N
    vision_seq: int = 0             # stub patch-embedding sequence length
    vision_dim: int = 0             # stub patch-embedding feature size

    # -- numerics ---------------------------------------------------------------
    param_dtype: str = "float32"

    # ---------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_routed > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this config serve 500k-token contexts?  (assignment rule)"""
        if self.family in ("ssm", "hybrid"):
            return True
        # Dense archs qualify only with a sliding-window/local variant.
        return self.window > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: attn | local | global | cross | mamba."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                kinds.append("mamba")
            elif self.family == "hybrid":
                kinds.append("mamba")  # shared attn handled separately
            elif self.cross_every and (i % self.cross_every == self.cross_every - 1):
                kinds.append("cross")
            elif self.global_every:
                kinds.append("global" if i % self.global_every == self.global_every - 1
                             else "local")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        kinds = []
        for i in range(self.n_layers):
            if self.d_ff == 0 and not self.is_moe:
                kinds.append("none")
            elif self.is_moe and i >= self.first_k_dense:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family (assignment: <=2 layers,
        d_model<=512, <=4 experts)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if n_heads else 0
        changes = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=(d_model // n_heads if n_heads else 0),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_routed=min(self.n_routed, 4),
            n_shared=min(self.n_shared, 1),
            top_k=min(self.top_k, 2),
            d_ff_expert=min(self.d_ff_expert, 128) if self.d_ff_expert else 0,
            first_k_dense=min(self.first_k_dense, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            attn_every=2 if self.attn_every else 0,
            window=min(self.window, 16) if self.window else 0,
            global_every=2 if self.global_every else 0,
            cross_every=2 if self.cross_every else 0,
            vision_seq=min(self.vision_seq, 16) if self.vision_seq else 0,
            vision_dim=min(self.vision_dim, 64) if self.vision_dim else 0,
        )
        return dataclasses.replace(self, **changes)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        total = V * D * (1 if self.tie_embeddings else 2)
        Hd = self.head_dim
        attn = D * self.n_heads * Hd + 2 * D * self.n_kv_heads * Hd + self.n_heads * Hd * D
        for kind, ffn in zip(self.layer_kinds(), self.ffn_kinds()):
            if kind in ("attn", "local", "global", "cross"):
                total += attn
            elif kind == "mamba":
                di, g, N = self.d_inner, self.ssm_ngroups, self.ssm_state
                H = self.ssm_nheads
                total += D * (2 * di + 2 * g * N + H) + di * D + (self.ssm_dconv) * (di + 2 * g * N)
            if ffn == "dense":
                total += 3 * D * F
            elif ffn == "moe":
                total += self.n_routed * 3 * D * self.d_ff_expert
                total += self.n_shared * 3 * D * self.d_ff_expert
                total += D * self.n_routed
        if self.family == "hybrid":
            total += attn + 3 * D * self.d_ff  # one shared attention block
        if self.family == "vlm":
            total += self.vision_dim * D       # patch-embedding projector
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top_k + shared instead of all)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        n_moe_layers = sum(1 for f in self.ffn_kinds() if f == "moe")
        all_routed = n_moe_layers * self.n_routed * 3 * self.d_model * self.d_ff_expert
        act_routed = n_moe_layers * self.top_k * 3 * self.d_model * self.d_ff_expert
        return total - all_routed + act_routed
