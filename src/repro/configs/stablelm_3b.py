"""StableLM-3B: dense decoder, MHA-style kv=32. [hf:stabilityai/stablelm-2-1_6b]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
)
