"""Llama-3.2-Vision-90B: text decoder with interleaved cross-attention image
layers. [hf:meta-llama/Llama-3.2-11B-Vision]

Backbone only (assignment carve-out): the ViT vision encoder is a stub —
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, vision_seq, vision_dim); a learned projector maps them to d_model.
Pattern: every 5th layer is cross-attention (20 of 100).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    cross_every=5,
    vision_seq=1601,         # 1 tile x (40x40 patches + cls), ViT-H/14 @ 560px
    vision_dim=1280,
)
