"""Assigned input shapes and per-arch input specs (ShapeDtypeStructs).

``input_specs(cfg, shape_name)`` returns stand-ins for every model input —
weak-type-correct, shardable, no device allocation — used by the multi-pod
dry-run.  Decode shapes describe ``serve_step`` inputs (ONE new token plus a
KV/state cache of ``seq_len``), not ``train_step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Assignment rule: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def token_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the data-batch inputs of a step."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode: one new token per sequence
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    if cfg.family == "vlm":
        # Stub modality frontend (assignment carve-out): precomputed patch
        # embeddings replace the ViT encoder.
        specs["vision_emb"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq, cfg.vision_dim), dtype)
    return specs
