"""Zamba2-1.2B: Mamba2 backbone + shared attention block. [arXiv:2411.15242]

Hybrid: 38 Mamba2 layers with ONE shared attention(+FFN) block applied after
every ``attn_every`` SSM layers (parameters reused at each application, as in
Zamba2).  long_500k adaptation (DESIGN.md §4): the shared attention block
uses a sliding window at 500k contexts; Zamba2 proper uses full attention,
which is quadratic and excluded by the assignment's long-context rule.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    attn_every=6,
    window=4096,            # shared-attn sliding window (500k adaptation)
)
