"""Gemma3-1B: 5:1 local:global attention, 128k-class context.
[hf:google/gemma-3-1b-pt]

The 5:1 sliding-window pattern makes this the one *dense* arch that runs
long_500k (assignment rule): local layers attend within a 1024-token window;
global layers use a sequence-sharded KV cache at 500k.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262144,
    window=1024,
    global_every=6,          # 5 local : 1 global
    rope_theta=1_000_000.0,
)
