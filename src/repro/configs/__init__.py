"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""

from typing import Dict, List

from .base import ModelConfig
from .shapes import INPUT_SHAPES, InputShape, shape_applicable, token_specs

from . import (deepseek_moe_16b, gemma3_1b, granite_3_8b, granite_8b,
               llama32_vision_90b, mamba2_780m, musicgen_large,
               qwen3_moe_30b_a3b, stablelm_3b, zamba2_1_2b)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (deepseek_moe_16b, granite_3_8b, mamba2_780m, musicgen_large,
              qwen3_moe_30b_a3b, zamba2_1_2b, granite_8b, gemma3_1b,
              llama32_vision_90b, stablelm_3b)
}

ARCH_IDS: List[str] = sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch}'; available: {ARCH_IDS}")
    return _REGISTRY[arch]


__all__ = ["ModelConfig", "get_config", "ARCH_IDS", "INPUT_SHAPES",
           "InputShape", "shape_applicable", "token_specs"]
