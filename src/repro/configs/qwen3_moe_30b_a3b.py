"""Qwen3-30B-A3B: 128-expert top-8 MoE, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=6144,              # unused (no dense layers); kept for reference
    vocab=151936,
    n_routed=128,
    n_shared=0,
    top_k=8,
    d_ff_expert=768,
    first_k_dense=0,
)
