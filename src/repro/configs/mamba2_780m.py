"""Mamba2-780m: attention-free SSD (state-space duality). [arXiv:2405.21060]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_dconv=4,
)
