"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices; smoke tests and benchmarks see the
real single CPU device.
"""

from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))
