"""Serving launcher: batched prefill + decode for any assigned arch.

Two modes:
  * monolithic  — sharded prefill_step + decode_step on the local mesh
  * disagg      — the §4 disaggregated path over the simulated fabric
                  (prefillers + decoders + scheduler), verified against the
                  monolithic generation.  Works for EVERY arch family:
                  ``repro.kvlayout`` derives the cache schema (uniform,
                  pattern-split, SSM/hybrid, first-k-dense) and compiles
                  the transfer plan.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --requests 4 --prompt-len 48 --decode 8 [--disagg]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..configs.shapes import InputShape
from ..models import decode_step, init_params, prefill
from .mesh import make_local_mesh


def monolithic(cfg, params, prompts, n_decode: int, vision_emb=None):
    ve = None if vision_emb is None else jnp.asarray(vision_emb)[None]
    outs = []
    for ids in prompts:
        lg, cache = prefill(params, jnp.asarray(ids)[None], cfg,
                            max_len=len(ids) + n_decode + 8, moe_mode="dense",
                            vision_emb=ve)
        toks = [int(jnp.argmax(lg[0, :cfg.vocab]))]
        pos = len(ids)
        for _ in range(n_decode - 1):
            lg, cache = decode_step(params, jnp.asarray([[toks[-1]]]),
                                    jnp.asarray([pos], jnp.int32), cache, cfg,
                                    moe_mode="dense")
            toks.append(int(jnp.argmax(lg[0, :cfg.vocab])))
            pos += 1
        outs.append(toks)
    return outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode", type=int, default=8)
    ap.add_argument("--disagg", action="store_true")
    ap.add_argument("--nic", default="efa", choices=["efa", "efa4", "cx7"])
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len)
               for _ in range(args.requests)]
    # vlm archs need patch embeddings; the launcher synthesises one image
    # shared by all requests (both paths use the same one, so parity holds)
    vision_emb = (rng.normal(size=(cfg.vision_seq, cfg.vision_dim))
                  .astype(np.float32) if cfg.family == "vlm" else None)

    t0 = time.time()
    mono = monolithic(cfg, params, prompts, args.decode, vision_emb)
    print(f"monolithic: {args.requests} requests x {args.decode} tokens "
          f"in {time.time() - t0:.1f}s")

    if args.disagg:
        from ..serving import disagg_unsupported_reason
        reason = disagg_unsupported_reason(cfg)
        if reason:  # retired guard: no current family triggers it
            print(f"disagg path cannot serve '{args.arch}': {reason}")
            return
        from ..core import Fabric
        from ..ctrl import ControlPlane
        from ..serving import Decoder, Prefiller, Scheduler
        fab = Fabric(seed=1)
        ctrl = ControlPlane(fab, nic=args.nic)
        pf = [Prefiller(fab, f"p{i}", cfg, params, nic=args.nic, ctrl=ctrl)
              for i in range(2)]
        dec = [Decoder(fab, f"d{i}", cfg, params, nic=args.nic, ctrl=ctrl)
               for i in range(2)]
        sched = Scheduler(fab, ctrl)
        rids = [sched.submit(ids, n_decode=args.decode,
                             vision_emb=vision_emb) for ids in prompts]
        fab.run()
        sched.check_drained()
        ok = 0
        for rid, ref in zip(rids, mono):
            r = sched.completed[rid]
            ok += r["tokens"] == ref
            print(f"req {rid}: TTFT {r['ttft_us']:8.1f}us  "
                  f"p={r['prefiller']} d={r['decoder']}  "
                  f"match={r['tokens'] == ref}")
        print(f"disaggregated == monolithic for {ok}/{len(rids)} requests "
              f"(membership epoch {sched.view.epoch})")
        assert ok == len(rids)

    for i, toks in enumerate(mono[:2]):
        print(f"sample {i}: {toks}")


if __name__ == "__main__":
    main()
