"""Jitted step builders: train_step / prefill_step / decode_step per arch.

Each builder returns (fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` — used both by the real trainer/server and by
the multi-pod dry-run (with ShapeDtypeStruct stand-ins).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.shapes import InputShape
from ..models import model as M
from ..models import sharding as S
from ..optim import AdamWConfig, adamw_update, init_adamw
from ..comm.context import use_mesh


def _ns(mesh, tree):
    return S.named(mesh, tree)


def build_train_step(cfg, mesh, shape: InputShape, *,
                     moe_mode: str = "a2a", use_kernel: bool = False,
                     remat: bool = True, opt_cfg: Optional[AdamWConfig] = None):
    """Returns (jitted_fn, (param_shd, opt_shd, batch_shd)).

    fn(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    opt_cfg = opt_cfg or AdamWConfig()
    pspec = S.param_spec_tree(cfg, mesh)
    ospec = S.opt_spec_tree(cfg, mesh)
    bspec = S.batch_spec_tree(cfg, mesh, shape)

    def step(params, opt_state, batch):
        with use_mesh(mesh):
            def loss(p):
                return M.loss_fn(p, batch, cfg, moe_mode=moe_mode,
                                 use_kernel=use_kernel, remat=remat)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            params2, opt2, om = adamw_update(grads, opt_state, params, opt_cfg)
            metrics = dict(metrics, loss=l, **om)
            return params2, opt2, metrics

    shardings = (_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec))
    fn = jax.jit(step, in_shardings=shardings,
                 out_shardings=(shardings[0], shardings[1], None),
                 donate_argnums=(0, 1))
    return fn, shardings


def build_prefill_step(cfg, mesh, shape: InputShape, *,
                       moe_mode: str = "a2a", use_kernel: bool = False):
    """fn(params, batch) -> (last_logits, cache)"""
    pspec = S.param_spec_tree(cfg, mesh)
    bspec = S.batch_spec_tree(cfg, mesh, shape)
    cspec = S.cache_spec_tree(cfg, mesh, shape.global_batch, shape.seq_len)

    def step(params, batch):
        with use_mesh(mesh):
            return M.prefill(params, batch["tokens"], cfg,
                             max_len=shape.seq_len,
                             vision_emb=batch.get("vision_emb"),
                             moe_mode=moe_mode, use_kernel=use_kernel)

    shardings = (_ns(mesh, pspec), _ns(mesh, bspec))
    fn = jax.jit(step, in_shardings=shardings,
                 out_shardings=(None, _ns(mesh, cspec)))
    return fn, shardings + (_ns(mesh, cspec),)


def build_decode_step(cfg, mesh, shape: InputShape, *, moe_mode: str = "a2a"):
    """fn(params, cache, tokens, positions) -> (logits, cache).

    ONE new token per sequence against a cache of shape.seq_len (the
    assignment's serve_step for decode_32k / long_500k).
    """
    pspec = S.param_spec_tree(cfg, mesh)
    cspec = S.cache_spec_tree(cfg, mesh, shape.global_batch, shape.seq_len)
    bspec = S.batch_spec_tree(cfg, mesh, shape)
    tok_spec = bspec["tokens"]
    pos_spec = P(tok_spec[0])

    def step(params, cache, tokens, positions):
        with use_mesh(mesh):
            return M.decode_step(params, tokens, positions, cache, cfg,
                                 moe_mode=moe_mode)

    cs = _ns(mesh, cspec)
    shardings = (_ns(mesh, pspec), cs,
                 NamedSharding(mesh, tok_spec), NamedSharding(mesh, pos_spec))
    fn = jax.jit(step, in_shardings=shardings, out_shardings=(None, cs),
                 donate_argnums=(1,))
    return fn, shardings
