import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production meshes; every step function is lowered from
ShapeDtypeStructs (no allocation), compiled, and its memory/cost analysis +
roofline terms are recorded.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--moe-mode a2a]
"""

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_applicable, token_specs
from ..models import model as M
from ..optim import init_adamw
from ..roofline import analyse
from . import steps as St
from .mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "out" / "dryrun"


def input_specs(cfg, shape, mesh, kind: str):
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    bf_cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    params = jax.eval_shape(lambda k: M.init_params(bf_cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch = token_specs(bf_cfg, shape)
    if kind == "train":
        opt = jax.eval_shape(init_adamw, params)
        return bf_cfg, (params, opt, batch)
    if kind == "prefill":
        return bf_cfg, (params, batch)
    cache = jax.eval_shape(
        lambda: M.init_cache(bf_cfg, shape.global_batch, shape.seq_len))
    tokens = batch["tokens"]
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return bf_cfg, (params, cache, tokens, pos)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            moe_mode: str = "a2a", verbose: bool = True,
            variant: str = "") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = mesh.devices.size

    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip",
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md §4)"}

    t0 = time.time()
    bf_cfg, specs = input_specs(cfg, shape, mesh, shape.kind)
    if shape.kind == "train":
        fn, _ = St.build_train_step(bf_cfg, mesh, shape, moe_mode=moe_mode)
    elif shape.kind == "prefill":
        fn, _ = St.build_prefill_step(bf_cfg, mesh, shape, moe_mode=moe_mode)
    else:
        fn, _ = St.build_decode_step(bf_cfg, mesh, shape, moe_mode=moe_mode)

    lowered = fn.lower(*specs)
    compiled = lowered.compile()
    rl = analyse(compiled, bf_cfg, shape, arch, mesh_name, n_chips)
    dt = time.time() - t0

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "moe_mode": moe_mode, "compile_s": round(dt, 1),
           **rl.to_dict()}
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception:
        pass
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}{variant}] OK "
              f"compile={dt:.0f}s flops/dev={rl.flops:.3g} "
              f"bytes/dev={rl.bytes_accessed:.3g} coll={rl.coll_bytes:.3g} "
              f"dominant={rl.dominant} useful={rl.useful_flops_ratio:.2f}")
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape_name}_{mesh_name}" + (f"_{variant}" if variant else "")
    (OUT_DIR / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-mode", default="a2a",
                    choices=["a2a", "scatter", "dense"])
    ap.add_argument("--variant", default="", help="tag for output file")
    args = ap.parse_args()

    combos = ([(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    failures = []
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          moe_mode=args.moe_mode, variant=args.variant)
            if rec["status"] == "skip":
                print(f"[{arch} x {shape}] SKIP: {rec['reason']}")
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"[{arch} x {shape}] FAIL: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures"); return 1
    print("\nDry-run complete: all combinations lowered and compiled.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
