"""Distributed training launcher.

Builds the sharded train step for an (arch, shape) pair on a mesh sized to
the available devices, feeds it from the deterministic data pipeline, and
logs/checkpoints.  On this CPU container it runs reduced configs on a 1x1
mesh; on a real slice the same entrypoint runs the full configs on the
production mesh (--production).

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 20 --seq-len 128 --global-batch 4 [--reduced]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..checkpoint import ckpt
from ..configs import ARCH_IDS, INPUT_SHAPES, get_config
from ..configs.shapes import InputShape
from ..data import Batcher, SyntheticCorpus
from ..models import init_params
from ..optim import init_adamw
from . import steps as St
from .mesh import make_local_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production", action="store_true",
                    help="use the 16x16 production mesh (needs 256 devices)")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--moe-mode", default="scatter",
                    choices=["dense", "scatter", "a2a"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production
            else make_local_mesh(args.data, args.model))
    shape = InputShape("cli", args.seq_len, args.global_batch, "train")
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"mesh={dict(mesh.shape)} moe_mode={args.moe_mode}")

    step_fn, (p_shd, o_shd, b_shd) = St.build_train_step(
        cfg, mesh, shape, moe_mode=args.moe_mode)
    params = jax.device_put(init_params(cfg, jax.random.PRNGKey(0)), p_shd)
    opt = jax.device_put(init_adamw(params), o_shd)
    batcher = Batcher(SyntheticCorpus(cfg.vocab, seed=0),
                      args.global_batch, args.seq_len)

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batcher.batch(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"{time.time() - t0:.0f}s")
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params}, step=args.steps,
                  meta={"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
