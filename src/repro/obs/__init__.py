"""repro.obs: zero-cost-when-off tracing, metrics and leak auditing.

Attach a :class:`Tracer` to a fabric (``Tracer(fabric)``; existing and
future engines are wired either way) to record per-WR lifecycle spans, ctrl-plane instants, gauges and
tagged observation windows, all in virtual time; export with
:func:`export_chrome_trace` (Perfetto) and :meth:`Tracer.finalize` (flat
metrics dict for ``BENCH_*.json``).  With no tracer attached every hook in
the fabric hot path is a single guarded attribute check.
"""

from .audit import assert_clean, format_audit
from .export import build_trace_events, export_chrome_trace
from .health import HealthMonitor, PairHealth
from .metrics import Histogram, MetricRegistry, rank_percentile
from .recorder import FlightRecorder
from .tracer import Tracer, Window, WrSpan, traced_phase, traced_window

__all__ = [
    "Tracer", "WrSpan", "Window", "traced_phase", "traced_window",
    "Histogram", "MetricRegistry", "rank_percentile",
    "HealthMonitor", "PairHealth", "FlightRecorder",
    "build_trace_events", "export_chrome_trace",
    "assert_clean", "format_audit",
]
