"""Streaming fabric health monitor: always-on, O(1), closed-loop-ready.

The PR-7 :class:`~repro.obs.tracer.Tracer` is a *post-hoc* recorder: it
retains every :class:`~repro.obs.tracer.WrSpan` and attributes stalls after
the run.  Production fabrics need the opposite trade — **always-on** live
signals with bounded memory.  :class:`HealthMonitor` consumes the exact
same hook points (span creation at submit, ``_on_post`` at the worker
posting slot, the delivery continuation) but keeps only O(channels)
incremental state: per-(src, dst) rolling-window stats — delivery latency,
NIC queue backlog, live enqueue/post/wire stall attribution — plus a
**deviation detector** that compares each window's observed wire time
against the ``Fabric.pair_spec`` cost-model prediction and flags channels
whose ratio stays above threshold for consecutive windows (degraded NIC,
injected congestion, cross-fabric misconfiguration).

Two hard invariants, shared with the tracer and pinned by the determinism
tests:

* the monitor never schedules events, never draws RNG, and never perturbs
  iteration order — an always-on-monitored run is **bit-identical** to an
  unmonitored one;
* every hook on the fabric hot path stays a single guarded attribute
  check (``if fab.health is not None``) when no monitor is attached.

Deviation model: for a WR of ``n`` bytes on pair (src, dst) with spec
``s = fabric.pair_spec(src, dst)``, the wire segment (``t_deliver -
t_wire`` — NIC queue wait excluded, so attribution stays per-pair even on
shared NIC queues) is bounded on a clean fabric by::

    expected = s.service_us(n) + s.base_latency_us + s.srd_jitter_us

A window's deviation ratio is ``sum(observed) / sum(expected)``; clean
channels sit at or below 1.0 by construction, so the default threshold
(1.5x for 2 consecutive windows) never false-positives on the golden
benches — a property the bench-smoke CI job asserts on every run.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .tracer import WrSpan


class PairHealth:
    """Incremental health state for one (src, dst) channel pair.

    Cumulative segment sums (``enqueue_us``/``post_us``/``wire_us``/
    ``total_us``) mirror the post-hoc trace attribution exactly — the
    ``--live-parity`` report checks them against recomputed span sums.
    Window state is O(1): sums reset every ``window_wrs`` deliveries.
    """

    __slots__ = ("src", "dst", "n", "nbytes", "enqueue_us", "post_us",
                 "wire_us", "total_us", "expected_wire_us", "backlog_max_us",
                 "w_n", "w_obs_us", "w_exp_us", "windows", "bad_windows",
                 "flagged", "last_ratio")

    def __init__(self, src: str, dst: str):
        self.src = src
        self.dst = dst
        # cumulative (whole run)
        self.n = 0
        self.nbytes = 0
        self.enqueue_us = 0.0
        self.post_us = 0.0
        self.wire_us = 0.0
        self.total_us = 0.0
        self.expected_wire_us = 0.0
        self.backlog_max_us = 0.0
        # current rolling window
        self.w_n = 0
        self.w_obs_us = 0.0
        self.w_exp_us = 0.0
        # detector state
        self.windows = 0           # closed windows so far
        self.bad_windows = 0       # consecutive over-threshold windows
        self.flagged = False
        self.last_ratio = 0.0      # deviation ratio of the last closed window

    def as_dict(self) -> Dict[str, float]:
        """Flat summary row for this pair (bench JSON / trace embedding)."""
        return {"src": self.src, "dst": self.dst, "n": self.n,
                "nbytes": self.nbytes, "enqueue_us": self.enqueue_us,
                "post_us": self.post_us, "wire_us": self.wire_us,
                "total_us": self.total_us,
                "expected_wire_us": self.expected_wire_us,
                "backlog_max_us": self.backlog_max_us,
                "windows": self.windows, "last_ratio": self.last_ratio,
                "flagged": self.flagged}


class HealthMonitor:
    """Always-on streaming health monitor, attached via ``HealthMonitor(fabric)``.

    Existing and future engines are wired either way (mirroring the tracer's
    attach contract).  The monitor is pure synchronous bookkeeping inside
    already-executing continuations: per-WR it does a handful of float adds
    on the pair's :class:`PairHealth` record.  Detection knobs:

    * ``window_wrs`` — deliveries per detector window (per pair);
    * ``deviation_ratio`` — observed/expected wire-time ratio above which a
      window counts as bad;
    * ``k_windows`` — consecutive bad windows before the pair is flagged.

    A flag fires once per pair (re-arm via :meth:`reset_flags`): it appends
    to :attr:`flags`, emits a ``health`` ctrl-plane instant when a tracer is
    attached, and notes + dumps the flight recorder when one is attached.
    """

    def __init__(self, fabric, *, window_wrs: int = 64,
                 deviation_ratio: float = 1.5, k_windows: int = 2):
        self.fabric = fabric
        self.loop = fabric.loop
        self.window_wrs = int(window_wrs)
        self.deviation_ratio = float(deviation_ratio)
        self.k_windows = int(k_windows)
        self.pairs: Dict[Tuple[str, str], PairHealth] = {}
        self.flags: List[dict] = []
        # fault-plan event counters (repro.core.faults): kind -> count,
        # bumped via on_fault from the plan's retry/exhaust/kill paths
        self.fault_counts: Dict[str, int] = {}
        # enqueue-side counters (bumped per WrBatch handoff, same ground
        # truth as BatchStats / Tracer.n_*), keyed by submitting engine
        self.n_wrs = 0
        self.n_batches = 0
        self.n_bytes = 0
        self.by_src: Dict[str, List[float]] = {}   # src -> [wrs, batches, bytes]
        self._spec_cache: Dict[Tuple[str, str], object] = {}
        fabric.attach_health(self)

    # -- hot-path hooks ----------------------------------------------------
    def begin_wr(self, kind: str, dst, nbytes: int, imm: Optional[int],
                 src: str = "") -> WrSpan:
        """Open an **unretained** lifecycle span for one WR.

        Used by the engine when a monitor is attached but no tracer is —
        the span travels on the WireOp, gets stamped by the usual hooks,
        and is consumed (not kept) by :meth:`on_deliver`."""
        return WrSpan(0, kind, "", str(dst), nbytes, imm, self.loop.now,
                      src=src)

    def on_enqueue(self, src: str, wrs: int, nbytes: int) -> None:
        """One WrBatch handed to the worker: bump the enqueue counters."""
        self.n_batches += 1
        self.n_wrs += wrs
        self.n_bytes += nbytes
        row = self.by_src.get(src)
        if row is None:
            row = self.by_src[src] = [0.0, 0.0, 0.0]
        row[0] += wrs
        row[1] += 1
        row[2] += nbytes

    def _on_post(self, op, ch, group, extra_post_us: float) -> None:
        """Worker-posting hook (same signature/call site as the tracer's):
        stamp the span's posting slot if no tracer already did, and fold
        the NIC queue backlog into the pair's gauge."""
        sp = op.span
        if sp is None:
            return
        if sp.t_enqueue is None:
            sp.t_enqueue = self.loop.now
        if sp.t_post is None:
            sp.t_post = group._post_busy_until
            sp.t_post0 = sp.t_post - group.post_us - extra_post_us
            sp.track = ch.label
        ph = self._pair(sp.src, sp.dst)
        b = ch.nic.backlog_us(self.loop.now)
        if b > ph.backlog_max_us:
            ph.backlog_max_us = b

    def on_deliver(self, sp) -> None:
        """Delivery hook: fold one completed span into the pair's rolling
        stats and run the deviation detector (the span is NOT retained)."""
        ph = self._pair(sp.src, sp.dst)
        ph.n += 1
        ph.nbytes += sp.nbytes
        ph.total_us += sp.t_deliver - sp.t_submit
        if sp.t_enqueue is not None:
            ph.enqueue_us += sp.t_enqueue - sp.t_submit
            if sp.t_wire is not None:
                ph.post_us += sp.t_wire - sp.t_enqueue
        rec = getattr(self.fabric, "recorder", None)
        if rec is not None:
            rec.record(sp.kind, f"{sp.src}>{sp.dst}", sp.nbytes,
                       sp.t_deliver - sp.t_submit)
        if sp.t_wire is None:
            return
        obs = sp.t_deliver - sp.t_wire
        exp = self._expected_wire_us(sp.src, sp.dst, sp.nbytes)
        ph.wire_us += obs
        ph.expected_wire_us += exp
        ph.w_n += 1
        ph.w_obs_us += obs
        ph.w_exp_us += exp
        if ph.w_n >= self.window_wrs:
            self._close_window(ph)

    # -- detector ----------------------------------------------------------
    def _close_window(self, ph: PairHealth) -> None:
        ratio = ph.w_obs_us / ph.w_exp_us if ph.w_exp_us > 0.0 else 0.0
        ph.last_ratio = ratio
        ph.windows += 1
        ph.w_n = 0
        ph.w_obs_us = 0.0
        ph.w_exp_us = 0.0
        if ratio > self.deviation_ratio:
            ph.bad_windows += 1
            if ph.bad_windows >= self.k_windows and not ph.flagged:
                self._flag(ph, ratio)
        else:
            ph.bad_windows = 0

    def _flag(self, ph: PairHealth, ratio: float) -> None:
        ph.flagged = True
        flag = {"t": self.loop.now, "src": ph.src, "dst": ph.dst,
                "ratio": ratio, "window": ph.windows,
                "backlog_max_us": ph.backlog_max_us}
        self.flags.append(flag)
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("health", f"degraded:{ph.src}>{ph.dst}",
                       {"ratio": ratio, "window": ph.windows})
        rec = getattr(self.fabric, "recorder", None)
        if rec is not None:
            if tr is None:
                # tracer.instant above already mirrored into the recorder
                rec.note("health", f"degraded:{ph.src}>{ph.dst}",
                         {"ratio": ratio, "window": ph.windows})
            rec.dump("health-flag")

    def on_fault(self, kind: str) -> None:
        """Fault-plan hook: count one transport fault event by kind
        (``drop`` / ``completion-error`` / ``retry`` / ``exhausted`` /
        ``send_blackholed`` ...).  Plain dict bump — never perturbs time."""
        self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1

    def reset_flags(self) -> None:
        """Re-arm the detector: clear flags and per-pair flagged state."""
        self.flags.clear()
        for ph in self.pairs.values():
            ph.flagged = False
            ph.bad_windows = 0

    # -- model lookup ------------------------------------------------------
    def _pair(self, src: str, dst: str) -> PairHealth:
        key = (src, dst)
        ph = self.pairs.get(key)
        if ph is None:
            ph = self.pairs[key] = PairHealth(src, dst)
        return ph

    def _expected_wire_us(self, src: str, dst: str, nbytes: int) -> float:
        spec = self._spec_cache.get((src, dst))
        if spec is None:
            try:
                spec = self.fabric.pair_spec(src, dst)
            except KeyError:
                return float("inf")     # unknown pair: never flag it
            self._spec_cache[(src, dst)] = spec
        return (spec.service_us(nbytes) + spec.base_latency_us
                + spec.srd_jitter_us)

    # -- aggregation -------------------------------------------------------
    def src_stats(self, src: str) -> Dict[str, float]:
        """Aggregate delivered-WR stats for one submitting engine — the
        online chunk tuner's feed: per-WR post overhead and per-byte wire
        cost measured from live traffic (``None``-free; zeros when the
        engine has no delivered WRs yet)."""
        n = 0
        nbytes = 0
        post = wire = enq = 0.0
        for (s, _), ph in self.pairs.items():
            if s != src:
                continue
            n += ph.n
            nbytes += ph.nbytes
            post += ph.post_us
            wire += ph.wire_us
            enq += ph.enqueue_us
        row = self.by_src.get(src, (0.0, 0.0, 0.0))
        return {"n": n, "nbytes": nbytes, "enqueue_us": enq,
                "post_us": post, "wire_us": wire,
                "wrs": row[0], "batches": row[1],
                "post_enqueue_ratio": row[0] / row[1] if row[1] else 0.0}

    def summary(self) -> dict:
        """Whole-monitor summary: global attribution sums + per-pair rows +
        flags, all plain scalars/lists (JSON-ready)."""
        enq = post = wire = 0.0
        for ph in self.pairs.values():
            enq += ph.enqueue_us
            post += ph.post_us
            wire += ph.wire_us
        return {
            "wrs": self.n_wrs, "batches": self.n_batches,
            "nbytes": self.n_bytes,
            "post_enqueue_ratio": (self.n_wrs / self.n_batches
                                   if self.n_batches else 0.0),
            "enqueue_us": enq, "post_us": post, "wire_us": wire,
            "pairs": {f"{s}>{d}": ph.as_dict()
                      for (s, d), ph in sorted(self.pairs.items())},
            "flags": list(self.flags),
            "faults": dict(sorted(self.fault_counts.items())),
        }
