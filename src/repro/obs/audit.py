"""Leak-audit helpers over ``Fabric.audit()`` (test-teardown wiring).

``Fabric.audit()`` reports, at loop-idle: logical WRITEs/SENDs still in
flight, per-engine unfulfilled ImmCounter expectations and queued-but-
undeliverable SENDs, and leaks from registered auditables (e.g. rlweights
staging reservations that were never released).  These helpers format that
report and turn it into a hard assertion for test teardown.
"""

from __future__ import annotations

from typing import List


def format_audit(report: dict) -> str:
    """Human-readable rendering of a ``Fabric.audit()`` report."""
    lines: List[str] = [
        f"fabric audit: clean={report['clean']} "
        f"(inflight_writes={report['inflight_writes']}, "
        f"inflight_sends={report['inflight_sends']}, "
        f"pending_events={report['pending_events']})"]
    for node, rep in report.get("engines", {}).items():
        for key, val in rep.items():
            lines.append(f"  engine {node}: {key} = {val}")
    for name, rep in report.get("auditables", {}).items():
        lines.append(f"  auditable {name}: {rep}")
    return "\n".join(lines)


def assert_clean(fabric, allow_pending_sends: bool = False) -> dict:
    """Assert the fabric has no leaked in-flight state at loop-idle.

    ``allow_pending_sends=True`` tolerates SENDs parked for RECVs that
    were never posted (RNR-queued) — some control-plane shutdown paths
    legitimately leave these.  Returns the audit report on success."""
    report = fabric.audit()
    if report["clean"]:
        return report
    if allow_pending_sends:
        dirty = (report["inflight_writes"] or report["inflight_sends"]
                 or report["auditables"]
                 or any(k for rep in report["engines"].values() for k in rep
                        if not k.startswith("pending_sends")))
        if not dirty:
            return report
    rec = getattr(fabric, "recorder", None)
    if rec is not None:
        # post-mortem forensics: persist the flight ring before failing
        rec.note("audit", "failure", {"report": format_audit(report)})
        rec.dump("audit-failure")
    raise AssertionError(format_audit(report))
