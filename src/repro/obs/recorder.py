"""Flight recorder: an always-on bounded ring of compact fabric events.

Full tracing (``BENCH_TRACE=1``) retains every span and is too heavy to
leave on in production runs; the flight recorder is the black-box
counterpart — a fixed-size ring (``collections.deque(maxlen=...)``) of
compact tuples fed by the health monitor's delivery stream, ctrl-plane
instants (mirrored from the tracer when one is attached), CommitGate
anomalies and SLO breaches.  When something goes wrong — ``Fabric.audit()``
failure, a CommitGate anomaly, an SLO breach, a health flag — the last N
events are dumped as JSON for post-mortem forensics, with no full trace
required.

Same hard invariants as the tracer and health monitor: the recorder never
schedules events and never draws RNG; recording is one ``deque.append``.
Dumps happen only on failure paths (or explicit :meth:`dump` calls), write
ordinary files outside the event loop's knowledge, and are rate-limited so
a pathological run cannot fill a disk.
"""

from __future__ import annotations

import json
import os
from collections import deque
from typing import List, Optional

# environment override for the dump directory (CI uploads this on failure)
DUMP_DIR_ENV = "FLIGHT_DUMP_DIR"
DEFAULT_DUMP_DIR = "flight-dumps"


class FlightRecorder:
    """Bounded event ring + failure-triggered JSON dumps.

    Attach with ``FlightRecorder(fabric)``; record points call
    :meth:`record` (per-WR delivery summaries, from the health monitor) or
    :meth:`note` (sparse named events: instants, anomalies, breaches).
    ``capacity`` bounds memory; ``max_dumps`` bounds disk globally and
    ``max_per_reason`` bounds it per dump reason, so a chaos run whose
    fault plan exhausts hundreds of retries (reason ``retry-exhausted``)
    cannot crowd out the one ``update-abort`` dump that matters.
    First-class dump reasons: ``retry-exhausted`` (per-WR retry budget
    spent), ``update-abort`` (rlweights update rolled back), the PR-7/8
    reasons (``commit-anomaly``, ``slo-breach``, ``health-flag``), plus
    the control-plane reasons ``fence-rejected`` (a WRITE stamped with a
    stale view epoch was refused at the receiver's engine fence) and
    ``ctrl-retry-exhausted`` (a ctrl RPC retry chain ran out of budget).
    """

    def __init__(self, fabric, *, capacity: int = 2048, max_dumps: int = 8,
                 max_per_reason: int = 2, dump_dir: Optional[str] = None):
        self.fabric = fabric
        self.loop = fabric.loop
        self.ring: deque = deque(maxlen=int(capacity))
        self.max_dumps = int(max_dumps)
        self.max_per_reason = int(max_per_reason)
        self.dump_dir = dump_dir
        self.dumps: List[str] = []      # paths written so far
        self._reason_counts: dict = {}  # reason -> dumps written
        self.n_events = 0               # total ever recorded (ring may drop)
        fabric.attach_recorder(self)

    # -- recording ---------------------------------------------------------
    def record(self, kind: str, where: str, nbytes: int, dur_us: float) -> None:
        """Append one compact per-WR record: (t, kind, src>dst, bytes, µs)."""
        self.ring.append((self.loop.now, kind, where, nbytes, dur_us))
        self.n_events += 1

    def note(self, category: str, name: str, args: Optional[dict] = None) -> None:
        """Append one sparse named event (instant / anomaly / breach)."""
        self.ring.append((self.loop.now, category, name, args, None))
        self.n_events += 1

    # -- dumping -----------------------------------------------------------
    def _dir(self) -> str:
        return (self.dump_dir or os.environ.get(DUMP_DIR_ENV)
                or DEFAULT_DUMP_DIR)

    def dump(self, reason: str) -> Optional[str]:
        """Write the ring (+ health summary when a monitor is attached) as
        JSON; returns the path, or None once ``max_dumps`` (global) or
        ``max_per_reason`` (for this ``reason``) is exhausted."""
        if len(self.dumps) >= self.max_dumps:
            return None
        if self._reason_counts.get(reason, 0) >= self.max_per_reason:
            return None
        d = self._dir()
        os.makedirs(d, exist_ok=True)
        safe = "".join(c if c.isalnum() or c in "-_" else "-" for c in reason)
        path = os.path.join(d, f"flight_{len(self.dumps):02d}_{safe}.json")
        doc = {
            "reason": reason,
            "virtual_time_us": self.loop.now,
            "n_events_total": self.n_events,
            "events": [list(e) for e in self.ring],
        }
        mon = getattr(self.fabric, "health", None)
        if mon is not None:
            doc["health"] = mon.summary()
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        self.dumps.append(path)
        self._reason_counts[reason] = self._reason_counts.get(reason, 0) + 1
        return path
