"""MetricRegistry: counters, gauges and virtual-time histograms.

All values are in the fabric's *virtual* units (microseconds for times,
bytes for sizes).  The registry is deliberately allocation-light: a
histogram is a plain append-only sample list with percentiles computed on
demand, so recording on the simulator hot path costs one ``list.append``.
Percentiles use linear interpolation between closest ranks (the same
definition as ``numpy.percentile``'s default), which the unit tests pin.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple


def rank_percentile(xs: Sequence[float], p: float) -> float:
    """The ``p``-th percentile (0..100) of an already-**sorted** sequence,
    linear interpolation between closest ranks — numpy's default
    definition, pinned by unit tests.  Shared by :class:`Histogram` and the
    serving SLO tracker so every percentile in the repo means the same
    thing.  Returns 0.0 for an empty sequence."""
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    k = (len(xs) - 1) * (p / 100.0)
    f = math.floor(k)
    c = min(f + 1, len(xs) - 1)
    return xs[f] + (xs[c] - xs[f]) * (k - f)


class Histogram:
    """An exact-sample histogram with on-demand percentiles (virtual µs)."""

    __slots__ = ("samples",)

    def __init__(self) -> None:
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        """Record one sample."""
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        """Number of recorded samples."""
        return len(self.samples)

    @property
    def mean(self) -> float:
        """Arithmetic mean of the samples (0.0 when empty)."""
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    @property
    def max(self) -> float:
        """Largest sample (0.0 when empty)."""
        return max(self.samples) if self.samples else 0.0

    def percentile(self, p: float) -> float:
        """The ``p``-th percentile (0..100), linear interpolation between
        closest ranks — numpy's default definition, pinned by unit tests."""
        return rank_percentile(sorted(self.samples), p)

    def summary(self) -> Dict[str, float]:
        """count/mean/p50/p95/p99/max as a flat dict (bench JSON rows)."""
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "max": self.max}


class MetricRegistry:
    """Named counters, gauges (last + peak) and histograms.

    The flat-dict export (:meth:`as_dict`) is what gets merged into every
    ``BENCH_*.json`` — scalar keys only, dotted names, so the perf-gate's
    row comparison can treat metrics like any other stats row.
    """

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, Tuple[float, float]] = {}   # name -> (last, peak)
        self.histograms: Dict[str, Histogram] = {}

    def count(self, name: str, by: float = 1) -> None:
        """Increment counter ``name`` by ``by``."""
        self.counters[name] = self.counters.get(name, 0) + by

    def gauge(self, name: str, value: float) -> None:
        """Set gauge ``name``; its peak (max ever seen) is kept alongside."""
        _, peak = self.gauges.get(name, (0.0, float("-inf")))
        self.gauges[name] = (float(value), max(peak, float(value)))

    def observe(self, name: str, value: float) -> None:
        """Record ``value`` into histogram ``name`` (created on first use)."""
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.observe(value)

    def as_dict(self) -> Dict[str, float]:
        """Flatten everything to scalars: counters as-is, gauges as
        ``name``/``name.peak``, histograms as ``name.{count,mean,p50,p95,
        p99,max}``."""
        out: Dict[str, float] = dict(self.counters)
        for name, (last, peak) in self.gauges.items():
            out[name] = last
            out[f"{name}.peak"] = peak
        for name, h in self.histograms.items():
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out
