"""Tracer: per-WR lifecycle spans and fabric-wide events in virtual time.

The tracer observes the fabric WITHOUT perturbing it.  Two hard invariants,
enforced by the determinism tests:

* with a tracer attached, all simulated times are **bit-identical** to an
  untraced run — the tracer never schedules events, never draws from any
  RNG, and never changes iteration order; every hook is synchronous
  bookkeeping inside an already-executing continuation;
* with tracing off, each hook compiles down to a single guarded attribute
  check (``if tracer is not None``) with no allocation.

A :class:`WrSpan` records one work request's lifecycle stamps (all virtual
µs): ``t_submit`` (templated into a WrBatch) → ``t_enqueue`` (batch posted
on the worker) → ``t_post0``/``t_post`` (the WR's slot on the serialised
posting thread) → ``t_wire`` (NIC starts serialising, i.e. queue wait over)
→ ``t_deliver`` (last chunk fully visible at the destination).  Spans are
created by the :class:`~repro.core.TransferEngine` at submission and
stamped downstream by the DomainGroup/Channel hooks; a span missing
``t_deliver`` after the loop idles is an orphan (see ``Fabric.audit``).
"""

from __future__ import annotations

import itertools
from contextlib import contextmanager, nullcontext
from typing import Any, Dict, List, Optional, Tuple

from .metrics import MetricRegistry


class WrSpan:
    """Lifecycle stamps (virtual µs) for ONE work request on the fabric."""

    __slots__ = ("op_id", "kind", "phase", "src", "dst", "nbytes", "imm",
                 "track", "t_submit", "t_enqueue", "t_post0", "t_post",
                 "t_wire", "t_deliver")

    def __init__(self, op_id: int, kind: str, phase: str, dst: str,
                 nbytes: int, imm: Optional[int], t_submit: float,
                 src: str = ""):
        self.op_id = op_id
        self.kind = kind
        self.phase = phase
        self.src = src              # submitting engine's wire address
        self.dst = dst
        self.nbytes = nbytes
        self.imm = imm
        self.track = ""             # queue label, stamped at post time
        self.t_submit = t_submit
        self.t_enqueue: Optional[float] = None
        self.t_post0: Optional[float] = None
        self.t_post: Optional[float] = None
        self.t_wire: Optional[float] = None
        self.t_deliver: Optional[float] = None

    @property
    def complete(self) -> bool:
        """True once the WR's payload fully landed at the destination."""
        return self.t_deliver is not None

    def as_dict(self) -> Dict[str, Any]:
        """All fields as a plain dict (trace export / debugging)."""
        return {k: getattr(self, k) for k in self.__slots__}


class Window:
    """One tagged observation window: virtual-time interval + WR/batch
    deltas, the vllm-ascend ``ProfileExecuteDuration`` idiom the future
    online autotuner feeds on."""

    __slots__ = ("tag", "t0", "t1", "wrs", "batches", "nbytes")

    def __init__(self, tag: str, t0: float):
        self.tag = tag
        self.t0 = t0
        self.t1 = t0
        self.wrs = 0
        self.batches = 0
        self.nbytes = 0

    @property
    def duration_us(self) -> float:
        """Virtual time covered by the window."""
        return self.t1 - self.t0

    @property
    def post_enqueue_ratio(self) -> float:
        """WRs posted per WrBatch enqueued inside the window — matches
        ``BatchStats.wrs_per_enqueue`` over the same interval."""
        return self.wrs / self.batches if self.batches else 0.0

    def as_dict(self) -> Dict[str, float]:
        """Window stats as a flat dict."""
        return {"tag": self.tag, "t0": self.t0, "t1": self.t1,
                "duration_us": self.duration_us, "wrs": self.wrs,
                "batches": self.batches, "nbytes": self.nbytes,
                "post_enqueue_ratio": self.post_enqueue_ratio}


class Tracer:
    """Fabric-wide tracing + metrics sink, attached via ``Tracer(fabric)``.

    Collects: per-WR :class:`WrSpan` lifecycles, known-interval compute/
    resource spans (``compute_span``), ctrl-plane instants (``instant``),
    gauge samples (``gauge``/``sample_gauges``), tagged observation windows
    (``window``) and a :class:`~repro.obs.metrics.MetricRegistry`.
    Everything is ordinary Python bookkeeping — no event-loop interaction.
    """

    def __init__(self, fabric) -> None:
        self.fabric = fabric
        self.loop = fabric.loop
        self.metrics = MetricRegistry()
        self.spans: List[WrSpan] = []
        # (track, name, phase, t0, t1) known-interval resource/compute spans
        self.xspans: List[Tuple[str, str, str, float, float]] = []
        self.instants: List[Tuple[float, str, str, Optional[dict]]] = []
        self.samples: List[Tuple[float, str, float]] = []   # "C" events
        self.windows: Dict[str, List[Window]] = {}
        self._phases: List[str] = []
        self._ids = itertools.count()
        # enqueue-side counters (incremented per WrBatch handoff, matching
        # BatchStats by construction — the window-ratio ground truth)
        self.n_wrs = 0
        self.n_batches = 0
        self.n_bytes = 0
        fabric.attach_tracer(self)

    # -- span creation (engine-side) --------------------------------------
    @property
    def current_phase(self) -> str:
        """Innermost active ``phase(...)`` tag ('' outside any phase)."""
        return self._phases[-1] if self._phases else ""

    def begin_wr(self, kind: str, dst, nbytes: int,
                 imm: Optional[int], src: str = "") -> WrSpan:
        """Open a lifecycle span for one WR at submission time."""
        sp = WrSpan(next(self._ids), kind, self.current_phase, str(dst),
                    nbytes, imm, self.loop.now, src=src)
        self.spans.append(sp)
        return sp

    # -- post-time stamping (DomainGroup-side) ----------------------------
    def _on_post(self, op, ch, group, extra_post_us: float) -> None:
        """Stamp a WR's worker-posting slot and queue track (called by
        ``DomainGroup.post_write`` right after the posting delay is
        charged; pure bookkeeping)."""
        sp = op.span
        if sp is None:
            return
        if sp.t_enqueue is None:
            sp.t_enqueue = self.loop.now
        sp.t_post = group._post_busy_until
        sp.t_post0 = sp.t_post - group.post_us - extra_post_us
        sp.track = ch.label

    # -- phases and windows ------------------------------------------------
    @contextmanager
    def phase(self, name: str):
        """Tag every WR submitted inside the block with ``name``."""
        self._phases.append(name)
        try:
            yield
        finally:
            self._phases.pop()

    @contextmanager
    def window(self, tag: str):
        """Tagged observation window: yields a :class:`Window` whose
        virtual-time interval and WR/batch/byte deltas are filled at exit
        (``with tracer.window("prepare") as w: ...``)."""
        w = Window(tag, self.loop.now)
        wrs0, b0, n0 = self.n_wrs, self.n_batches, self.n_bytes
        try:
            yield w
        finally:
            w.t1 = self.loop.now
            w.wrs = self.n_wrs - wrs0
            w.batches = self.n_batches - b0
            w.nbytes = self.n_bytes - n0
            self.windows.setdefault(tag, []).append(w)
            m = self.metrics
            m.observe(f"window.{tag}.us", w.duration_us)
            if w.batches:
                m.observe(f"window.{tag}.wrs_per_enqueue",
                          w.post_enqueue_ratio)

    # -- instants, gauges, compute spans -----------------------------------
    def instant(self, category: str, name: str,
                args: Optional[dict] = None) -> None:
        """Record a point event (ctrl-plane JOIN/DRAIN/expiry, imm fire...)."""
        self.instants.append((self.loop.now, category, name, args))
        self.metrics.count(f"instant.{category}")
        rec = getattr(self.fabric, "recorder", None)
        if rec is not None:
            # mirror ctrl-plane instants into the always-on flight recorder
            rec.note(category, name, args)

    def gauge(self, name: str, value: float) -> None:
        """Record a gauge sample (exported as a Perfetto counter track)."""
        self.metrics.gauge(name, value)
        self.samples.append((self.loop.now, name, float(value)))

    def compute_span(self, track: str, name: str, t0: float, t1: float,
                     phase: str = "") -> None:
        """Record a known-interval span on a serialised resource track
        (kernel launch, route processing, H2D/prepare, layer compute)."""
        self.xspans.append((track, name, phase, t0, t1))
        self.metrics.observe(f"compute.{name}.us", t1 - t0)

    def sample_gauges(self) -> None:
        """Sample fabric-wide gauges NOW: per-NIC-queue backlog (µs of
        queued service time), staging watermarks via registered auditables,
        and outstanding ImmCounter expectations.  Call at natural protocol
        boundaries (round ends, window flushes) — never from hot hooks."""
        fab = self.fabric
        now = self.loop.now
        backlog_max = 0.0
        per_queue: Dict[str, float] = {}
        seen: set = set()
        outstanding = 0
        for addr, (group, eng) in fab._groups.items():
            for d in group.domains:
                b = d.nic.backlog_us(now)
                per_queue[f"{addr} nic{d.index}"] = b
                backlog_max = max(backlog_max, b)
            if id(eng) not in seen:
                seen.add(id(eng))
                for c in eng.counters.values():
                    outstanding += len(c.outstanding())
        self.gauge("queue.backlog_max_us", backlog_max)
        if len(per_queue) <= 64:      # per-queue tracks only at small scale
            for k, v in per_queue.items():
                self.gauge(f"queue.{k}.backlog_us", v)
        self.gauge("imm.outstanding", outstanding)

    # -- aggregation --------------------------------------------------------
    def finalize(self) -> Dict[str, float]:
        """Fold every completed span into the registry's ``wr.*``
        histograms and return the flat metrics dict (idempotent — derived
        entries are recomputed from scratch on each call)."""
        m = self.metrics
        for k in [k for k in m.histograms if k.startswith("wr.")]:
            del m.histograms[k]
        complete = 0
        for sp in self.spans:
            if sp.t_deliver is None:
                continue
            complete += 1
            m.observe("wr.total_us", sp.t_deliver - sp.t_submit)
            if sp.t_enqueue is not None:
                m.observe("wr.enqueue_us", sp.t_enqueue - sp.t_submit)
                if sp.t_wire is not None:
                    m.observe("wr.post_us", sp.t_wire - sp.t_enqueue)
            if sp.t_wire is not None:
                m.observe("wr.wire_us", sp.t_deliver - sp.t_wire)
        m.counters["wr.spans"] = len(self.spans)
        m.counters["wr.complete"] = complete
        m.counters["wr.orphans"] = len(self.spans) - complete
        m.counters["enqueue.batches"] = self.n_batches
        m.counters["enqueue.wrs"] = self.n_wrs
        m.counters["enqueue.nbytes"] = self.n_bytes
        return m.as_dict()


def traced_phase(fabric, name: str):
    """``tracer.phase(name)`` when ``fabric`` has a tracer, else a no-op
    context manager — the single-attribute-check guard for call sites."""
    tr = fabric.tracer
    return tr.phase(name) if tr is not None else nullcontext()


def traced_window(fabric, tag: str):
    """``tracer.window(tag)`` when ``fabric`` has a tracer, else a no-op
    context manager (yields None)."""
    tr = fabric.tracer
    return tr.window(tag) if tr is not None else nullcontext()
