"""Chrome trace-event JSON export (Perfetto-loadable).

Track layout (Perfetto groups by process, then thread):

* pid 1 "compute + engines" — one thread per serialised resource (GPU
  kernel launches, route processing, H2D/prepare engines, layer compute,
  per-group posting threads), rendered as "X" complete events;
* one pid per fabric queue (NIC queue / NVLink channel / cross channel) —
  WR lifecycle spans as async "b"/"e" events keyed by ``op_id``, so
  overlapping WRs on one queue nest instead of colliding;
* pid 2 "ctrl" — instant events (JOIN/DRAIN/lease expiry/autoscale/imm);
* pid 3 "gauges" — counter ("C") tracks for queue backlog, staging
  watermark and outstanding expectations.

Spans are colored by phase via a stable hash into the trace-viewer
palette.  Timestamps are virtual microseconds, passed through unscaled
(the trace-event ``ts`` unit is µs).
"""

from __future__ import annotations

import json
import zlib
from typing import Dict, List

# trace-viewer reserved color names (stable subset)
_PALETTE = [
    "thread_state_running", "thread_state_runnable", "thread_state_iowait",
    "rail_response", "rail_animation", "rail_idle", "rail_load",
    "cq_build_running", "cq_build_passed", "cq_build_failed",
    "good", "bad", "terrible", "yellow", "olive", "generic_work",
]

_PID_COMPUTE = 1
_PID_CTRL = 2
_PID_GAUGES = 3
_PID_QUEUE0 = 100


def _cname(phase: str) -> str:
    """Stable phase -> palette color mapping."""
    return _PALETTE[zlib.crc32(phase.encode()) % len(_PALETTE)]


def build_trace_events(tracer) -> List[dict]:
    """The tracer's contents as a trace-event list (no file I/O)."""
    events: List[dict] = []
    events.append({"ph": "M", "pid": _PID_COMPUTE, "name": "process_name",
                   "args": {"name": "compute + engines"}})
    events.append({"ph": "M", "pid": _PID_CTRL, "name": "process_name",
                   "args": {"name": "ctrl"}})
    events.append({"ph": "M", "pid": _PID_GAUGES, "name": "process_name",
                   "args": {"name": "gauges"}})

    # compute / resource spans: one tid per track under pid 1
    tids: Dict[str, int] = {}
    for track, name, phase, t0, t1 in tracer.xspans:
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids)
            events.append({"ph": "M", "pid": _PID_COMPUTE, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
        events.append({"ph": "X", "pid": _PID_COMPUTE, "tid": tid,
                       "name": name, "cat": phase or "compute",
                       "ts": t0, "dur": max(0.0, t1 - t0),
                       "cname": _cname(phase or name)})

    # WR lifecycle spans: async b/e per fabric queue track
    qpids: Dict[str, int] = {}
    for sp in tracer.spans:
        track = sp.track or "(unposted)"
        pid = qpids.get(track)
        if pid is None:
            pid = qpids[track] = _PID_QUEUE0 + len(qpids)
            events.append({"ph": "M", "pid": pid, "name": "process_name",
                           "args": {"name": f"queue {track}"}})
        name = f"{sp.kind}:{sp.phase}" if sp.phase else sp.kind
        args = {"src": sp.src, "dst": sp.dst, "nbytes": sp.nbytes,
                "phase": sp.phase,
                "t_submit": sp.t_submit, "t_enqueue": sp.t_enqueue,
                "t_post0": sp.t_post0, "t_post": sp.t_post,
                "t_wire": sp.t_wire, "t_deliver": sp.t_deliver}
        if sp.imm is not None:
            args["imm"] = sp.imm
        events.append({"ph": "b", "pid": pid, "tid": 0, "cat": "wr",
                       "id": sp.op_id, "name": name, "ts": sp.t_submit,
                       "cname": _cname(sp.phase or sp.kind), "args": args})
        if sp.t_deliver is not None:
            events.append({"ph": "e", "pid": pid, "tid": 0, "cat": "wr",
                           "id": sp.op_id, "name": name, "ts": sp.t_deliver})

    # instants
    for t, category, name, args in tracer.instants:
        ev = {"ph": "i", "pid": _PID_CTRL, "tid": 0, "s": "g",
              "cat": category, "name": f"{category}:{name}", "ts": t}
        if args:
            ev["args"] = args
        events.append(ev)

    # gauge samples as counter tracks
    for t, name, value in tracer.samples:
        events.append({"ph": "C", "pid": _PID_GAUGES, "tid": 0,
                       "name": name, "ts": t, "args": {"value": value}})
    return events


def export_chrome_trace(tracer, path: str) -> int:
    """Write the tracer's contents as Chrome trace-event JSON at ``path``
    (open with https://ui.perfetto.dev).  Returns the event count.

    When the traced fabric also carries a streaming
    :class:`~repro.obs.health.HealthMonitor`, its per-pair summary is
    embedded under a top-level ``"health"`` key (ignored by Perfetto) so
    ``tools/trace_report.py --live-parity`` can check the live counters
    against the post-hoc span attribution from one artifact."""
    events = build_trace_events(tracer)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    mon = getattr(tracer.fabric, "health", None)
    if mon is not None:
        doc["health"] = mon.summary()
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
    return len(events)
