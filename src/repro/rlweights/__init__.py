from .planner import ParamMeta, Route, compute_routing, schedule_stats
from .transfer import (Cluster, CommitGate, OnlineChunkTuner, StageChunk,
                       arm_commit_gates, autotune_chunk_bytes, commit_imm,
                       data_imm, launch_p2p_update, launch_pipelined_update,
                       make_cluster, p2p_transfer, plan_chunks,
                       rank0_transfer, resolve_chunk_bytes, run_pipelined_update, verify_contents)

__all__ = ["ParamMeta", "Route", "compute_routing", "schedule_stats",
           "Cluster", "CommitGate", "OnlineChunkTuner", "StageChunk",
           "arm_commit_gates",
           "autotune_chunk_bytes", "commit_imm", "data_imm",
           "launch_p2p_update", "launch_pipelined_update", "make_cluster",
           "p2p_transfer", "plan_chunks", "rank0_transfer",
           "resolve_chunk_bytes",
           "run_pipelined_update", "verify_contents"]
