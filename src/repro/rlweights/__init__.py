from .planner import ParamMeta, Route, compute_routing, schedule_stats
from .transfer import (Cluster, make_cluster, p2p_transfer, rank0_transfer,
                       verify_contents)

__all__ = ["ParamMeta", "Route", "compute_routing", "schedule_stats",
           "Cluster", "make_cluster", "p2p_transfer", "rank0_transfer",
           "verify_contents"]
