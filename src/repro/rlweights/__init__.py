from .planner import ParamMeta, Route, compute_routing, schedule_stats
from .transfer import (Cluster, CommitGate, StageChunk, arm_commit_gates,
                       commit_imm, data_imm, make_cluster, p2p_transfer,
                       plan_chunks, rank0_transfer, run_pipelined_update,
                       verify_contents)

__all__ = ["ParamMeta", "Route", "compute_routing", "schedule_stats",
           "Cluster", "CommitGate", "StageChunk", "arm_commit_gates",
           "commit_imm", "data_imm", "make_cluster", "p2p_transfer",
           "plan_chunks", "rank0_transfer", "run_pipelined_update",
           "verify_contents"]
