"""Static weight-transfer schedule (paper §5, Appendix B).

The controller gathers parameter metadata (name, shape, dtype, sharding)
from training and inference workers, then computes a static routing table:
which training rank sends which byte range of which parameter to which
inference rank, at which remote offset.  At each training step the workers
replay the schedule with one-sided WRITEs — no re-planning, no coordination,
and the inference side stays passive.

Shardings modeled:
  * training: FSDP — each parameter flattened and split evenly across the
    ranks of its MeshGroup (paper: different parameter types use different
    FSDP sharding strategies => several MeshGroups).
  * inference: TP — each parameter split across inference ranks along a
    (possibly different) axis; replicas receive identical bytes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class ParamMeta:
    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int
    mesh_group: int = 0

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * self.dtype_bytes


@dataclass(frozen=True)
class Route:
    """One WRITE of the schedule."""
    param: str
    train_rank: int
    infer_rank: int
    src_off: int           # byte offset within the train rank's shard
    dst_off: int           # byte offset within the inference rank's buffer
    nbytes: int


def fsdp_ranges(total: int, n: int) -> List[Tuple[int, int]]:
    """Even contiguous byte split (FSDP flat-param style)."""
    per = -(-total // n)
    return [(i * per, min(total, (i + 1) * per)) for i in range(n)]


def compute_routing(params: List[ParamMeta], n_train: int, n_infer: int,
                    infer_tp: int = 1, quant_ratio: float = 1.0,
                    changed: Optional[Iterable[str]] = None,
                    ) -> Tuple[List[Route], Dict[str, int]]:
    """Overlap-intersect FSDP source ranges with TP destination ranges.

    ``quant_ratio``: output bytes per input byte (bf16 -> fp8 => 0.5); the
    prepare stage quantises before the WRITE, so wire bytes are scaled.
    ``infer_tp``: TP degree of the inference fleet; each parameter is split
    into ``infer_tp`` contiguous byte ranges, and the fleet holds
    n_infer/infer_tp replicas of each range.
    ``changed``: delta mode for async fine-tuning — when given, routes are
    emitted ONLY for the named (dirty) parameters, while the source and
    destination cursors still advance over the full parameter list, so every
    delta route is byte-identical (same offsets, same sizes) to the full
    plan's route for that parameter: inference buffers keep the full-state
    layout and clean regions are simply never touched.
    Returns (routes, dst_offsets per (param, infer_rank))."""
    routes: List[Route] = []
    n_replica = n_infer // infer_tp
    dst_cursor = [0] * n_infer
    src_cursor = [0] * n_train
    dirty = None if changed is None else frozenset(changed)
    if dirty is not None:
        unknown = dirty - {pm.name for pm in params}
        if unknown:
            raise ValueError(f"changed names not in params: {sorted(unknown)}")

    for pm in params:
        emit = dirty is None or pm.name in dirty
        out_bytes = int(pm.nbytes * quant_ratio)
        src = fsdp_ranges(out_bytes, n_train)       # ranges in OUTPUT space
        dst = fsdp_ranges(out_bytes, infer_tp)      # TP split of the output
        if emit:
            for t, (slo, shi) in enumerate(src):
                if shi <= slo:
                    continue
                for tp, (dlo, dhi) in enumerate(dst):
                    lo, hi = max(slo, dlo), min(shi, dhi)
                    if hi <= lo:
                        continue
                    for rep in range(n_replica):
                        ir = rep * infer_tp + tp
                        routes.append(Route(
                            param=pm.name, train_rank=t, infer_rank=ir,
                            src_off=src_cursor[t] + (lo - slo),
                            dst_off=dst_cursor[ir] + (lo - dlo),
                            nbytes=hi - lo))
        for t, (slo, shi) in enumerate(src):
            src_cursor[t] += max(0, shi - slo)
        for tp in range(infer_tp):
            seg = dst[tp][1] - dst[tp][0]
            for rep in range(n_replica):
                dst_cursor[rep * infer_tp + tp] += seg

    sizes = {"infer": {r: dst_cursor[r] for r in range(n_infer)},
             "train": {r: src_cursor[r] for r in range(n_train)}}
    return routes, sizes


def schedule_stats(routes: List[Route], n_train: int, n_infer: int,
                   full_routes: Optional[List[Route]] = None) -> Dict:
    """Per-rank byte loads and balance.  Pass the full plan's routes as
    ``full_routes`` when ``routes`` is a delta plan to also report delta vs
    full wire bytes (the async fine-tuning saving)."""
    per_train = np.zeros(n_train, np.int64)
    per_infer = np.zeros(n_infer, np.int64)
    for r in routes:
        per_train[r.train_rank] += r.nbytes
        per_infer[r.infer_rank] += r.nbytes
    stats = {
        "n_routes": len(routes),
        "total_bytes": int(per_train.sum()),
        "max_train_bytes": int(per_train.max()),
        "max_infer_bytes": int(per_infer.max()),
        "balance": float(per_train.max() / max(1, per_train.mean())),
    }
    if full_routes is not None:
        full = sum(r.nbytes for r in full_routes)
        stats["delta_bytes"] = stats["total_bytes"]
        stats["full_bytes"] = int(full)
        stats["delta_frac"] = stats["total_bytes"] / max(1, full)
    return stats
