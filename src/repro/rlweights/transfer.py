"""Weight-transfer execution: pipelined P2P vs rank0 gather+broadcast (§5).

Two executors over the simulated fabric:

* ``p2p_transfer`` — the paper's approach.  Every training rank WRITEs its
  routed byte ranges directly to inference ranks, with the 4-stage pipeline
  (H2D memcpy -> prepare/quantise -> RDMA -> barrier) overlapped per task
  and a GPU-memory watermark limiting in-flight tasks.
* ``rank0_transfer`` — the baseline used by existing RL frameworks: all
  shards are gathered to training rank 0, then broadcast to inference
  rank 0s — bottlenecked by rank 0's NIC.

Both move REAL bytes through the fabric (content validated by tests); the
virtual clock gives the latency comparison (paper: 1.3 s vs 10-100 s).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import Fabric, MrDesc, MrHandle, TransferEngine
from .planner import ParamMeta, Route

# Pipeline stage rates (paper Table 5 calibration)
H2D_GBPS = 25.0            # PCIe H2D memcpy
PREP_GBPS = 150.0          # full_tensor + fusion + quantise, GPU-side
POST_US = 23.0             # per-WRITE submit overhead (Table 5: 26ms/1144)


@dataclass
class Cluster:
    fabric: Fabric
    train_engines: List[TransferEngine]
    infer_engines: List[TransferEngine]
    train_bufs: List[np.ndarray]
    infer_bufs: List[np.ndarray]
    train_handles: List[MrHandle]
    infer_descs: List[MrDesc]


def make_cluster(n_train: int, n_infer: int, shard_bytes: int,
                 infer_bytes: int, nic: str = "cx7", seed: int = 0) -> Cluster:
    fab = Fabric(seed=seed)
    te, ie, tb, ib, th, idesc = [], [], [], [], [], []
    for i in range(n_train):
        e = fab.add_engine(f"train{i}", nic=nic)
        buf = np.random.default_rng(100 + i).integers(
            0, 255, size=shard_bytes, dtype=np.uint8)
        h, _ = e.reg_mr(buf)
        te.append(e); tb.append(buf); th.append(h)
    for i in range(n_infer):
        e = fab.add_engine(f"infer{i}", nic=nic)
        buf = np.zeros(infer_bytes, np.uint8)
        _, d = e.reg_mr(buf)
        ie.append(e); ib.append(buf); idesc.append(d)
    return Cluster(fab, te, ie, tb, ib, th, idesc)


def p2p_transfer(cluster: Cluster, routes: List[Route], *,
                 watermark_bytes: int = 2 << 30,
                 h2d: bool = True) -> Dict[str, float]:
    """Pipelined point-to-point transfer.  Returns stage timings (us)."""
    fab = cluster.fabric
    by_rank: Dict[int, List[Route]] = {}
    for r in routes:
        by_rank.setdefault(r.train_rank, []).append(r)

    stats = {"h2d_us": 0.0, "prep_us": 0.0, "writes": 0}
    done = {"sent": 0, "need": len(routes)}

    for rank, rs in by_rank.items():
        eng = cluster.train_engines[rank]
        handle = cluster.train_handles[rank]
        # per-rank pipeline: stage k+1 of task i overlaps stage k of task i+1
        t_h2d, t_prep = 0.0, 0.0
        for r in rs:
            h2d_us = (r.nbytes / H2D_GBPS) * 1e-3 if h2d else 0.0
            prep_us = (r.nbytes / PREP_GBPS) * 1e-3
            t_h2d = t_h2d + h2d_us                 # H2D engine serialises
            t_prep = max(t_prep, t_h2d) + prep_us  # GPU prepare after H2D
            stats["h2d_us"] = max(stats["h2d_us"], t_h2d)
            stats["prep_us"] = max(stats["prep_us"], t_prep)

            def submit(r=r, eng=eng, handle=handle):
                eng.submit_single_write(
                    r.nbytes, None, (handle, r.src_off),
                    (cluster.infer_descs[r.infer_rank], r.dst_off),
                    on_done=lambda: done.__setitem__("sent", done["sent"] + 1))

            fab.loop.schedule(t_prep, submit)
            stats["writes"] += 1

    t_end = fab.run()
    stats["total_us"] = t_end
    stats["all_sent"] = done["sent"] == done["need"]
    return stats


def rank0_transfer(cluster: Cluster, routes: List[Route]) -> Dict[str, float]:
    """Baseline: gather all shards to train rank0, then rank0 WRITEs
    everything to every inference rank (collective-world pattern)."""
    fab = cluster.fabric
    eng0 = cluster.train_engines[0]
    # gather: every other train rank sends its shard to rank0
    gather_bytes = 0
    stage_buf = np.zeros(sum(b.size for b in cluster.train_bufs), np.uint8)
    h0, d0 = eng0.reg_mr(stage_buf)
    off = 0
    done = {"gathered": 0, "need": len(cluster.train_engines) - 1}
    for i, eng in enumerate(cluster.train_engines):
        n = cluster.train_bufs[i].size
        if i == 0:
            stage_buf[off:off + n] = cluster.train_bufs[0]
        else:
            eng.submit_single_write(
                n, None, (cluster.train_handles[i], 0), (d0, off),
                on_done=lambda: done.__setitem__("gathered", done["gathered"] + 1))
            gather_bytes += n
        off += n
    fab.run()
    t_gather = fab.now

    # broadcast: rank0 writes each inference rank's ranges — the whole
    # fan-out is templated into one batched submission (single enqueue,
    # per-WR posting cost amortised on rank0's worker)
    by_infer: Dict[int, List[Route]] = {}
    for r in routes:
        by_infer.setdefault(r.infer_rank, []).append(r)
    shard_sz = cluster.train_bufs[0].size
    writes = []
    for ir, rs in by_infer.items():
        for r in rs:
            src_off = r.train_rank * shard_sz + r.src_off
            writes.append((r.nbytes, None, (h0, src_off),
                           (cluster.infer_descs[ir], r.dst_off)))
    eng0.submit_write_batch(writes)
    t_end = fab.run()
    return {"gather_us": t_gather, "total_us": t_end,
            "bottleneck": "train rank0 NIC"}


def verify_contents(cluster: Cluster, routes: List[Route]) -> bool:
    """Check every routed byte range landed bit-exact."""
    for r in routes:
        src = cluster.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes]
        dst = cluster.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes]
        if not np.array_equal(src, dst):
            return False
    return True
