"""Weight-update execution: the staged P2P pipeline vs rank0 gather+broadcast.

``p2p_transfer`` is the paper's §5.2 engine, rebuilt around three ideas:

* **Watermark-bounded chunked staging** — every route is split into chunks
  small enough that ``watermark_bytes`` of staging memory bounds what is in
  flight per training rank.  The H2D memcpy engine and the GPU prepare
  (full_tensor + fuse + quantise) are serialised resources; chunks move
  through H2D -> prepare -> NIC as a pipeline, so stage k of chunk i
  overlaps stage k+1 of chunk i-1 at sub-parameter granularity.  Staging
  memory is reserved at admission and released on the chunk's sender-side
  completion — the watermark is honoured exactly (the seed accepted the
  argument and ignored it).
* **Window-coalesced WrBatches** — chunks whose prepare completes within
  the same pipeline window are templated into ONE ``WrBatch`` via
  ``submit_scatters`` (one app->worker enqueue for the whole window),
  retiring the per-route closure + per-submit enqueue of the old path.
  Replicas are deduplicated at staging: a source range is H2D'd and
  prepared ONCE, then WRITTEN to every TP replica.
* **Two-phase commit** — inference ranks arm a :class:`CommitGate` per
  update; data WRITEs carry ``data_imm(update_id)``, and once every data
  WRITE has a sender-side completion the coordinator posts a
  ``submit_barrier`` carrying ``commit_imm(update_id)``.  A rank flips to
  the new version exactly once, when BOTH its expected data count and the
  commit write have fully landed — in any arrival order (the paper's
  no-ordering contract: SRD may deliver the commit before late data).

``rank0_transfer`` stays the baseline used by existing RL frameworks: all
shards gathered to training rank 0, then broadcast — bottlenecked by rank
0's NIC (paper: 10-100 s vs 1.3 s).

Both move REAL bytes through the fabric (content validated by tests); the
virtual clock gives the latency comparison.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import Fabric, MrDesc, MrHandle, ScatterDst, TransferEngine
from ..core.engine import NIC_PRESETS
from ..core.netsim import POST_US
from ..core.topology import cross_spec
from .planner import ParamMeta, Route

# Pipeline stage rates (paper Table 5 calibration)
H2D_GBPS = 25.0            # PCIe H2D memcpy
PREP_GBPS = 150.0          # full_tensor + fusion + quantise, GPU-side
DEFAULT_WINDOW_US = 2.0    # pipeline window for WrBatch coalescing

# chunk autotuning clamps
MIN_CHUNK_BYTES = 256 << 10
AUTOTUNE_STAGES = 2        # H2D + prepare: pipeline-fill stages ahead of the NIC


def autotune_chunk_bytes(nic: str, bytes_per_rank: int, *,
                         watermark_bytes: int = 2 << 30,
                         stage_scale: float = 1.0,
                         stages: int = AUTOTUNE_STAGES,
                         dst_nic: Optional[str] = None) -> int:
    """Per-pair chunk size from the transport's post/enqueue cost model.

    Total pipelined time over ``B = bytes_per_rank`` at chunk size ``c`` is
    roughly ``B*w + (B/c)*fix + stages*c*w``: the wire term, the per-chunk
    posting overhead (``fix = POST_US + NicSpec.fixed_us``, paid once per
    WR), and the pipeline fill (``stages`` upstream stages must each hold
    one chunk before the NIC streams).  Minimising over ``c`` gives

        c* = sqrt(B * fix / (stages * w)),   w = us per wire byte.

    EFA's ~10x higher per-WR cost pushes its sweet spot to much larger
    chunks than CX7 (per-WR posting dominated vs pipelining dominated) —
    the Table-5 bench shows both.  The result is clamped to
    [``MIN_CHUNK_BYTES``, watermark/(stage_scale * 2)] so at least two
    chunks fit under the staging watermark, and rounded to 256 KiB.

    ``dst_nic``: the inference side's NIC kind when it differs from the
    training side's (heterogeneous fabrics).  The wire terms then come
    from the derived cross-fabric pair spec (:func:`~repro.core.cross_spec`
    — bottleneck bandwidth, the slower engine's fixed cost), while posting
    cost stays the sender's (WRs are posted on the training NIC).
    """
    spec, n_nics = NIC_PRESETS[nic]
    wire = spec
    if dst_nic is not None and dst_nic != nic:
        wire = cross_spec(spec, NIC_PRESETS[dst_nic][0])
    fix_us = POST_US.get(spec.name, 0.1) + wire.fixed_us
    wire_us_per_byte = 8e-3 / (wire.bw_gbps * wire.eff * n_nics)
    c = (max(1, bytes_per_rank) * fix_us / (stages * wire_us_per_byte)) ** 0.5
    cap = max(MIN_CHUNK_BYTES, int(watermark_bytes / max(stage_scale, 1e-9) / 2))
    c = min(max(int(c), MIN_CHUNK_BYTES), cap)
    return max(MIN_CHUNK_BYTES, (c // MIN_CHUNK_BYTES) * MIN_CHUNK_BYTES)


def resolve_chunk_bytes(chunk_bytes, routes: Sequence[Route], nic: str, *,
                        watermark_bytes: int = 2 << 30,
                        stage_scale: float = 1.0,
                        dst_nic: Optional[str] = None):
    """``chunk_bytes="auto"`` => derive from the pair cost model and the
    busiest rank's wire bytes; int/None pass through unchanged.  The
    single aggregation point for every "auto" consumer (engine + benches).
    ``chunk_bytes="online"`` starts at the same auto value — the executor
    then attaches an :class:`OnlineChunkTuner` per rank that re-derives the
    optimum from *measured* per-WR/per-byte costs mid-update.
    ``dst_nic`` forwards the inference side's NIC kind for mixed clusters."""
    if chunk_bytes not in ("auto", "online"):
        return chunk_bytes
    per_rank: Dict[int, int] = {}
    for r in routes:
        per_rank[r.train_rank] = per_rank.get(r.train_rank, 0) + r.nbytes
    return autotune_chunk_bytes(nic, max(per_rank.values(), default=1),
                                watermark_bytes=watermark_bytes,
                                stage_scale=stage_scale, dst_nic=dst_nic)

# Immediate-value block for weight updates: data and commit immediates are
# distinct per update_id so back-to-back updates never alias counters.
IMM_BASE = 0x52570000


def data_imm(update_id: int) -> int:
    return IMM_BASE + 2 * update_id


def commit_imm(update_id: int) -> int:
    return IMM_BASE + 2 * update_id + 1


@dataclass
class Cluster:
    fabric: Fabric
    train_engines: List[TransferEngine]
    infer_engines: List[TransferEngine]
    train_bufs: List[np.ndarray]
    infer_bufs: List[np.ndarray]
    train_handles: List[MrHandle]
    infer_descs: List[MrDesc]


def make_cluster(n_train: int, n_infer: int, shard_bytes: int,
                 infer_bytes: int, nic: str = "cx7", seed: int = 0,
                 infer_nic: Optional[str] = None) -> Cluster:
    """Build a train + infer fabric with registered weight buffers.

    ``infer_nic`` gives the inference cluster a different NIC kind than the
    training cluster (the Holmes cross-zone shape) — train->infer WRITEs
    then ride the derived cross-fabric pair spec.  Default: same kind."""
    fab = Fabric(seed=seed)
    te, ie, tb, ib, th, idesc = [], [], [], [], [], []
    for i in range(n_train):
        e = fab.add_engine(f"train{i}", nic=nic)
        buf = np.random.default_rng(100 + i).integers(
            0, 255, size=shard_bytes, dtype=np.uint8)
        h, _ = e.reg_mr(buf)
        te.append(e); tb.append(buf); th.append(h)
    for i in range(n_infer):
        e = fab.add_engine(f"infer{i}", nic=infer_nic or nic)
        buf = np.zeros(infer_bytes, np.uint8)
        _, d = e.reg_mr(buf)
        ie.append(e); ib.append(buf); idesc.append(d)
    return Cluster(fab, te, ie, tb, ib, th, idesc)


# ---------------------------------------------------------------------------
# two-phase commit (consumer side)
# ---------------------------------------------------------------------------

class CommitGate:
    """Per-inference-rank version gate for two-phase weight commits.

    ``arm`` registers two ImmCounter expectations: ``n_data`` WRITEs
    carrying the update's data immediate, and the single commit-barrier
    write.  The version flips exactly once, when both have fired —
    correctness never depends on the order the transport delivered them.

    Anomaly detection (flight-recorder hook): a second flip for the same
    ``update_id``, re-arming an already-armed id, or — checked by
    :meth:`audit_commits` once the run quiesces — more data/commit
    immediates landing than were armed, all append to ``anomalies``, emit
    a ctrl instant, and dump the flight recorder when one is attached.
    """

    def __init__(self, engine: TransferEngine, device: int = 0):
        self.engine = engine
        self.device = device
        self.version = 0
        self.flips: List[Tuple[float, int]] = []   # (virtual time, update_id)
        self.expected: Dict[int, int] = {}         # update_id -> armed n_data
        self.anomalies: List[dict] = []
        self.aborted_ids: List[int] = []           # update_ids rolled back

    def _anomaly(self, update_id: int, kind: str, info: dict) -> None:
        fab = self.engine.fabric
        rec = {"t": fab.now, "node": self.engine.node,
               "update_id": update_id, "kind": kind}
        rec.update(info)
        self.anomalies.append(rec)
        tr = fab.tracer
        if tr is not None:
            tr.instant("rlweights",
                       f"commit_anomaly:{self.engine.node}", rec)
        recorder = getattr(fab, "recorder", None)
        if recorder is not None:
            if tr is None:      # tracer instants already mirror into the ring
                recorder.note("rlweights", f"commit_anomaly:{kind}", rec)
            recorder.dump("commit-anomaly")

    def arm(self, update_id: int, n_data: int,
            on_flip: Optional[Callable[[int], None]] = None) -> None:
        if update_id in self.expected:
            self._anomaly(update_id, "re-armed",
                          {"n_data": n_data,
                           "prev_n_data": self.expected[update_id]})
        self.expected[update_id] = n_data
        state = {"data": False, "commit": False}

        def check(kind: str) -> None:
            state[kind] = True
            if state["data"] and state["commit"]:
                if any(uid == update_id for _, uid in self.flips):
                    self._anomaly(update_id, "double-flip",
                                  {"version": self.version})
                    return
                self.version += 1
                self.flips.append((self.engine.fabric.now, update_id))
                tr = self.engine.fabric.tracer
                if tr is not None:
                    tr.instant("rlweights",
                               f"commit_flip:{self.engine.node}",
                               {"update_id": update_id,
                                "version": self.version})
                if on_flip is not None:
                    on_flip(update_id)

        self.engine.expect_imm_count(data_imm(update_id), n_data,
                                     lambda: check("data"), device=self.device)
        self.engine.expect_imm_count(commit_imm(update_id), 1,
                                     lambda: check("commit"), device=self.device)

    def abort(self, update_id: int) -> None:
        """Roll back an armed-but-uncommitted update: reset both of the
        update's immediate counters (dropping their watchers, so late data
        WRITEs land bytes but fire nothing) and forget the armed
        expectation.  The coordinator calls this when it withholds the
        commit barrier — the rank's version never flips, the next
        ``update_id``'s immediates are untouched, and ``Fabric.audit()``
        stays clean (no unfulfilled expectations survive)."""
        ctr = self.engine.counters[self.device]
        ctr.reset(data_imm(update_id))
        ctr.reset(commit_imm(update_id))
        self.expected.pop(update_id, None)
        self.aborted_ids.append(update_id)

    def audit_commits(self, update_id: int) -> List[dict]:
        """Post-quiesce over-delivery check: the landed data/commit counters
        must sit *exactly* at the armed expectation — any excess means a
        duplicated WRITE or a misrouted immediate (recorded as an anomaly).
        Returns the gate's cumulative anomaly list."""
        ctr = self.engine.counters[self.device]
        n_data = self.expected.get(update_id, 0)
        have = ctr.value(data_imm(update_id))
        if have > n_data:
            self._anomaly(update_id, "extra-data-imm",
                          {"have": have, "need": n_data})
        have_c = ctr.value(commit_imm(update_id))
        if have_c > 1:
            self._anomaly(update_id, "extra-commit-imm",
                          {"have": have_c, "need": 1})
        return self.anomalies


def arm_commit_gates(engines: Sequence[TransferEngine],
                     chunks_by_rank: Dict[int, List["StageChunk"]],
                     update_id: int) -> List[CommitGate]:
    """Arm one :class:`CommitGate` per inference engine with its expected
    data-write count under ``chunks_by_rank`` (one WRITE per chunk target)
    — shared by the real-bytes executor and the synthetic bench so the
    commit protocol has a single definition."""
    n_data = [0] * len(engines)
    for chunks in chunks_by_rank.values():
        for c in chunks:
            for ir, _ in c.targets:
                n_data[ir] += 1
    gates = []
    for ir, eng in enumerate(engines):
        gate = CommitGate(eng)
        gate.arm(update_id, n_data[ir])
        gates.append(gate)
    return gates


# ---------------------------------------------------------------------------
# staged pipeline (producer side)
# ---------------------------------------------------------------------------

@dataclass
class StageChunk:
    """One staged unit: a contiguous sub-parameter source range, prepared
    once and WRITTEN to every replica target."""

    param: str
    src_off: int                              # train-shard offset (out space)
    nbytes: int                               # wire bytes per target
    stage_bytes: int                          # staging footprint (input side)
    targets: Tuple[Tuple[int, int], ...]      # (infer_rank, dst_off)


def plan_chunks(routes: Sequence[Route], *, chunk_bytes: Optional[int],
                watermark_bytes: int,
                stage_scale: float = 1.0) -> Dict[int, List[StageChunk]]:
    """Group a route schedule into per-rank staged chunks.

    Routes sharing ``(train_rank, param, src_off, nbytes)`` are TP replicas
    of one source range: they are staged (H2D + prepare) once and fanned
    out on the wire.  Each range is then split into chunks of at most
    ``chunk_bytes`` wire bytes, additionally capped so that one chunk's
    staging footprint (``stage_scale`` input bytes per wire byte, e.g. 2.0
    for bf16 -> fp8) never exceeds the watermark on its own.
    """
    if watermark_bytes <= 0:
        raise ValueError("watermark_bytes must be positive")
    cap = max(1, int(watermark_bytes / max(stage_scale, 1e-9)))
    eff_chunk = cap if chunk_bytes is None else max(1, min(chunk_bytes, cap))

    groups: Dict[int, Dict[Tuple[str, int, int], List[Tuple[int, int]]]] = {}
    for r in routes:
        key = (r.param, r.src_off, r.nbytes)
        groups.setdefault(r.train_rank, {}).setdefault(key, []).append(
            (r.infer_rank, r.dst_off))

    chunks: Dict[int, List[StageChunk]] = {}
    for rank, ranges in groups.items():
        out = chunks.setdefault(rank, [])
        for (param, src_off, nbytes), targets in ranges.items():
            off = 0
            while off < nbytes:
                n = min(eff_chunk, nbytes - off)
                out.append(StageChunk(
                    param=param, src_off=src_off + off, nbytes=n,
                    stage_bytes=max(1, int(n * stage_scale)),
                    targets=tuple((ir, doff + off) for ir, doff in targets)))
                off += n
    return chunks


class RankPipeline:
    """Event-driven H2D -> prepare -> post pipeline for ONE training rank.

    H2D and prepare are serialised engines (``busy-until`` clocks); chunks
    are admitted FIFO whenever their staging footprint fits under the
    watermark, and released on sender-side completion.  Prepared chunks
    collect into a window; one flush per window hands the whole batch to
    the submit callback (-> one WrBatch enqueue).
    """

    def __init__(self, fabric: Fabric, chunks: Sequence[StageChunk], *,
                 watermark_bytes: int, window_us: float,
                 submit_window: Callable[[List[StageChunk]], None],
                 h2d: bool = True, h2d_gbps: float = H2D_GBPS,
                 prep_gbps: float = PREP_GBPS, label: str = ""):
        self.loop = fabric.loop
        # observability: captured at construction (attach the Tracer first)
        self.tracer = fabric.tracer
        self.label = label
        self.queue = list(chunks)[::-1]        # pop() from the tail = FIFO
        self.watermark = watermark_bytes
        self.window_us = window_us
        self.submit_window = submit_window
        self.h2d = h2d
        self.h2d_gbps = h2d_gbps
        self.prep_gbps = prep_gbps
        self.staged = 0
        self.peak_staged = 0
        self.h2d_busy = self.prep_busy = self.loop.now
        self.h2d_work_us = 0.0    # pure stage service time (Table-5 style:
        self.prep_work_us = 0.0   # excludes watermark-admission stalls)
        self.n_flushes = 0
        self.aborted = False
        self._ready: List[StageChunk] = []
        self._flush_scheduled = False
        # assigned by run_pipelined_update: shared sent-accounting + release
        self.chunk_done_cb: Callable[[StageChunk], None] = self.chunk_sent
        # terminal per-chunk failure (fault injection): assigned by the
        # launcher to its abort handler; default swallows (no fault plan)
        self.chunk_error_cb: Callable[[StageChunk, str], None] = \
            lambda c, reason: None
        # online retuning (chunk_bytes="online"): per-rank tuner + the
        # launcher's remaining-count adjustment when queued chunks merge
        self.tuner = None
        self.chunks_merged_cb: Callable[[int], None] = lambda n: None
        self.n_merged = 0

    def start(self) -> None:
        self._admit()

    def _admit(self) -> None:
        if self.aborted:
            return
        while self.queue:
            c = self.queue[-1]
            if self.staged + c.stage_bytes > self.watermark:
                return                       # FIFO: wait for a release
            self.queue.pop()
            self.staged += c.stage_bytes
            self.peak_staged = max(self.peak_staged, self.staged)
            h2d_us = (c.stage_bytes / self.h2d_gbps) * 1e-3 if self.h2d else 0.0
            prep_us = (c.stage_bytes / self.prep_gbps) * 1e-3
            self.h2d_work_us += h2d_us
            self.prep_work_us += prep_us
            self.h2d_busy = max(self.loop.now, self.h2d_busy) + h2d_us
            t_ready = max(self.prep_busy, self.h2d_busy) + prep_us
            self.prep_busy = t_ready
            tr = self.tracer
            if tr is not None:
                # the serialised engines' slots are known at admission —
                # record them as resource spans (no event-loop interaction)
                if h2d_us:
                    tr.compute_span(f"{self.label} h2d", "h2d",
                                    self.h2d_busy - h2d_us, self.h2d_busy,
                                    phase="rlweights.stage")
                tr.compute_span(f"{self.label} prep", "prepare",
                                t_ready - prep_us, t_ready,
                                phase="rlweights.stage")
                tr.gauge("rlweights.staged_bytes", self.staged)
            self.loop.schedule_at(t_ready, lambda c=c: self._prepared(c))

    def _prepared(self, c: StageChunk) -> None:
        if self.aborted:
            # admitted before the abort, prepared after: release its
            # staging reservation instead of submitting it
            self.staged -= c.stage_bytes
            return
        self._ready.append(c)
        if not self._flush_scheduled:
            self._flush_scheduled = True
            self.loop.schedule(self.window_us, self._flush)

    def _flush(self) -> None:
        self._flush_scheduled = False
        window, self._ready = self._ready, []
        if window:
            self.n_flushes += 1
            self.submit_window(window)

    def chunk_sent(self, c: StageChunk) -> None:
        """Sender-side completion of every WRITE of ``c``: staging freed."""
        self.staged -= c.stage_bytes
        if self.tracer is not None:
            self.tracer.gauge("rlweights.staged_bytes", self.staged)
        self._admit()

    def abort(self) -> None:
        """Stop this rank's pipeline: drop un-admitted chunks and release
        the staging of prepared-but-unsubmitted ones.  Chunks already on
        the wire run to their own completion (success frees staging via
        :meth:`chunk_sent`; failure via the launcher's error handler) — so
        at loop-idle an aborted pipeline audits clean."""
        self.aborted = True
        self.queue.clear()
        for c in self._ready:
            self.staged -= c.stage_bytes
        self._ready.clear()
        if self.tracer is not None:
            self.tracer.gauge("rlweights.staged_bytes", self.staged)

    def retarget_chunk_bytes(self, target: int) -> int:
        """Merge-only rechunk of the not-yet-admitted queue toward ``target``
        wire bytes per chunk.

        Adjacent queued chunks coalesce when they are the same parameter,
        source-contiguous, and every replica target lines up (same infer
        ranks, destination offsets contiguous) — exactly the inverse of the
        split :func:`plan_chunks` performed, so the merged chunk WRITEs the
        same bytes with fewer WRs.  Chunks already admitted (staging
        reserved) or in flight are never touched, and chunks never shrink:
        splitting mid-update would invalidate the commit gate's armed data
        counts, merging only *reduces* them (the launcher is notified via
        ``chunks_merged_cb``).  Returns the number of merges performed."""
        if len(self.queue) < 2:
            return 0
        fifo = self.queue[::-1]                # queue tail = next FIFO chunk
        out: List[StageChunk] = []
        merged = 0
        i = 0
        while i < len(fifo):
            c = fifo[i]
            while i + 1 < len(fifo):
                nxt = fifo[i + 1]
                if not (nxt.param == c.param
                        and nxt.src_off == c.src_off + c.nbytes
                        and c.nbytes + nxt.nbytes <= target
                        and len(nxt.targets) == len(c.targets)
                        and all(ir2 == ir and d2 == d + c.nbytes
                                for (ir, d), (ir2, d2)
                                in zip(c.targets, nxt.targets))):
                    break
                c = StageChunk(
                    param=c.param, src_off=c.src_off,
                    nbytes=c.nbytes + nxt.nbytes,
                    stage_bytes=c.stage_bytes + nxt.stage_bytes,
                    targets=c.targets)
                merged += 1
                i += 1
            out.append(c)
            i += 1
        if merged:
            self.queue = out[::-1]
            self.n_merged += merged
            self.chunks_merged_cb(merged)
        return merged

    def audit_leaks(self) -> Dict[str, int]:
        """Unreleased staging state at loop-idle (empty dict = clean):
        reserved-but-unreleased staging bytes, never-admitted chunks, and
        prepared chunks whose window never flushed."""
        rep: Dict[str, int] = {}
        if self.staged:
            rep["staged_bytes"] = self.staged
        if self.queue:
            rep["queued_chunks"] = len(self.queue)
        if self._ready:
            rep["unflushed_window_chunks"] = len(self._ready)
        return rep

    @property
    def h2d_total_us(self) -> float:
        return self.h2d_work_us

    @property
    def prep_total_us(self) -> float:
        return self.prep_work_us


class OnlineChunkTuner:
    """Closed-loop chunk-size calibration (``chunk_bytes="online"``).

    :func:`autotune_chunk_bytes` derives ``c* = sqrt(B*fix/(stages*w))``
    from the *static* NIC spec.  This tuner re-derives it from **measured**
    costs, read off the always-on :class:`~repro.obs.health.HealthMonitor`
    on each chunk's sender-side completion:

    * ``fix`` = delta post-segment time / delta WRs for this rank's engine
      — the live per-WR overhead.  On a congested fabric the post segment
      absorbs the NIC backlog, so measured ``fix`` explodes past the
      spec's ``POST_US + fixed_us`` and the optimum drifts to *bigger*
      chunks (fewer WRs amortise the queueing).
    * ``w`` = delta wire time / delta wire bytes — the live per-byte cost.
    * ``B`` = bytes still queued (un-admitted) on the rank's pipeline.

    Retargeting is merge-only (:meth:`RankPipeline.retarget_chunk_bytes`)
    and gated by ``hysteresis`` (new target must exceed 1.5x the current
    one), so a clean fabric — where measured costs match the spec — never
    retunes and the schedule stays byte-identical to static ``"auto"``.
    Pure bookkeeping: never schedules events, never draws RNG.  With no
    HealthMonitor attached the tuner is inert.
    """

    def __init__(self, fabric: Fabric, src, chunk_bytes: int, *, cap: int,
                 stages: int = AUTOTUNE_STAGES, min_wrs: int = 8,
                 hysteresis: float = 1.5):
        self.fabric = fabric
        self.monitor = fabric.health
        self.src = str(src)
        self.target = int(chunk_bytes)
        self.cap = int(cap)
        self.stages = max(1, int(stages))
        self.min_wrs = int(min_wrs)
        self.hysteresis = float(hysteresis)
        self.retunes: List[dict] = []
        self._base = (self.monitor.src_stats(self.src)
                      if self.monitor is not None else None)

    def on_chunk_done(self, pipe: RankPipeline) -> None:
        """Re-derive the chunk optimum from the observation window since
        the last retune; merge the queued tail up when it moved >= 1.5x."""
        mon = self.monitor
        if mon is None:
            return
        st = mon.src_stats(self.src)
        base = self._base
        dn = st["n"] - base["n"]
        dbytes = st["nbytes"] - base["nbytes"]
        if dn < self.min_wrs or dbytes <= 0:
            return
        fix_us = (st["post_us"] - base["post_us"]) / dn
        w = (st["wire_us"] - base["wire_us"]) / dbytes
        b_rem = sum(c.nbytes for c in pipe.queue)
        if b_rem <= 0 or fix_us <= 0.0 or w <= 0.0:
            return
        c = int((b_rem * fix_us / (self.stages * w)) ** 0.5)
        c = min(c, self.cap)
        c = max(MIN_CHUNK_BYTES, (c // MIN_CHUNK_BYTES) * MIN_CHUNK_BYTES)
        if c < self.target * self.hysteresis:
            return
        merged = pipe.retarget_chunk_bytes(c)
        old, self.target = self.target, c
        self._base = st          # rolling window: next decision on fresh data
        rec = {"t": self.fabric.now, "rank": pipe.label, "old": old,
               "new": c, "merged": merged, "fix_us": fix_us,
               "wire_us_per_byte": w}
        self.retunes.append(rec)
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("rlweights", f"chunk_retarget:{pipe.label}", rec)
        else:
            recorder = getattr(self.fabric, "recorder", None)
            if recorder is not None:
                recorder.note("rlweights", f"chunk_retarget:{pipe.label}",
                              rec)


def launch_pipelined_update(
        fabric: Fabric, chunks_by_rank: Dict[int, List[StageChunk]], *,
        make_submit: Callable[[int, "RankPipeline"],
                              Callable[[List[StageChunk]], None]],
        commit_fn: Optional[Callable[[], None]],
        watermark_bytes: int, window_us: float, h2d: bool,
        h2d_gbps: float, prep_gbps: float,
        tuner_factory: Optional[Callable[[int, "RankPipeline"],
                                         Optional[OnlineChunkTuner]]] = None,
        on_abort: Optional[Callable[[str], None]] = None
        ) -> Callable[[], Dict[str, float]]:
    """Create and START every rank's pipeline NOW — without draining the
    fabric — and return a ``collect()`` closure for the stats once the run
    has quiesced.  This is the overlap building block: a second update can
    be launched while the first is still in flight (its chunks admitted
    behind the first's tail), each with its own per-``update_id`` commit.

    ``make_submit(rank, pipe)`` returns the window-flush callback that
    actually posts the chunk WRITEs; it must arrange for
    ``pipe.chunk_done_cb(c)`` to run on each chunk's sender-side completion
    — wiring kept in the callers so the real-bytes and synthetic paths
    share this exact scheduler.  ``commit_fn`` is invoked once, after every
    chunk of every rank has sender-side completions.

    ``tuner_factory(rank, pipe)`` (optional) attaches an
    :class:`OnlineChunkTuner` per rank; it observes on every chunk
    completion and may merge the queued tail into bigger chunks — the
    launcher's remaining-count is adjusted through ``chunks_merged_cb`` so
    the commit still fires after the *last actually-sent* chunk.

    **Abort protocol** (fault injection): when a chunk's WRITEs exhaust
    their retry budget, the chunk's error callback fires ``chunk_error`` —
    the first failure aborts every rank's pipeline (un-admitted chunks
    dropped, staged-but-unsubmitted reservations released), the commit is
    permanently withheld, the flight recorder dumps with reason
    ``update-abort``, and ``on_abort(reason)`` lets the caller roll back
    consumer-side state (:meth:`CommitGate.abort`).  Chunks already on the
    wire drain to their own terminal state, so the fabric audits clean.
    """
    pipes: Dict[int, RankPipeline] = {}
    state = {"remaining": sum(len(v) for v in chunks_by_rank.values()),
             "writes_sent": 0, "aborted": False, "abort_reason": None}
    t0 = fabric.now

    def chunk_done(pipe: RankPipeline, c: StageChunk) -> None:
        pipe.chunk_sent(c)
        state["writes_sent"] += len(c.targets)
        state["remaining"] -= 1
        if pipe.tuner is not None:
            pipe.tuner.on_chunk_done(pipe)
        if (state["remaining"] == 0 and commit_fn is not None
                and not state["aborted"]):
            commit_fn()

    def chunk_error(pipe: RankPipeline, c: StageChunk, reason: str) -> None:
        # the failed chunk's staging was reserved at admission and will
        # never see a sender-side completion — release it here
        pipe.staged -= c.stage_bytes
        if state["aborted"]:
            return                  # a sibling already tore the update down
        state["aborted"] = True
        state["abort_reason"] = reason
        for p in pipes.values():
            p.abort()
        tr = fabric.tracer
        info = {"rank": pipe.label, "param": c.param, "reason": reason}
        if tr is not None:
            tr.instant("rlweights", "update_abort", info)
        rec = getattr(fabric, "recorder", None)
        if rec is not None:
            if tr is None:          # tracer instants mirror into the ring
                rec.note("rlweights", "update_abort", info)
            rec.dump("update-abort")
        if on_abort is not None:
            on_abort(reason)

    def chunks_merged(n: int) -> None:
        # n merges = n fewer chunk completions still to come; merged chunks
        # are un-admitted, so remaining stays >= 1 here — the commit check
        # in chunk_done still sees the true last completion
        state["remaining"] -= n

    for rank, chunks in chunks_by_rank.items():
        pipe = RankPipeline(
            fabric, chunks, watermark_bytes=watermark_bytes,
            window_us=window_us, h2d=h2d, h2d_gbps=h2d_gbps,
            prep_gbps=prep_gbps, label=f"rank{rank}",
            submit_window=lambda w: None)      # bound just below
        pipe.submit_window = make_submit(rank, pipe)
        pipe.chunk_done_cb = lambda c, pipe=pipe: chunk_done(pipe, c)
        pipe.chunk_error_cb = (
            lambda c, reason, pipe=pipe: chunk_error(pipe, c, reason))
        pipe.chunks_merged_cb = chunks_merged
        if tuner_factory is not None:
            pipe.tuner = tuner_factory(rank, pipe)
        fabric.register_auditable(f"rlweights.rank{rank}", pipe)
        pipes[rank] = pipe

    for pipe in pipes.values():
        pipe.start()
    if state["remaining"] == 0 and commit_fn is not None:
        commit_fn()                            # empty (all-clean delta) update

    def collect() -> Dict[str, float]:
        return {
            "total_us": fabric.now - t0,
            "h2d_us": max((p.h2d_total_us for p in pipes.values()), default=0.0),
            "prep_us": max((p.prep_total_us for p in pipes.values()), default=0.0),
            "writes": state["writes_sent"],
            "n_chunks": sum(len(v) for v in chunks_by_rank.values()),
            "n_merges": sum(p.n_merged for p in pipes.values()),
            "n_retunes": sum(len(p.tuner.retunes) for p in pipes.values()
                             if p.tuner is not None),
            "n_batches": sum(p.n_flushes for p in pipes.values()),
            "peak_staged_bytes": max((p.peak_staged for p in pipes.values()),
                                     default=0),
            "watermark_ok": all(p.peak_staged <= watermark_bytes
                                for p in pipes.values()),
            "all_sent": state["remaining"] == 0 and not state["aborted"],
            "aborted": state["aborted"],
            "abort_reason": state["abort_reason"],
        }

    return collect


def run_pipelined_update(
        fabric: Fabric, chunks_by_rank: Dict[int, List[StageChunk]], *,
        make_submit, commit_fn, watermark_bytes: int, window_us: float,
        h2d: bool, h2d_gbps: float, prep_gbps: float,
        tuner_factory: Optional[Callable[[int, "RankPipeline"],
                                         Optional[OnlineChunkTuner]]] = None
        ) -> Dict[str, float]:
    """Launch one pipelined update and drive the fabric until idle."""
    collect = launch_pipelined_update(
        fabric, chunks_by_rank, make_submit=make_submit, commit_fn=commit_fn,
        watermark_bytes=watermark_bytes, window_us=window_us, h2d=h2d,
        h2d_gbps=h2d_gbps, prep_gbps=prep_gbps, tuner_factory=tuner_factory)
    fabric.run()
    return collect()


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

def launch_p2p_update(cluster: Cluster, routes: List[Route], *,
                      watermark_bytes: int = 2 << 30, h2d: bool = True,
                      chunk_bytes=None,
                      window_us: float = DEFAULT_WINDOW_US,
                      stage_scale: float = 1.0,
                      h2d_gbps: float = H2D_GBPS, prep_gbps: float = PREP_GBPS,
                      update_id: int = 0, commit: bool = True,
                      src_handles: Optional[List[MrHandle]] = None
                      ) -> Callable[[], Dict[str, float]]:
    """Start a pipelined p2p update on a (possibly already running) fabric
    and return its ``collect()`` closure — the overlap building block for
    async RL, where update N+1 begins while update N's tail is still in
    flight.  Per-``update_id`` data/commit immediates keep the two updates'
    gates independent.  ``src_handles`` overrides the cluster's registered
    training shards (e.g. a second set of buffers for the next version).
    """
    fab = cluster.fabric
    nic = cluster.train_engines[0].nic_name
    dst_nic = cluster.infer_engines[0].nic_name if cluster.infer_engines \
        else None
    online = chunk_bytes == "online"
    chunk_bytes = resolve_chunk_bytes(chunk_bytes, routes, nic,
                                      watermark_bytes=watermark_bytes,
                                      stage_scale=stage_scale,
                                      dst_nic=dst_nic)
    chunks_by_rank = plan_chunks(routes, chunk_bytes=chunk_bytes,
                                 watermark_bytes=watermark_bytes,
                                 stage_scale=stage_scale)

    gates: List[CommitGate] = []
    n_data_live = [0] * len(cluster.infer_engines)
    if commit:
        if online:
            # gate arming is deferred to commit time: the online tuner may
            # merge queued chunks mid-update, so per-rank data-WRITE counts
            # are only final once every chunk has a sender-side completion.
            # ImmCounter is order-agnostic — arming after (some) data
            # landed still flips exactly once, in any delivery order.
            gates = [CommitGate(eng) for eng in cluster.infer_engines]
        else:
            gates = arm_commit_gates(cluster.infer_engines, chunks_by_rank,
                                     update_id)

    imm = data_imm(update_id) if commit else None
    handles = src_handles if src_handles is not None else cluster.train_handles

    def make_submit(rank: int, pipe: RankPipeline):
        eng = cluster.train_engines[rank]
        handle = handles[rank]

        def submit(window: List[StageChunk]) -> None:
            if online and commit:
                for c in window:
                    for ir, _ in c.targets:
                        n_data_live[ir] += 1
            eng.submit_scatters([
                (handle,
                 [ScatterDst(len=c.nbytes, src=c.src_off,
                             dst=(cluster.infer_descs[ir], doff))
                  for ir, doff in c.targets],
                 imm, (lambda c=c: pipe.chunk_done_cb(c)),
                 (lambda reason, c=c: pipe.chunk_error_cb(c, reason)))
                for c in window])

        return submit

    def on_abort(reason: str) -> None:
        # coordinator withholds the commit barrier; roll back each
        # consumer's armed gate so no expectation leaks (online gates are
        # unarmed at this point — resetting their imms is a no-op)
        for g in gates:
            g.abort(update_id)

    def commit_fn() -> None:
        if online and commit:
            for ir, g in enumerate(gates):
                g.arm(update_id, n_data_live[ir])
        cluster.train_engines[0].submit_barrier(
            list(cluster.infer_descs), commit_imm(update_id))

    tuners: Dict[int, OnlineChunkTuner] = {}
    tuner_factory = None
    if online:
        cap = max(MIN_CHUNK_BYTES,
                  int(watermark_bytes / max(stage_scale, 1e-9) / 2))

        def tuner_factory(rank: int, pipe: RankPipeline) -> OnlineChunkTuner:
            t = OnlineChunkTuner(
                fab, cluster.train_engines[rank].address(0), chunk_bytes,
                cap=cap)
            tuners[rank] = t
            return t

    collect_pipe = launch_pipelined_update(
        fab, chunks_by_rank,
        make_submit=make_submit,
        commit_fn=commit_fn if commit else None,
        watermark_bytes=watermark_bytes, window_us=window_us, h2d=h2d,
        h2d_gbps=h2d_gbps, prep_gbps=prep_gbps,
        tuner_factory=tuner_factory,
        on_abort=on_abort if commit else None)

    def collect() -> Dict[str, float]:
        stats = collect_pipe()
        stats["chunk_bytes"] = chunk_bytes
        if online:
            stats["online"] = True
            stats["chunk_bytes_final"] = max(
                (t.target for t in tuners.values()), default=chunk_bytes)
        if commit:
            if not stats["aborted"]:
                # post-quiesce over-delivery audit is meaningless after an
                # abort: the gates' counters were deliberately reset
                for g in gates:
                    g.audit_commits(update_id)
            stats["commits"] = [len(g.flips) for g in gates]
            stats["committed"] = (not stats["aborted"]) and all(
                len(g.flips) == 1 and g.flips[0][1] == update_id
                for g in gates)
            stats["commit_anomalies"] = sum(len(g.anomalies) for g in gates)
        return stats

    return collect


def p2p_transfer(cluster: Cluster, routes: List[Route], *,
                 watermark_bytes: int = 2 << 30, h2d: bool = True,
                 chunk_bytes=None,
                 window_us: float = DEFAULT_WINDOW_US,
                 stage_scale: float = 1.0,
                 h2d_gbps: float = H2D_GBPS, prep_gbps: float = PREP_GBPS,
                 update_id: int = 0, commit: bool = True) -> Dict[str, float]:
    """Pipelined point-to-point weight update.  Returns stage timings (us).

    Every training rank runs the watermark-bounded chunk pipeline; windows
    of prepared chunks post as single WrBatches (``submit_scatters``, one
    group per chunk so staging frees per chunk); with ``commit=True`` the
    update ends with the two-phase commit barrier and the returned stats
    carry per-rank flip records ("commits").  ``chunk_bytes`` may be an
    int, None (watermark-capped whole ranges), ``"auto"`` (per-NIC cost
    model via :func:`autotune_chunk_bytes`), or ``"online"`` (start at the
    auto value, then let :class:`OnlineChunkTuner` recalibrate from the
    attached HealthMonitor's measured costs mid-update).
    """
    collect = launch_p2p_update(
        cluster, routes, watermark_bytes=watermark_bytes, h2d=h2d,
        chunk_bytes=chunk_bytes, window_us=window_us,
        stage_scale=stage_scale, h2d_gbps=h2d_gbps, prep_gbps=prep_gbps,
        update_id=update_id, commit=commit)
    cluster.fabric.run()
    return collect()


def rank0_transfer(cluster: Cluster, routes: List[Route], *,
                   update_id: int = 0,
                   commit: bool = True) -> Dict[str, float]:
    """Baseline: gather all shards to train rank0, then rank0 WRITEs
    everything to every inference rank (collective-world pattern).

    With ``commit=True`` the broadcast ends with the same two-phase commit
    as the p2p path (data immediates per WRITE + one commit barrier, a
    :class:`CommitGate` flip per inference rank) — protocol parity for the
    Table-5 comparison: the baseline's deficit is bandwidth, not a lighter
    contract."""
    fab = cluster.fabric
    eng0 = cluster.train_engines[0]
    # gather: every other train rank sends its shard to rank0
    gather_bytes = 0
    stage_buf = np.zeros(sum(b.size for b in cluster.train_bufs), np.uint8)
    h0, d0 = eng0.reg_mr(stage_buf)
    off = 0
    done = {"gathered": 0, "need": len(cluster.train_engines) - 1}
    for i, eng in enumerate(cluster.train_engines):
        n = cluster.train_bufs[i].size
        if i == 0:
            stage_buf[off:off + n] = cluster.train_bufs[0]
        else:
            eng.submit_single_write(
                n, None, (cluster.train_handles[i], 0), (d0, off),
                on_done=lambda: done.__setitem__("gathered", done["gathered"] + 1))
            gather_bytes += n
        off += n
    fab.run()
    t_gather = fab.now

    # broadcast: rank0 writes each inference rank's ranges — the whole
    # fan-out is templated into one batched submission (single enqueue,
    # per-WR posting cost amortised on rank0's worker)
    by_infer: Dict[int, List[Route]] = {}
    for r in routes:
        by_infer.setdefault(r.infer_rank, []).append(r)
    shard_sz = cluster.train_bufs[0].size

    gates: List[CommitGate] = []
    if commit:
        for ir, eng in enumerate(cluster.infer_engines):
            gate = CommitGate(eng)
            gate.arm(update_id, len(by_infer.get(ir, [])))
            gates.append(gate)

    imm = data_imm(update_id) if commit else None
    writes = []
    for ir, rs in by_infer.items():
        for r in rs:
            src_off = r.train_rank * shard_sz + r.src_off
            writes.append((r.nbytes, imm, (h0, src_off),
                           (cluster.infer_descs[ir], r.dst_off)))

    def broadcast_done() -> None:
        if commit:
            eng0.submit_barrier(list(cluster.infer_descs),
                                commit_imm(update_id))

    eng0.submit_write_batch(writes, on_done=broadcast_done)
    t_end = fab.run()
    stats = {"gather_us": t_gather, "total_us": t_end,
             "bottleneck": "train rank0 NIC"}
    if commit:
        stats["commits"] = [len(g.flips) for g in gates]
        stats["committed"] = all(
            len(g.flips) == 1 and g.flips[0][1] == update_id for g in gates)
    return stats


def verify_contents(cluster: Cluster, routes: List[Route]) -> bool:
    """Check every routed byte range landed bit-exact."""
    for r in routes:
        src = cluster.train_bufs[r.train_rank][r.src_off:r.src_off + r.nbytes]
        dst = cluster.infer_bufs[r.infer_rank][r.dst_off:r.dst_off + r.nbytes]
        if not np.array_equal(src, dst):
            return False
    return True
