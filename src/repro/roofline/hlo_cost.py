"""Trip-count-aware static cost analysis of post-optimization HLO.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, massively
undercounting scan-stacked models (a 100-layer scan contributes a single
layer of FLOPs).  XLA:CPU annotates every while with
``backend_config={"known_trip_count":{"n":...}}``, so we reconstruct true
per-device totals by walking the computation call graph with multiplicities:

  * FLOPs       — 2 * prod(result dims) * prod(contracting dims) per dot,
                  accumulated through while bodies (x trip count) and fusion
                  subcomputations; elementwise flops are ignored (dots
                  dominate every arch here; recorded as a known undercount).
  * bytes       — per instruction: operand + result bytes at computation
                  level, fusions opaque (operands+result only) — mirroring
                  XLA's bytes-accessed model — scaled by multiplicity.
  * collectives — operand bytes of collective ops scaled by multiplicity.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "while", "conditional", "call", "custom-call",
}


def _dims(dims_str: str) -> List[int]:
    return [int(d) for d in dims_str.split(",") if d] if dims_str else []


def _type_bytes(typespec: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typespec):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in _dims(dims):
                n *= d
            total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    typespec: str
    op: str
    rest: str

    @property
    def result_bytes(self) -> int:
        return _type_bytes(self.typespec)

    def operand_names(self) -> List[str]:
        depth, end = 1, len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return re.findall(r"%([\w.\-]+)", self.rest[:end])

    def attr(self, name: str) -> Optional[str]:
        m = re.search(name + r"=\{([0-9,]*)\}", self.rest)
        return m.group(1) if m else None

    def called(self, key: str) -> List[str]:
        out = []
        for m in re.finditer(key + r"=%([\w.\-]+)", self.rest):
            out.append(m.group(1))
        m = re.search(key + r"=\{([^}]*)\}", self.rest)
        if m:
            out += re.findall(r"%([\w.\-]+)", m.group(1))
        return out

    @property
    def trip_count(self) -> Optional[int]:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', self.rest)
        return int(m.group(1)) if m else None


def parse_hlo(text: str) -> Tuple[Dict[str, List[Instr]], str, Dict[str, Instr]]:
    comps: Dict[str, List[Instr]] = {}
    table: Dict[str, Instr] = {}
    entry = ""
    current: Optional[str] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            current = mc.group(2)
            comps[current] = []
            if mc.group(1):
                entry = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = Instr(*mi.groups())
            comps[current].append(ins)
            table[ins.name] = ins
    return comps, entry, table


def _dot_flops(ins: Instr, table: Dict[str, Instr]) -> float:
    res_elems = 1
    for _, dims in _SHAPE_RE.findall(ins.typespec):
        for d in _dims(dims):
            res_elems *= d
        break
    ops = ins.operand_names()
    contract = 1
    if ops:
        lhs = table.get(ops[0])
        lc = ins.attr("lhs_contracting_dims")
        if lhs is not None and lc is not None:
            m = _SHAPE_RE.search(lhs.typespec)
            if m:
                ldims = _dims(m.group(2))
                for ci in _dims(lc):
                    if ci < len(ldims):
                        contract *= ldims[ci]
    return 2.0 * res_elems * contract


def _sliced_params(comp: List[Instr]) -> Dict[int, int]:
    """Parameter indices of a fusion body that only feed dynamic-slice ops,
    mapped to the slice size in bytes (the actual read)."""
    param_idx: Dict[str, int] = {}
    for ins in comp:
        if ins.op == "parameter":
            m = re.match(r"(\d+)", ins.rest)   # rest begins after "parameter("
            if m:
                param_idx[ins.name] = int(m.group(1))
    fed: Dict[str, List[Instr]] = {}
    for ins in comp:
        for o in ins.operand_names():
            if o in param_idx:
                fed.setdefault(o, []).append(ins)
    out: Dict[int, int] = {}
    for pname, users in fed.items():
        if users and all(u.op == "dynamic-slice" for u in users):
            out[param_idx[pname]] = sum(u.result_bytes for u in users)
    return out


def _instr_bytes(ins: Instr, table: Dict[str, Instr],
                 comps: Optional[Dict[str, List[Instr]]] = None) -> float:
    """HBM traffic of one instruction, XLA-cost-model style.

    Special cases that matter enormously for scan-stacked models:
      * dynamic-slice (standalone, named-fusion, or a fusion PARAMETER that
        only feeds dynamic-slices): reads only the slice, not the whole
        stacked operand.
      * dynamic-update-slice (incl. fusions): updates in place -> ~3 x the
        update operand; the aliased full buffer is NOT streamed.
    Everything else: operands + result.
    """
    name_l = ins.name
    is_dus = (ins.op == "dynamic-update-slice" or
              (ins.op == "fusion" and "dynamic-update-slice" in name_l))
    is_ds = (ins.op == "dynamic-slice" or
             (ins.op == "fusion" and "dynamic-slice" in name_l and not is_dus))
    operands = ins.operand_names()
    op_sizes = [table[o].result_bytes if o in table else 0 for o in operands]
    if is_ds:
        return 2.0 * ins.result_bytes
    if is_dus:
        if len(op_sizes) >= 2:
            return 3.0 * (sum(op_sizes) - max(op_sizes))
        return 3.0 * ins.result_bytes
    if ins.op == "fusion" and comps is not None:
        called = ins.called("calls")
        if called and called[0] in comps:
            sliced = _sliced_params(comps[called[0]])
            for i, nb in sliced.items():
                if i < len(op_sizes):
                    op_sizes[i] = min(op_sizes[i], 2 * nb)
    return ins.result_bytes + sum(op_sizes)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0                 # operand bytes (assignment spec)
    coll_wire_bytes: float = 0.0            # per-device link-crossing bytes
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    dynamic_whiles: int = 0


def _wire_bytes(kind: str, operand: float, result: float) -> float:
    """Approximate per-device bytes crossing links for one collective."""
    if kind == "all-gather":
        return max(result - operand, operand)      # receives (n-1)/n of result
    if kind == "reduce-scatter":
        return max(operand - result, result)
    if kind == "all-reduce":
        return 2.0 * operand                        # ring: reduce + broadcast
    return operand                                  # a2a / permute / ragged


def analyze_hlo(text: str) -> HloCost:
    comps, entry, table = parse_hlo(text)
    cost = HloCost()
    if not entry:
        return cost

    # worklist of (computation, multiplicity, opaque) — opaque computations
    # (fusion bodies) contribute flops but not HBM bytes
    work: List[Tuple[str, float, bool]] = [(entry, 1.0, False)]
    seen_guard = 0
    while work:
        comp, mult, opaque = work.pop()
        seen_guard += 1
        if seen_guard > 100_000:
            raise RuntimeError("HLO call graph runaway")
        for ins in comps.get(comp, []):
            if ins.op == "dot":
                cost.flops += mult * _dot_flops(ins, table)
            if ins.op == "while":
                tc = ins.trip_count
                if tc is None:
                    tc = 1
                    cost.dynamic_whiles += 1
                for b in ins.called("body"):
                    work.append((b, mult * tc, opaque))
                # condition runs tc+1 times but is negligible
            elif ins.op == "conditional":
                for b in ins.called("branch_computations") + ins.called("true_computation") + ins.called("false_computation"):
                    work.append((b, mult, opaque))
            elif ins.op in ("call", "custom-call", "fusion", "map", "reduce",
                            "reduce-window", "scatter", "sort", "all-reduce"):
                for b in (ins.called("calls") + ins.called("to_apply")):
                    # fusion/reduction subcomputations: flops-only
                    work.append((b, mult, True))

            if not opaque and ins.op not in _SKIP_BYTES_OPS:
                cost.bytes_accessed += mult * _instr_bytes(ins, table, comps)
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                nb = sum(table[o].result_bytes for o in ins.operand_names()
                         if o in table)
                cost.coll_bytes += mult * nb
                cost.coll_wire_bytes += mult * _wire_bytes(base, nb, ins.result_bytes)
                cost.coll_breakdown[base] = cost.coll_breakdown.get(base, 0) + mult * nb
    return cost
