from .analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, analyse,
                       collective_bytes, model_flops)

__all__ = ["Roofline", "analyse", "collective_bytes", "model_flops",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
