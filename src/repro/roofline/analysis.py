"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment:

    compute    = HLO_FLOPs            / (peak_FLOP/s per chip)
    memory     = HLO_bytes_accessed   / (HBM bandwidth per chip)
    collective = collective_bytes     / (ICI link bandwidth per chip)

``cost_analysis()`` of the SPMD-partitioned executable reports PER-DEVICE
flops/bytes, so the terms divide by per-chip peaks directly.  Collective
bytes are not in cost_analysis — we parse the post-optimization HLO and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute / ragged-all-to-all op.
"""

from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*(?:-start|-done)?)\((.*)$")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from post-optimization HLO.

    Post-opt HLO references operands by name only, so we first build a
    name -> result-bytes table, then resolve each collective's operands.
    """
    table: Dict[str, int] = {}
    pending = []  # (kind, operand names)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, typespec, op, rest = m.groups()
        table[name] = sum(_shape_bytes(d, dims)
                          for d, dims in _SHAPE_RE.findall(typespec))
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        # operand list = up to the matching close paren
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", rest[:end])
        pending.append((base, operands))

    out: Dict[str, int] = {}
    for kind, operands in pending:
        nbytes = sum(table.get(o, 0) for o in operands)
        out[kind] = out.get(kind, 0) + nbytes
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HLO bytes
    coll_bytes: float            # per-device collective bytes
    coll_breakdown: Dict[str, int]
    model_flops: float           # 6*N*D (train) / 2*N*D (inference), per device
    peak_memory: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio)
        for extra in ("xla_flops_once", "xla_bytes_once", "dynamic_whiles"):
            if hasattr(self, extra):
                d[extra] = getattr(self, extra)
        return d


def model_flops(cfg, shape, n_chips: int) -> float:
    """Analytic useful FLOPs per device: 6·N_active·tokens (train) or
    2·N_active·tokens (inference forward)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:
        tokens = shape.global_batch  # one new token per sequence
        mult = 2.0
    return mult * n * tokens / n_chips


def analyse(compiled, cfg, shape, arch: str, mesh_name: str,
            n_chips: int) -> Roofline:
    from .hlo_cost import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    # trip-count-aware totals (XLA's cost_analysis counts while bodies once)
    flops = hc.flops
    nbytes = hc.bytes_accessed
    coll = {k: int(v) for k, v in hc.coll_breakdown.items()}
    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                         getattr(ma, "argument_size_in_bytes", 0) +
                         getattr(ma, "output_size_in_bytes", 0) -
                         getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    rl = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name,
        flops=flops, bytes_accessed=nbytes,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=model_flops(cfg, shape, n_chips),
        peak_memory=peak_mem)
    rl.xla_flops_once = float(cost.get("flops", 0.0))
    rl.xla_bytes_once = float(cost.get("bytes accessed", 0.0))
    rl.dynamic_whiles = hc.dynamic_whiles
    return rl
