"""Dense SwiGLU feed-forward block."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Dict[str, jax.Array]:
    ks = split_keys(key, 3)
    return {
        "norm": jnp.zeros((d_model,), dtype),
        "wg": dense_init(ks[0], (d_model, d_ff), dtype),
        "wu": dense_init(ks[1], (d_model, d_ff), dtype),
        "wd": dense_init(ks[2], (d_ff, d_model), dtype, scale=d_ff ** -0.5),
    }


def mlp_forward(p: Dict[str, jax.Array], x: jax.Array, eps: float) -> jax.Array:
    h = rms_norm(x, p["norm"], eps)
    return (jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]
