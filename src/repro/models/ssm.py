"""Mamba2 SSD (state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
block-diagonal intra-chunk "attention" with decay kernel + a low-rank
inter-chunk recurrence over chunk states.  Decode is the O(1) recurrent
state update.  The intra-chunk block is the compute hotspot and has a Pallas
kernel (``repro.kernels.ssd_scan``); this module is the pure-jnp reference
used everywhere correctness matters.

State layout: ssd state (B, H, P, N); conv state (B, dconv-1, conv_dim).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba(key, cfg, dtype) -> Dict[str, jax.Array]:
    D, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    g, N = cfg.ssm_ngroups, cfg.ssm_state
    cd = conv_dim(cfg)
    d_in_proj = 2 * di + 2 * g * N + H
    ks = split_keys(key, 4)
    return {
        "norm": jnp.zeros((D,), dtype),
        "in_proj": dense_init(ks[0], (D, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_dconv, cd), dtype, scale=cfg.ssm_dconv ** -0.5),
        "conv_b": jnp.zeros((cd,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype, scale=di ** -0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: (B,S,C), w: (K,C).  ``state``: (B,K-1,C)
    carry-in from a previous segment (zeros for a fresh sequence)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros((B, S, C), jnp.float32)
    for k in range(K):
        y = y + xp[:, k:k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _expand_groups(t: jax.Array, H: int) -> jax.Array:
    """(b, ..., G, N) -> (b, ..., H, N) by repeating each group."""
    G = t.shape[-2]
    return jnp.repeat(t, H // G, axis=-2)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                use_kernel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (already softplus'ed); A: (H,) negative;
    Bm, Cm: (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))      # dt=0 => identity step
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc, cl = Sp // chunk, chunk

    xr = x.reshape(Bsz, nc, cl, H, P).astype(jnp.float32)
    dtr = dt.reshape(Bsz, nc, cl, H).astype(jnp.float32)
    Br = _expand_groups(Bm.reshape(Bsz, nc, cl, -1, N), H).astype(jnp.float32)
    Cr = _expand_groups(Cm.reshape(Bsz, nc, cl, -1, N), H).astype(jnp.float32)

    dA = dtr * A                                           # (b,nc,cl,h), <= 0
    cum = jnp.cumsum(dA, axis=2)
    xw = xr * dtr[..., None]                               # dt-weighted input

    # ---- intra-chunk (block-diagonal) term -------------------------------
    if use_kernel:
        from ..kernels import ops as kops
        y_intra, states = kops.ssd_intra(xw, cum, Br, Cr)
    else:
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,nc,i,j,h)
        ii, jj = jnp.arange(cl)[:, None], jnp.arange(cl)[None, :]
        L = jnp.where((ii >= jj)[None, None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", CB * L, xw)
        # chunk-final states: decay from position j to end of chunk
        decay = jnp.exp(cum[:, :, -1:, :] - cum)               # (b,nc,cl,h)
        states = jnp.einsum("bcjhn,bcjhp->bchpn", Br * decay[..., None], xw)

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (b,nc,h)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        st_k, dec_k = inp
        s_out = s * dec_k[0][:, :, None, None] + st_k
        return s_out, s                                     # emit carry-IN

    (s_final, prev_states) = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)[:, None]))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Cr * jnp.exp(cum)[..., None], prev_states)
    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y, s_final


def mamba_prefill(p: Dict[str, jax.Array], x: jax.Array, cfg, *,
                  conv_state: Optional[jax.Array] = None,
                  ssd_state: Optional[jax.Array] = None,
                  use_kernel: bool = False):
    """x: (B,S,D) -> (out (B,S,D), (conv_state, ssd_state))."""
    B, S, D = x.shape
    di, H, P = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, N, K = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_dconv
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * N], axis=-1)
    new_conv_state = jnp.concatenate(
        [jnp.zeros((B, max(0, K - 1 - S), xBC.shape[-1]), xBC.dtype),
         xBC[:, max(0, S - (K - 1)):]], axis=1) if K > 1 else None
    if conv_state is not None and K > 1:
        # stitch carry-in for continued sequences
        new_conv_state = jnp.concatenate([conv_state, xBC], axis=1)[:, -(K - 1):]
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state))
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, g, N)
    Cm = Cm.reshape(B, S, g, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, s_final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                             init_state=ssd_state, use_kernel=use_kernel)
    y = y + (p["D"][:, None] * xs.astype(jnp.float32))
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, (new_conv_state, s_final.astype(jnp.float32))


def mamba_decode(p: Dict[str, jax.Array], x: jax.Array, cfg, *,
                 conv_state: jax.Array, ssd_state: jax.Array):
    """One-token recurrent step.  x: (B,1,D).

    Returns (out (B,1,D), (conv_state, ssd_state))."""
    B, _, D = x.shape
    di, H, P = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, N, K = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_dconv
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h[:, 0] @ p["in_proj"]                          # (B, d_in_proj)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * N], axis=-1)
    conv_in = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (B,K,cd)
    new_conv_state = conv_in[:, 1:]
    y_conv = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(y_conv)
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * N], axis=-1)
    xs = xs.reshape(B, H, P)
    Bm = _expand_groups(Bm.reshape(B, g, N), H)              # (B,H,N)
    Cm = _expand_groups(Cm.reshape(B, g, N), H)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                  # (B,H)
    xw = xs.astype(jnp.float32) * dt[..., None]              # (B,H,P)
    new_state = (ssd_state * decay[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xw, Bm))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cm) + p["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, (new_conv_state, new_state)
