"""Model assembly: init / train-forward / prefill / decode for all families.

Public API (pure functions over param pytrees):
  init_params(cfg, key)                        -> params
  forward_train(params, tokens, cfg, ...)      -> (logits, aux_loss)
  loss_fn(params, batch, cfg, ...)             -> (loss, metrics)
  init_cache(cfg, batch, max_len, ...)         -> cache pytree
  prefill(params, tokens, cfg, ...)            -> (logits, cache)
  decode_step(params, tokens, positions, cache, cfg, ...) -> (logits, cache)

Caches (per family):
  attn:   {"k","v": (L,B,Smax,K,Dh)}  [+ {"ck","cv": (L,B,Sv,K,Dh)} for vlm]
  ssm:    {"conv": (L,B,K-1,convdim), "ssd": (L,B,H,P,N)}
  hybrid: ssm caches (L=n_mamba) + ring KV for the shared attention block:
          {"ak","av": (n_groups? no — single shared block per application is
           re-applied; its cache is (n_apps,B,W,K,Dh))}
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_prefill, init_attn
from .blocks import layer_metadata, stacked_init
from .common import dense_init, rms_norm, split_keys
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .ssm import conv_dim, init_mamba, mamba_decode, mamba_prefill

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# Embedding rows are padded so the vocabulary always divides the model axis
# (Megatron-style): granite's 49155 would otherwise force either a
# d_model-sharded embedding (=> a (B,S,V) partial-sum logits all-reduce,
# 12.9 GB/step) or a replicated unembed (=> 16x duplicated logits compute).
# Pad logits are masked to -inf in _unembed.  §Perf iteration D2.
VOCAB_PAD = 512


def padded_vocab(cfg) -> int:
    return -(-cfg.vocab // VOCAB_PAD) * VOCAB_PAD


def init_params(cfg, key: jax.Array) -> Params:
    dtype = _dtype(cfg)
    ks = split_keys(key, 8)
    D = cfg.d_model
    params: Params = {
        "embed": dense_init(ks[0], (padded_vocab(cfg), D), dtype, scale=0.02),
        "final_norm": jnp.zeros((D,), dtype),
    }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(ks[1], (cfg.vision_dim, D), dtype)

    if cfg.family == "ssm":
        params["layers"] = {"mamba": stacked_init(
            lambda k: init_mamba(k, cfg, dtype), ks[2], cfg.n_layers)}
    elif cfg.family == "hybrid":
        params["layers"] = {"mamba": stacked_init(
            lambda k: init_mamba(k, cfg, dtype), ks[2], cfg.n_layers)}
        params["shared_attn"] = {
            "attn": init_attn(ks[3], cfg, dtype),
            "mlp": init_mlp(ks[4], D, cfg.d_ff, dtype),
        }
    else:
        n_scan = cfg.n_layers - cfg.first_k_dense
        layers: Params = {"attn": stacked_init(
            lambda k: init_attn(k, cfg, dtype), ks[2], n_scan)}
        if cfg.is_moe:
            layers["ffn"] = stacked_init(
                lambda k: init_moe(k, cfg, dtype), ks[3], n_scan)
        else:
            layers["ffn"] = stacked_init(
                lambda k: init_mlp(k, D, cfg.d_ff, dtype), ks[3], n_scan)
        params["layers"] = layers
        if cfg.first_k_dense:
            d0 = []
            for i, k in enumerate(split_keys(ks[5], cfg.first_k_dense)):
                k1, k2 = jax.random.split(k)
                d0.append({"attn": init_attn(k1, cfg, dtype),
                           "mlp": init_mlp(k2, D, cfg.d_ff, dtype)})
            params["dense0"] = d0
    return params


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(params, x, cfg):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["embed"].T).astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp != cfg.vocab:   # mask vocab-padding rows
        pad_mask = jnp.arange(Vp) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def _vision(params, vision_emb, cfg):
    if cfg.family != "vlm":
        return None
    return (vision_emb.astype(_dtype(cfg)) @ params["vision_proj"])


def _hybrid_groups(cfg) -> Tuple[int, int, int]:
    """(n_groups, group_size, remainder) for zamba2-style layouts."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


# --------------------------------------------------------------------------
# pattern-split serving path (§Perf iteration B)
#
# Archs with a periodic special layer (gemma3: 1 global per 6; llama-vision:
# 1 cross per 5) serve with SPLIT layer stacks: the frequent "local" layers
# carry only a window-sized ring cache (gemma3) or no extra cache (vlm self
# layers stay full-length), while the rare special layers carry their own
# full-length / vision-length cache.  This removes the uniform-stack waste
# (a 500k cache allocated for 1024-window layers; a 32k self-cache allocated
# for cross layers that never self-attend).
# --------------------------------------------------------------------------

def _pattern(cfg) -> int:
    """Pattern period (0 = no pattern split)."""
    if cfg.family in ("ssm", "hybrid") or cfg.first_k_dense:
        return 0
    if cfg.global_every:
        return cfg.global_every
    if cfg.cross_every:
        return cfg.cross_every
    return 0


def _pattern_split(cfg, layers):
    """Split the uniform layer stack into (local_stack, special_stack)."""
    import numpy as np
    kinds = cfg.layer_kinds()
    loc = np.asarray([i for i, k in enumerate(kinds)
                      if k in ("local", "attn")], np.int32)
    spe = np.asarray([i for i, k in enumerate(kinds)
                      if k in ("global", "cross")], np.int32)
    ltree = jax.tree.map(lambda a: a[loc], layers)
    stree = jax.tree.map(lambda a: a[spe], layers)
    return ltree, stree, len(loc), len(spe)


def _group_stack(tree, n_groups: int, group: int):
    return jax.tree.map(
        lambda a: a[: n_groups * group].reshape(n_groups, group, *a.shape[1:]), tree)


def _tail_stack(tree, n_head: int):
    return jax.tree.map(lambda a: a[n_head:], tree)


# --------------------------------------------------------------------------
# train forward
# --------------------------------------------------------------------------

def forward_train(params: Params, tokens: jax.Array, cfg, *,
                  vision_emb: Optional[jax.Array] = None,
                  moe_mode: str = "scatter", use_kernel: bool = False,
                  remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits (B,S,V) fp32, aux_loss scalar)."""
    B, S = tokens.shape
    x = _embed(params, tokens, cfg)
    vis = _vision(params, vision_emb, cfg)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def body(carry, p_l):
            x = carry
            out, _ = mamba_prefill(p_l, x, cfg, use_kernel=use_kernel)
            return x + out, None
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"]["mamba"])
        return _unembed(params, x, cfg), aux0

    if cfg.family == "hybrid":
        n_groups, gsize, rem = _hybrid_groups(cfg)
        shared = params["shared_attn"]

        def mamba_body(carry, p_l):
            x = carry
            out, _ = mamba_prefill(p_l, x, cfg, use_kernel=use_kernel)
            return x + out, None
        if remat:
            mamba_body = jax.checkpoint(mamba_body)

        def shared_block(x):
            win = jnp.asarray(cfg.window if cfg.window else -1, jnp.int32)
            x = x + attn_prefill(shared["attn"], x, cfg, window=win)
            x = x + mlp_forward(shared["mlp"], x, cfg.norm_eps)
            return x

        def group_body(carry, p_group):
            x = carry
            x, _ = jax.lax.scan(mamba_body, x, p_group)
            return shared_block(x), None

        grouped = _group_stack(params["layers"]["mamba"], n_groups, gsize)
        x, _ = jax.lax.scan(group_body, x, grouped)
        if rem:
            tail = _tail_stack(params["layers"]["mamba"], n_groups * gsize)
            x, _ = jax.lax.scan(mamba_body, x, tail)
        return _unembed(params, x, cfg), aux0

    if _pattern(cfg) and cfg.global_every:
        # windowed pattern archs train with BANDED local attention
        # (iteration C): local layers only visit kv blocks inside the window
        ltree, stree, n_loc, n_spe = _pattern_split(cfg, params["layers"])
        p = _pattern(cfg)
        per_group = p - 1
        rem = n_loc - n_spe * per_group
        positions = jnp.arange(S, dtype=jnp.int32)

        def local_body(x, p_l):
            x = x + attn_prefill(p_l["attn"], x, cfg, positions=positions,
                                 static_window=cfg.window)
            x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
            return x, None

        def group_body(x, xs):
            p_group, p_s = xs
            x, _ = jax.lax.scan(local_body, x, p_group)
            x = x + attn_prefill(p_s["attn"], x, cfg, positions=positions)
            x = x + mlp_forward(p_s["ffn"], x, cfg.norm_eps)
            return x, None

        if remat:
            local_body = jax.checkpoint(local_body)
            group_body = jax.checkpoint(group_body)
        grouped = jax.tree.map(
            lambda a: a[: n_spe * per_group].reshape(n_spe, per_group, *a.shape[1:]),
            ltree)
        x, _ = jax.lax.scan(group_body, x, (grouped, stree))
        if rem:
            tail = jax.tree.map(lambda a: a[n_spe * per_group:], ltree)
            x, _ = jax.lax.scan(local_body, x, tail)
        return _unembed(params, x, cfg), aux0

    # ---- attention families ----------------------------------------------
    meta = layer_metadata(cfg)
    positions = jnp.arange(S, dtype=jnp.int32)

    for d0 in params.get("dense0", []):
        x = x + attn_prefill(d0["attn"], x, cfg, positions=positions)
        x = x + mlp_forward(d0["mlp"], x, cfg.norm_eps)

    k0 = cfg.first_k_dense

    def body(carry, xs):
        x, aux = carry
        p_l, window_l, is_cross_l = xs

        def self_branch(x):
            return attn_prefill(p_l["attn"], x, cfg, window=window_l,
                                positions=positions)

        if cfg.cross_every:
            def cross_branch(x):
                return attn_prefill(p_l["attn"], x, cfg, kv_src=vis,
                                    positions=positions)
            attn_out = jax.lax.cond(is_cross_l, cross_branch, self_branch, x)
        else:
            attn_out = self_branch(x)
        x = x + attn_out

        if cfg.is_moe:
            y, a = moe_forward(p_l["ffn"], x, cfg, mode=moe_mode)
            x = x + y
            aux = aux + a
        else:
            x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(
        body, (x, aux0),
        (params["layers"], meta["window"][k0:], meta["is_cross"][k0:]))
    return _unembed(params, x, cfg), aux


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg, *,
            moe_mode: str = "scatter", use_kernel: bool = False,
            remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_train(
        params, batch["tokens"], cfg,
        vision_emb=batch.get("vision_emb"),
        moe_mode=moe_mode, use_kernel=use_kernel, remat=remat)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# cache
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or _dtype(cfg)
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    cache: Dict[str, jax.Array] = {}
    if cfg.family == "ssm":
        cache["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_dconv - 1, conv_dim(cfg)), dtype)
        cache["ssd"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                                  cfg.ssm_headdim, cfg.ssm_state), jnp.float32)
    elif cfg.family == "hybrid":
        n_groups, _, _ = _hybrid_groups(cfg)
        W = min(max_len, cfg.window) if cfg.window else max_len
        cache["conv"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_dconv - 1, conv_dim(cfg)), dtype)
        cache["ssd"] = jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                                  cfg.ssm_headdim, cfg.ssm_state), jnp.float32)
        cache["ak"] = jnp.zeros((n_groups, batch, W, K, Dh), dtype)
        cache["av"] = jnp.zeros((n_groups, batch, W, K, Dh), dtype)
    elif _pattern(cfg):
        kinds = cfg.layer_kinds()
        n_loc = sum(1 for k in kinds if k in ("local", "attn"))
        n_spe = sum(1 for k in kinds if k in ("global", "cross"))
        W = min(max_len, cfg.window) if cfg.global_every else max_len
        S_spec = max_len if cfg.global_every else cfg.vision_seq
        cache["lk"] = jnp.zeros((n_loc, batch, W, K, Dh), dtype)
        cache["lv"] = jnp.zeros((n_loc, batch, W, K, Dh), dtype)
        cache["sk"] = jnp.zeros((n_spe, batch, S_spec, K, Dh), dtype)
        cache["sv"] = jnp.zeros((n_spe, batch, S_spec, K, Dh), dtype)
    else:
        L = cfg.n_layers - cfg.first_k_dense
        cache["k"] = jnp.zeros((L, batch, max_len, K, Dh), dtype)
        cache["v"] = jnp.zeros((L, batch, max_len, K, Dh), dtype)
        if cfg.first_k_dense:
            cache["k0"] = jnp.zeros((cfg.first_k_dense, batch, max_len, K, Dh), dtype)
            cache["v0"] = jnp.zeros((cfg.first_k_dense, batch, max_len, K, Dh), dtype)
        if cfg.family == "vlm":
            cache["ck"] = jnp.zeros((L, batch, cfg.vision_seq, K, Dh), dtype)
            cache["cv"] = jnp.zeros((L, batch, cfg.vision_seq, K, Dh), dtype)
    return cache


# --------------------------------------------------------------------------
# pattern-split prefill / decode (iteration B)
# --------------------------------------------------------------------------

def _ring_pack(k: jax.Array, W: int) -> jax.Array:
    """Pack the last W positions of (B,S,...) into ring slots pos % W."""
    B, S = k.shape[:2]
    take = k[:, -W:]
    pos = jnp.arange(max(0, S - W), S, dtype=jnp.int32)
    slots = pos % W
    out = jnp.zeros((B, W) + k.shape[2:], k.dtype)
    return out.at[:, slots].set(take)


def _prefill_pattern(params, tokens, cfg, max_len, vis, moe_mode):
    B, S = tokens.shape
    x = _embed(params, tokens, cfg)
    cache = init_cache(cfg, B, max_len)
    ltree, stree, n_loc, n_spe = _pattern_split(cfg, params["layers"])
    p = _pattern(cfg)
    per_group = p - 1
    rem = n_loc - n_spe * per_group
    W = cache["lk"].shape[2]
    positions = jnp.arange(S, dtype=jnp.int32)
    win = jnp.asarray(cfg.window if cfg.global_every else -1, jnp.int32)

    def pad_kv(k):
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    def local_body(x, p_l):
        out, (k, v) = attn_prefill(
            p_l["attn"], x, cfg, window=win, positions=positions,
            return_kv=True,
            static_window=cfg.window if cfg.global_every else None)
        x = x + out
        x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
        if cfg.global_every:
            return x, (_ring_pack(k, W), _ring_pack(v, W))
        return x, (pad_kv(k), pad_kv(v))

    def special_body(x, p_s):
        if cfg.global_every:
            out, (k, v) = attn_prefill(p_s["attn"], x, cfg,
                                       positions=positions, return_kv=True)
            k, v = pad_kv(k), pad_kv(v)
        else:
            out, (k, v) = attn_prefill(p_s["attn"], x, cfg, kv_src=vis,
                                       positions=positions, return_kv=True)
        x = x + out
        x = x + mlp_forward(p_s["ffn"], x, cfg.norm_eps)
        return x, (k, v)

    def group_body(x, xs):
        p_group, p_s = xs
        x, lkv = jax.lax.scan(local_body, x, p_group)
        x, skv = special_body(x, p_s)
        return x, (lkv, skv)

    grouped = jax.tree.map(
        lambda a: a[: n_spe * per_group].reshape(n_spe, per_group, *a.shape[1:]),
        ltree)
    x, ((lk, lv), (sk, sv)) = jax.lax.scan(group_body, x, (grouped, stree))
    lk = lk.reshape(n_spe * per_group, *lk.shape[2:])
    lv = lv.reshape(n_spe * per_group, *lv.shape[2:])
    if rem:
        tail = jax.tree.map(lambda a: a[n_spe * per_group:], ltree)
        x, (lk_t, lv_t) = jax.lax.scan(local_body, x, tail)
        lk = jnp.concatenate([lk, lk_t], 0)
        lv = jnp.concatenate([lv, lv_t], 0)
    cache["lk"], cache["lv"] = lk, lv
    cache["sk"], cache["sv"] = sk, sv
    return _unembed(params, x[:, -1:], cfg)[:, 0], cache


def _decode_pattern(params, tokens, positions, cache, cfg, moe_mode):
    B = tokens.shape[0]
    x = _embed(params, tokens, cfg)
    new_cache = dict(cache)
    ltree, stree, n_loc, n_spe = _pattern_split(cfg, params["layers"])
    p = _pattern(cfg)
    per_group = p - 1
    rem = n_loc - n_spe * per_group
    W = cache["lk"].shape[2]
    win = jnp.asarray(cfg.window if cfg.global_every else -1, jnp.int32)

    if cfg.global_every:
        slots = jnp.arange(W, dtype=jnp.int32)
        p_abs = positions[:, None] - ((positions[:, None] - slots) % W)
        cache_pos = jnp.where(p_abs < 0, 2 ** 30, p_abs)
        ring = W
    else:
        cache_pos, ring = None, None

    def local_body(x, xs):
        p_l, k_l, v_l = xs
        out, k, v = attn_decode(p_l["attn"], x, cfg, k_cache=k_l, v_cache=v_l,
                                positions=positions, window=win,
                                cache_positions=cache_pos, ring=ring)
        x = x + out
        x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
        return x, (k, v)

    def special_body(x, p_s, k_s, v_s):
        if cfg.global_every:
            out, k, v = attn_decode(p_s["attn"], x, cfg, k_cache=k_s,
                                    v_cache=v_s, positions=positions)
        else:
            out, _, _ = attn_decode(p_s["attn"], x, cfg, k_cache=k_s,
                                    v_cache=v_s, positions=positions,
                                    cross=True)
            k, v = k_s, v_s
        x = x + out
        x = x + mlp_forward(p_s["ffn"], x, cfg.norm_eps)
        return x, (k, v)

    def group_body(x, xs):
        p_group, p_s, lk_g, lv_g, sk_g, sv_g = xs
        x, lkv = jax.lax.scan(local_body, x, (p_group, lk_g, lv_g))
        x, (sk, sv) = special_body(x, p_s, sk_g, sv_g)
        return x, (lkv, (sk, sv))

    grouped = jax.tree.map(
        lambda a: a[: n_spe * per_group].reshape(n_spe, per_group, *a.shape[1:]),
        ltree)
    lk_g = cache["lk"][: n_spe * per_group].reshape(n_spe, per_group, *cache["lk"].shape[1:])
    lv_g = cache["lv"][: n_spe * per_group].reshape(n_spe, per_group, *cache["lv"].shape[1:])
    x, ((lk, lv), (sk, sv)) = jax.lax.scan(
        group_body, x, (grouped, stree, lk_g, lv_g, cache["sk"], cache["sv"]))
    lk = lk.reshape(n_spe * per_group, *lk.shape[2:])
    lv = lv.reshape(n_spe * per_group, *lv.shape[2:])
    if rem:
        tail = jax.tree.map(lambda a: a[n_spe * per_group:], ltree)
        x, (lk_t, lv_t) = jax.lax.scan(
            local_body, x,
            (tail, cache["lk"][n_spe * per_group:], cache["lv"][n_spe * per_group:]))
        lk = jnp.concatenate([lk, lk_t], 0)
        lv = jnp.concatenate([lv, lv_t], 0)
    new_cache["lk"], new_cache["lv"] = lk, lv
    new_cache["sk"], new_cache["sv"] = sk, sv
    return _unembed(params, x, cfg)[:, 0], new_cache


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def prefill(params: Params, tokens: jax.Array, cfg, *,
            max_len: Optional[int] = None,
            vision_emb: Optional[jax.Array] = None,
            moe_mode: str = "scatter", use_kernel: bool = False
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence prefill.  Returns (last-token logits (B,V), cache)."""
    B, S = tokens.shape
    max_len = max_len or S
    if max_len < S:
        raise ValueError("cache must hold at least the prompt")
    vis = _vision(params, vision_emb, cfg)
    if _pattern(cfg):
        return _prefill_pattern(params, tokens, cfg, max_len, vis, moe_mode)
    x = _embed(params, tokens, cfg)
    cache = init_cache(cfg, B, max_len)

    def pad_kv(k):  # (B,S,K,Dh) -> (B,max_len,K,Dh)
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    if cfg.family in ("ssm", "hybrid"):
        def mamba_body(carry, p_l):
            x = carry
            out, (cs, ss) = mamba_prefill(p_l, x, cfg, use_kernel=use_kernel)
            return x + out, (cs, ss)

        if cfg.family == "ssm":
            x, (cs, ss) = jax.lax.scan(mamba_body, x, params["layers"]["mamba"])
            cache["conv"], cache["ssd"] = cs, ss
        else:
            n_groups, gsize, rem = _hybrid_groups(cfg)
            shared = params["shared_attn"]
            W = cache["ak"].shape[2]
            win = jnp.asarray(cfg.window if cfg.window else -1, jnp.int32)

            def shared_block(x):
                out, (k, v) = attn_prefill(shared["attn"], x, cfg, window=win,
                                           return_kv=True)
                x = x + out
                x = x + mlp_forward(shared["mlp"], x, cfg.norm_eps)
                # ring-buffer the last W positions: slot = pos % W
                kv_slice = (k[:, -W:], v[:, -W:])
                pos = jnp.arange(max(0, S - W), S, dtype=jnp.int32)
                slots = pos % W
                ak = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(kv_slice[0])
                av = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(kv_slice[1])
                return x, (ak, av)

            def group_body(carry, p_group):
                x = carry
                x, (cs, ss) = jax.lax.scan(mamba_body, x, p_group)
                x, (ak, av) = shared_block(x)
                return x, ((cs, ss), (ak, av))

            grouped = _group_stack(params["layers"]["mamba"], n_groups, gsize)
            x, ((cs, ss), (ak, av)) = jax.lax.scan(group_body, x, grouped)
            cs = jax.tree.map(lambda a: a.reshape(n_groups * gsize, *a.shape[2:]), cs)
            ss = jax.tree.map(lambda a: a.reshape(n_groups * gsize, *a.shape[2:]), ss)
            if rem:
                tail = _tail_stack(params["layers"]["mamba"], n_groups * gsize)
                x, (cs_t, ss_t) = jax.lax.scan(mamba_body, x, tail)
                cs = jnp.concatenate([cs, cs_t], 0)
                ss = jnp.concatenate([ss, ss_t], 0)
            cache["conv"], cache["ssd"] = cs, ss
            cache["ak"], cache["av"] = ak, av
        return _unembed(params, x[:, -1:], cfg)[:, 0], cache

    # ---- attention families -------------------------------------------------
    meta = layer_metadata(cfg)
    positions = jnp.arange(S, dtype=jnp.int32)
    k0 = cfg.first_k_dense
    for i, d0 in enumerate(params.get("dense0", [])):
        out, (k, v) = attn_prefill(d0["attn"], x, cfg, positions=positions,
                                   return_kv=True)
        x = x + out
        x = x + mlp_forward(d0["mlp"], x, cfg.norm_eps)
        cache["k0"] = cache["k0"].at[i].set(pad_kv(k))
        cache["v0"] = cache["v0"].at[i].set(pad_kv(v))

    K, Dh = cfg.n_kv_heads, cfg.head_dim
    Sv = cfg.vision_seq

    def body(x, xs):
        p_l, window_l, is_cross_l = xs

        def self_branch(x):
            out, (k, v) = attn_prefill(p_l["attn"], x, cfg, window=window_l,
                                       positions=positions, return_kv=True)
            ck = jnp.zeros((B, Sv, K, Dh), x.dtype) if cfg.family == "vlm" else None
            return out, pad_kv(k), pad_kv(v), ck, ck

        if cfg.cross_every:
            def cross_branch(x):
                out, (ck, cv) = attn_prefill(p_l["attn"], x, cfg, kv_src=vis,
                                             positions=positions, return_kv=True)
                z = jnp.zeros((B, max_len, K, Dh), x.dtype)
                return out, z, z, ck, cv
            out, k, v, ck, cv = jax.lax.cond(is_cross_l, cross_branch, self_branch, x)
        else:
            out, k, v, ck, cv = self_branch(x)
        x = x + out
        if cfg.is_moe:
            y, _ = moe_forward(p_l["ffn"], x, cfg, mode=moe_mode)
            x = x + y
        else:
            x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
        ys = (k, v) + ((ck, cv) if cfg.family == "vlm" else ())
        return x, ys

    x, ys = jax.lax.scan(
        body, x, (params["layers"], meta["window"][k0:], meta["is_cross"][k0:]))
    cache["k"], cache["v"] = ys[0], ys[1]
    if cfg.family == "vlm":
        cache["ck"], cache["cv"] = ys[2], ys[3]
    return _unembed(params, x[:, -1:], cfg)[:, 0], cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode_step(params: Params, tokens: jax.Array, positions: jax.Array,
                cache: Dict[str, jax.Array], cfg, *,
                moe_mode: str = "scatter"
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step.  tokens: (B,1); positions: (B,) index of the new
    token.  Returns (logits (B,V) fp32, updated cache)."""
    if _pattern(cfg):
        return _decode_pattern(params, tokens, positions, cache, cfg, moe_mode)
    B = tokens.shape[0]
    x = _embed(params, tokens, cfg)
    new_cache = dict(cache)

    if cfg.family in ("ssm", "hybrid"):
        def mamba_body(carry, xs):
            x = carry
            p_l, cs_l, ss_l = xs
            out, (cs, ss) = mamba_decode(p_l, x, cfg, conv_state=cs_l, ssd_state=ss_l)
            return x + out, (cs, ss)

        if cfg.family == "ssm":
            x, (cs, ss) = jax.lax.scan(
                mamba_body, x,
                (params["layers"]["mamba"], cache["conv"], cache["ssd"]))
            new_cache["conv"], new_cache["ssd"] = cs, ss
        else:
            n_groups, gsize, rem = _hybrid_groups(cfg)
            shared = params["shared_attn"]
            W = cache["ak"].shape[2]
            win = jnp.asarray(cfg.window if cfg.window else -1, jnp.int32)
            # absolute position held by each ring slot (see DESIGN notes)
            slots = jnp.arange(W, dtype=jnp.int32)
            p_abs = positions[:, None] - ((positions[:, None] - slots) % W)
            cache_pos = jnp.where(p_abs < 0, 2 ** 30, p_abs)      # (B,W)

            def shared_block(x, ak, av):
                # write new kv into ring slot positions % W
                out, ak, av = attn_decode(
                    shared["attn"], x, cfg, k_cache=ak, v_cache=av,
                    positions=positions, window=win, cache_positions=cache_pos,
                    ring=W)
                x = x + out
                x = x + mlp_forward(shared["mlp"], x, cfg.norm_eps)
                return x, ak, av

            def group_body(carry, xs):
                x = carry
                p_group, cs_g, ss_g, ak_g, av_g = xs
                x, (cs, ss) = jax.lax.scan(mamba_body, x, (p_group, cs_g, ss_g))
                x, ak, av = shared_block(x, ak_g, av_g)
                return x, (cs, ss, ak, av)

            grouped = _group_stack(params["layers"]["mamba"], n_groups, gsize)
            cs_g = jax.tree.map(lambda a: a[:n_groups * gsize].reshape(
                n_groups, gsize, *a.shape[1:]), cache["conv"])
            ss_g = jax.tree.map(lambda a: a[:n_groups * gsize].reshape(
                n_groups, gsize, *a.shape[1:]), cache["ssd"])
            x, (cs, ss, ak, av) = jax.lax.scan(
                group_body, x, (grouped, cs_g, ss_g, cache["ak"], cache["av"]))
            cs = cs.reshape(n_groups * gsize, *cs.shape[2:])
            ss = ss.reshape(n_groups * gsize, *ss.shape[2:])
            if rem:
                tail = _tail_stack(params["layers"]["mamba"], n_groups * gsize)
                x, (cs_t, ss_t) = jax.lax.scan(
                    mamba_body, x,
                    (tail, cache["conv"][n_groups * gsize:], cache["ssd"][n_groups * gsize:]))
                cs = jnp.concatenate([cs, cs_t], 0)
                ss = jnp.concatenate([ss, ss_t], 0)
            new_cache["conv"], new_cache["ssd"] = cs, ss
            new_cache["ak"], new_cache["av"] = ak, av
        return _unembed(params, x, cfg)[:, 0], new_cache

    # ---- attention families --------------------------------------------------
    meta = layer_metadata(cfg)
    k0 = cfg.first_k_dense
    for i, d0 in enumerate(params.get("dense0", [])):
        out, k, v = attn_decode(d0["attn"], x, cfg, k_cache=cache["k0"][i],
                                v_cache=cache["v0"][i], positions=positions)
        x = x + out
        x = x + mlp_forward(d0["mlp"], x, cfg.norm_eps)
        new_cache["k0"] = new_cache["k0"].at[i].set(k)
        new_cache["v0"] = new_cache["v0"].at[i].set(v)

    def body(x, xs):
        if cfg.family == "vlm":
            p_l, window_l, is_cross_l, k_l, v_l, ck_l, cv_l = xs
        else:
            p_l, window_l, is_cross_l, k_l, v_l = xs

        def self_branch(x):
            out, k, v = attn_decode(p_l["attn"], x, cfg, k_cache=k_l,
                                    v_cache=v_l, positions=positions,
                                    window=window_l)
            return out, k, v

        if cfg.cross_every:
            def cross_branch(x):
                out, _, _ = attn_decode(p_l["attn"], x, cfg, k_cache=ck_l,
                                        v_cache=cv_l, positions=positions,
                                        cross=True)
                return out, k_l, v_l
            out, k, v = jax.lax.cond(is_cross_l, cross_branch, self_branch, x)
        else:
            out, k, v = self_branch(x)
        x = x + out
        if cfg.is_moe:
            y, _ = moe_forward(p_l["ffn"], x, cfg, mode=moe_mode)
            x = x + y
        else:
            x = x + mlp_forward(p_l["ffn"], x, cfg.norm_eps)
        ys = (k, v)
        return x, ys

    xs = (params["layers"], meta["window"][k0:], meta["is_cross"][k0:],
          cache["k"], cache["v"])
    if cfg.family == "vlm":
        xs = xs + (cache["ck"], cache["cv"])
    x, (k, v) = jax.lax.scan(body, x, xs)
    new_cache["k"], new_cache["v"] = k, v
    return _unembed(params, x, cfg)[:, 0], new_cache
