"""Shared model building blocks: norms, RoPE, initializers."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))
