"""Sharding rules: PartitionSpecs for params, batches and caches.

Divisibility-driven rules (DESIGN.md §5): tensors shard on the ``model``
axis only when the relevant *logical* unit (attention heads, experts, FFN
columns) divides evenly; otherwise they replicate — e.g. gemma3's 4 heads
replicate on a 16-way model axis while its FFN shards, and GQA KV
projections replicate whenever n_kv_heads < model parallelism (the same KV
replication the paper handles in §4).

Batch axes: ('pod','data') when present.  Decode caches shard batch over the
data axes when divisible; for long_500k (batch=1) the KV cache shards its
SEQUENCE axis over 'data' instead (flash-decode style).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..comm.context import data_axes
from . import model as M


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1) if name in mesh.axis_names else 1


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def param_spec_tree(cfg, mesh: Mesh):
    """PartitionSpec pytree matching ``init_params(cfg, key)``."""
    m = _axis_size(mesh, "model")
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    shard_q = _div(H, m)
    shard_kv = _div(K, m)
    shard_ff = _div(cfg.d_ff, m)
    shard_ffe = _div(cfg.d_ff_expert, m)
    shard_exp = _div(cfg.n_routed, m)
    shard_vocab = _div(M.padded_vocab(cfg), m)
    shard_dmodel = _div(cfg.d_model, m)
    shard_di = _div(cfg.d_inner, m) if cfg.is_ssm else False
    shard_shared_ff = _div(cfg.n_shared * cfg.d_ff_expert, m)

    def attn_spec(stacked: bool):
        lead = (None,) if stacked else ()
        return {
            "norm": P(*lead, None),
            "wq": P(*lead, None, "model") if shard_q else P(*lead, None, None),
            "wk": P(*lead, None, "model") if shard_kv else P(*lead, None, None),
            "wv": P(*lead, None, "model") if shard_kv else P(*lead, None, None),
            "wo": P(*lead, "model", None) if shard_q else P(*lead, None, None),
        }

    def mlp_spec(stacked: bool):
        lead = (None,) if stacked else ()
        sp = "model" if shard_ff else None
        return {
            "norm": P(*lead, None),
            "wg": P(*lead, None, sp),
            "wu": P(*lead, None, sp),
            "wd": P(*lead, sp, None),
        }

    def moe_spec(stacked: bool):
        lead = (None,) if stacked else ()
        se = "model" if shard_exp else None
        spec = {
            "norm": P(*lead, None),
            "router": P(*lead, None, None),
            "wg": P(*lead, se, None, None),
            "wu": P(*lead, se, None, None),
            "wd": P(*lead, se, None, None),
        }
        if cfg.n_shared:
            ss = "model" if shard_shared_ff else None
            spec.update({"swg": P(*lead, None, ss), "swu": P(*lead, None, ss),
                         "swd": P(*lead, ss, None)})
        return spec

    def mamba_spec(stacked: bool):
        lead = (None,) if stacked else ()
        sd = "model" if shard_dmodel else None
        si = "model" if shard_di else None
        return {
            "norm": P(*lead, None),
            "in_proj": P(*lead, sd, None),     # row-parallel
            "conv_w": P(*lead, None, None),
            "conv_b": P(*lead, None),
            "dt_bias": P(*lead, None),
            "A_log": P(*lead, None),
            "D": P(*lead, None),
            "out_norm": P(*lead, None),
            "out_proj": P(*lead, si, None),    # row-parallel
        }

    specs: Dict[str, Any] = {
        # vocab-sharded when divisible; otherwise REPLICATED — d_model
        # sharding makes every unembed a partial-sum and forces a (B,S,V)
        # logits all-reduce (iteration D: 12.9 GB/step on granite-3-8b)
        "embed": P("model", None) if shard_vocab else P(None, None),  # padded vocab
        "final_norm": P(None),
    }
    if cfg.family == "vlm":
        specs["vision_proj"] = P(None, "model") if shard_dmodel else P(None, None)

    if cfg.family in ("ssm", "hybrid"):
        specs["layers"] = {"mamba": mamba_spec(stacked=True)}
        if cfg.family == "hybrid":
            specs["shared_attn"] = {"attn": attn_spec(False), "mlp": mlp_spec(False)}
    else:
        specs["layers"] = {
            "attn": attn_spec(True),
            "ffn": moe_spec(True) if cfg.is_moe else mlp_spec(True),
        }
        if cfg.first_k_dense:
            specs["dense0"] = [
                {"attn": attn_spec(False), "mlp": mlp_spec(False)}
                for _ in range(cfg.first_k_dense)]
    return specs


def batch_spec_tree(cfg, mesh: Mesh, shape) -> Dict[str, P]:
    """Specs for the data batch of a given InputShape."""
    daxes = data_axes(mesh)
    nd = math.prod(_axis_size(mesh, a) for a in daxes)
    bspec = daxes if _div(shape.global_batch, nd) else None
    specs: Dict[str, P] = {}
    if shape.kind == "train":
        specs = {"tokens": P(bspec, None), "targets": P(bspec, None)}
    else:
        specs = {"tokens": P(bspec, None)}
    if cfg.family == "vlm":
        specs["vision_emb"] = P(bspec, None, None)
    return specs


def cache_spec_tree(cfg, mesh: Mesh, batch: int, seq_len: int) -> Dict[str, P]:
    """Specs matching ``init_cache(cfg, batch, seq_len)``."""
    m = _axis_size(mesh, "model")
    daxes = data_axes(mesh)
    nd = math.prod(_axis_size(mesh, a) for a in daxes)
    data_only = tuple(a for a in daxes if a == "data") or None

    batch_ok = _div(batch, nd)
    bspec = daxes if batch_ok else None
    # long-context: shard the cache sequence axis instead of batch
    seq_spec = None
    if not batch_ok and data_only and _div(seq_len, _axis_size(mesh, "data")):
        seq_spec = "data"

    kv_head = "model" if _div(cfg.n_kv_heads, m) else None
    specs: Dict[str, P] = {}
    if cfg.family in ("ssm", "hybrid"):
        h_spec = "model" if _div(cfg.ssm_nheads, m) else None
        specs["conv"] = P(None, bspec, None, None)
        specs["ssd"] = P(None, bspec, h_spec, None, None)
        if cfg.family == "hybrid":
            W = min(seq_len, cfg.window) if cfg.window else seq_len
            wseq = "data" if (not batch_ok and data_only
                              and _div(W, _axis_size(mesh, "data"))) else None
            specs["ak"] = P(None, bspec, wseq, kv_head, None)
            specs["av"] = P(None, bspec, wseq, kv_head, None)
    elif cfg.global_every or cfg.cross_every:
        # pattern-split caches (model._pattern): local ring/full + special
        W = min(seq_len, cfg.window) if cfg.global_every else seq_len
        S_spec = seq_len if cfg.global_every else cfg.vision_seq
        wseq = "data" if (not batch_ok and data_only
                          and _div(W, _axis_size(mesh, "data"))) else None
        sseq = "data" if (not batch_ok and data_only
                          and _div(S_spec, _axis_size(mesh, "data"))) else None
        specs["lk"] = P(None, bspec, wseq, kv_head, None)
        specs["lv"] = P(None, bspec, wseq, kv_head, None)
        specs["sk"] = P(None, bspec, sseq, kv_head, None)
        specs["sv"] = P(None, bspec, sseq, kv_head, None)
    else:
        specs["k"] = P(None, bspec, seq_spec, kv_head, None)
        specs["v"] = P(None, bspec, seq_spec, kv_head, None)
        if cfg.first_k_dense:
            specs["k0"] = P(None, bspec, seq_spec, kv_head, None)
            specs["v0"] = P(None, bspec, seq_spec, kv_head, None)
    return specs


def opt_spec_tree(cfg, mesh: Mesh):
    """AdamW state specs (mu/nu mirror params)."""
    from ..optim import AdamWState
    pspec = param_spec_tree(cfg, mesh)
    return AdamWState(step=P(), mu=pspec, nu=pspec)


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
