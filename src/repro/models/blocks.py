"""Per-layer blocks and stacked-parameter initialisation.

Layers are stored STACKED (leading axis = layer) and executed with
``jax.lax.scan`` so the compiled HLO contains each layer body once — this is
what keeps 100-layer lowering tractable for the 512-device dry-run.

Heterogeneous layer patterns are expressed as per-layer *metadata arrays*
(scan xs), never as per-layer param structure differences:
  * gemma3  — ``windows[l]``: -1 full attention, >0 sliding window
  * vlm     — ``is_cross[l]``: kv source = vision embeddings (lax.cond)
  * deepseek— leading dense layers are unrolled (different FFN shape)
  * zamba2  — grouped scans over mamba layers + ONE shared attn block
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import init_attn
from .common import split_keys
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .ssm import init_mamba


def stacked_init(init_fn, key, n: int):
    """Initialise ``n`` layers of identical structure, stacked on axis 0."""
    keys = jnp.stack(split_keys(key, n))
    return jax.vmap(init_fn)(keys)


def layer_metadata(cfg) -> Dict[str, jnp.ndarray]:
    """Per-layer static metadata as arrays (scan xs)."""
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    n = cfg.n_layers
    windows = []
    for k in kinds:
        if k == "local":
            windows.append(cfg.window)
        elif k in ("global", "attn", "cross"):
            windows.append(-1)
        else:
            windows.append(0)
    return {
        "window": jnp.asarray(windows, jnp.int32),
        "is_cross": jnp.asarray([k == "cross" for k in kinds], jnp.bool_),
        "is_moe": jnp.asarray([f == "moe" for f in ffns], jnp.bool_),
    }
