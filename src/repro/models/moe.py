"""Mixture-of-Experts layer: router + shared/routed experts.

Three dispatch modes, mirroring the paper's baseline-vs-technique split:

* ``dense``   — every expert computes every token, combined by router weight.
                Exact; used as the oracle and for tiny smoke configs.
* ``scatter`` — capacity-based scatter/gather dispatch (GShard-style).  The
                "collective-style" baseline: under pjit, GSPMD materialises
                the token movement as all-gathers/dynamic-slices.
* ``a2a``     — the fabric-lib analogue: explicit dispatch/combine through
                ``ragged_all_to_all`` inside shard_map on the expert-parallel
                axis (see ``repro.comm.moe_a2a``), the TPU-native mapping of
                the paper's §6 dispatch/combine WRITEs.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys


def init_moe(key, cfg, dtype) -> Dict[str, jax.Array]:
    D, E, Fe = cfg.d_model, cfg.n_routed, cfg.d_ff_expert
    ks = split_keys(key, 7)
    p = {
        "norm": jnp.zeros((D,), dtype),
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=D ** -0.5),
        "wg": dense_init(ks[1], (E, D, Fe), dtype),
        "wu": dense_init(ks[2], (E, D, Fe), dtype),
        "wd": dense_init(ks[3], (E, Fe, D), dtype, scale=Fe ** -0.5),
    }
    if cfg.n_shared:
        Fs = cfg.n_shared * Fe
        p.update({
            "swg": dense_init(ks[4], (D, Fs), dtype),
            "swu": dense_init(ks[5], (D, Fs), dtype),
            "swd": dense_init(ks[6], (Fs, D), dtype, scale=Fs ** -0.5),
        })
    return p


def router_topk(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Softmax router with renormalised top-k gates + aux load-balance loss.

    logits: (T, E) float32.  Returns (gates (T,k), eids (T,k), aux_loss).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * P_e
    E = logits.shape[-1]
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = E * jnp.sum(me * ce)
    return gates, eids, aux


def _experts_swiglu(p, xe: jax.Array) -> jax.Array:
    """xe: (E, C, D) -> (E, C, D) through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wd"])


def _shared_out(p, h: jax.Array) -> jax.Array:
    if "swg" not in p:
        return jnp.zeros_like(h)
    return (jax.nn.silu(h @ p["swg"]) * (h @ p["swu"])) @ p["swd"]


def moe_dense(p, h: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Exact all-experts path (oracle)."""
    T, D = h.shape
    logits = h.astype(jnp.float32) @ p["router"]
    gates, eids, aux = router_topk(logits, cfg.top_k)
    # (E, T, D) expert outputs
    ye = _experts_swiglu(p, jnp.broadcast_to(h[None], (cfg.n_routed, T, D)))
    w = jnp.zeros((T, cfg.n_routed), h.dtype).at[
        jnp.arange(T)[:, None], eids].set(gates.astype(h.dtype))
    y = jnp.einsum("te,etd->td", w, ye)
    return y + _shared_out(p, h), aux


def moe_scatter(p, h: jax.Array, cfg, capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based scatter/gather dispatch (collective-style baseline).

    Tokens beyond an expert's capacity are dropped (contribute zero), as in
    GShard/Switch.  Capacity C = ceil(T * k / E * cf).
    """
    T, D = h.shape
    E, k = cfg.n_routed, cfg.top_k
    C = max(1, int(T * k / E * capacity_factor))
    logits = h.astype(jnp.float32) @ p["router"]
    gates, eids, aux = router_topk(logits, k)

    fe = eids.reshape(-1)                                   # (T*k,)
    fg = gates.reshape(-1).astype(h.dtype)
    ft = jnp.repeat(jnp.arange(T), k)
    oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)             # (T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, 0) - oh, fe[:, None], 1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, pos, C)                          # overflow -> parking slot

    xe = jnp.zeros((E, C + 1, D), h.dtype).at[fe, slot].add(
        jnp.where(keep[:, None], h[ft], 0))
    ye = _experts_swiglu(p, xe[:, :C])
    ye = jnp.concatenate([ye, jnp.zeros((E, 1, D), ye.dtype)], axis=1)
    contrib = ye[fe, slot] * (fg * keep.astype(h.dtype))[:, None]
    y = jnp.zeros((T, D), h.dtype).at[ft].add(contrib)
    return y + _shared_out(p, h), aux


def moe_forward(p, x: jax.Array, cfg, mode: str = "scatter",
                ep_axis: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps).reshape(B * S, D)
    if mode == "dense":
        y, aux = moe_dense(p, h, cfg)
    elif mode == "scatter":
        y, aux = moe_scatter(p, h, cfg)
    elif mode == "a2a":
        from ..comm.moe_a2a import moe_a2a
        y, aux = moe_a2a(p, h, cfg, ep_axis or "model")
    else:
        raise ValueError(f"unknown moe mode {mode}")
    return y.reshape(B, S, D), aux
