"""Attention: GQA with full / sliding-window / cross variants.

Prefill uses a chunked (flash-style) implementation — a double scan over
query and key/value blocks with a running (max, sum, acc) carry — so no
S x S score matrix is ever materialised (required for the 32k/500k shapes).
Masks are computed from index arithmetic inside each block.

Decode attends one query position against the full cache; for long_500k the
cache is sequence-sharded across the ``data`` mesh axis and the softmax
reduction spans shards (GSPMD inserts the collectives; see EXPERIMENTS.md
§Perf for the shard_map flash-decode iteration).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rms_norm, split_keys

NEG_INF = -1e30

# Prefill attention implementation: the Pallas flash kernel keeps the score
# tiles and running statistics in VMEM (the dominant residual memory-term
# contributor per EXPERIMENTS §Perf).  Enabled automatically on TPU; the
# chunked-jnp path remains the CPU/host default.  FORCE_FLASH is a test hook.
FORCE_FLASH: bool = False


def _use_flash() -> bool:
    return FORCE_FLASH or jax.default_backend() == "tpu"


def init_attn(key, cfg, dtype) -> Dict[str, jax.Array]:
    D, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 5)
    return {
        "norm": jnp.zeros((D,), dtype),
        "wq": dense_init(ks[0], (D, H * Dh), dtype),
        "wk": dense_init(ks[1], (D, K * Dh), dtype),
        "wv": dense_init(ks[2], (D, K * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, D), dtype, scale=(H * Dh) ** -0.5),
    }


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_positions: jax.Array, k_positions: jax.Array,
                      *, causal: bool, window: Optional[jax.Array] = None,
                      q_block: int = 512, k_block: int = 1024,
                      static_window: Optional[int] = None) -> jax.Array:
    """Memory-efficient attention.

    q: (B, Sq, H, Dh);  k, v: (B, Sk, K, Dh) with H = K * G.
    ``window``: traced scalar; <=0 means full attention, otherwise sliding
    window of that many positions (query attends keys in (qpos-window, qpos]).
    ``static_window``: compile-time window — the kv scan is BANDED, visiting
    only the ceil((window+qb)/kb)+1 kv blocks that can intersect each query
    block (§Perf iteration C: local layers stop paying O(S^2)).
    Returns (B, Sq, H, Dh).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = Dh ** -0.5

    qb = min(q_block, Sq)
    kb = min(k_block, Sk)
    # Pad sequence dims to multiples of the block sizes.
    pq = (-Sq) % qb
    pk = (-Sk) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, pq),), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, pk),), constant_values=2**30)
    nq, nk = q.shape[1] // qb, k.shape[1] // kb

    # (B, nq, qb, K, G, Dh) / (B, nk, kb, K, Dh) — kept in storage dtype;
    # block dots accumulate fp32 on the MXU (iteration D: fp32 operand
    # copies double both HBM traffic and the TP-collective bytes of the
    # k/v cotangents in backward)
    qr = (q * scale).reshape(B, nq, qb, K, G, Dh)
    kr = k.reshape(B, nk, kb, K, Dh)
    vr = v.reshape(B, nk, kb, K, Dh)
    qpos = q_positions.reshape(nq, qb)
    kpos = k_positions.reshape(nk, kb)

    if static_window is not None:
        win = jnp.asarray(static_window, jnp.int32)
        n_rel = min(nk, (static_window + qb + kb - 1) // kb + 1)
    else:
        win = window if window is not None else jnp.asarray(0, jnp.int32)
        n_rel = None

    def q_step(qi):
        qblk = qr[:, qi]          # (B, qb, K, G, Dh)
        qp = qpos[qi]             # (qb,)

        def kv_step(carry, ki):
            oob = None
            if n_rel is not None:
                # banded: ki is a relative offset below this q block's last
                # reachable kv block; out-of-range blocks are masked out
                base = (qi * qb) // kb + (qb - 1) // kb
                oob = (base - ki) < 0
                ki = jnp.clip(base - ki, 0, nk - 1)
            m, l, acc = carry
            kblk, vblk, kp = kr[:, ki], vr[:, ki], kpos[ki]
            s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk,
                           preferred_element_type=jnp.float32)     # (B,K,G,qb,kb)
            dpos = qp[:, None] - kp[None, :]                        # (qb, kb)
            mask = jnp.ones_like(dpos, dtype=bool)
            if causal:
                mask &= dpos >= 0
            mask &= jnp.where(win > 0, dpos < win, True)
            if oob is not None:
                mask &= ~oob
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, K, G, qb, Dh), jnp.float32)
        ks = jnp.arange(n_rel if n_rel is not None else nk)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), ks)
        out = acc / jnp.maximum(l, 1e-20)[..., None]                # (B,K,G,qb,Dh)
        return out.transpose(0, 3, 1, 2, 4)                          # (B,qb,K,G,Dh)

    out = jax.lax.map(q_step, jnp.arange(nq))                        # (nq,B,qb,K,G,Dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, H, Dh)
    return out[:, :Sq].astype(q.dtype)


def attn_prefill(p: Dict[str, jax.Array], x: jax.Array, cfg, *,
                 kv_src: Optional[jax.Array] = None,
                 window: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None,
                 return_kv: bool = False,
                 static_window: Optional[int] = None):
    """Self- or cross-attention over a full sequence.

    ``kv_src``: None => self-attention (causal); otherwise cross-attention
    over the given source (no causal mask, no RoPE on source positions).
    """
    B, S, D = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = _split_heads(h @ p["wq"], H, Dh)
    src = h if kv_src is None else kv_src
    k = _split_heads(src @ p["wk"], K, Dh)
    v = _split_heads(src @ p["wv"], K, Dh)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kpos = positions
        causal = True
    else:
        kpos = jnp.arange(src.shape[1], dtype=jnp.int32)
        causal = False
    # Pallas flash path (TPU): self-attention over contiguous positions.
    # Window comes either from the static band or a trace-time constant.
    win_static = static_window
    if win_static is None:
        if window is None:
            win_static = 0             # full causal attention
        else:
            try:
                w = int(window)        # concrete per-arch constant
                win_static = w if w > 0 else 0
            except Exception:
                win_static = None      # traced (mixed-layer scan) -> chunked
    if (_use_flash() and kv_src is None and win_static is not None
            and S % 16 == 0):
        from ..kernels import ops as kops
        G = H // K
        kb = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)   # (B,H,S,Dh)
        vb = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
        qb = q.transpose(0, 2, 1, 3)
        o = kops.flash_attention(qb, kb, vb, causal=True,
                                 window=max(win_static, 0))
        o = o.transpose(0, 2, 1, 3)
    else:
        o = chunked_attention(q, k, v, positions, kpos, causal=causal,
                              window=window, static_window=static_window)
    out = o.reshape(B, S, H * Dh) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(p: Dict[str, jax.Array], x: jax.Array, cfg, *,
                k_cache: jax.Array, v_cache: jax.Array,
                positions: jax.Array,
                window: Optional[jax.Array] = None,
                cross: bool = False,
                cache_positions: Optional[jax.Array] = None,
                ring: Optional[int] = None):
    """One-token decode against a cache.

    x: (B, 1, D); k_cache/v_cache: (B, Smax, K, Dh); positions: (B,) — the
    index of the NEW token.  For self-attention the new K/V is written into
    the cache at ``positions`` (scatter) and attention spans cache slots
    <= positions (within ``window`` if sliding).  For cross-attention the
    cache is the fixed source KV and nothing is written.

    Returns (out (B,1,D), k_cache, v_cache).
    """
    B, _, D = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Smax = k_cache.shape[1]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = _split_heads(h @ p["wq"], H, Dh)                     # (B,1,H,Dh)
    if not cross:
        k_new = _split_heads(h @ p["wk"], K, Dh)             # (B,1,K,Dh)
        v_new = _split_heads(h @ p["wv"], K, Dh)
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)
        bidx = jnp.arange(B)
        slots_w = positions % ring if ring else positions
        k_cache = k_cache.at[bidx, slots_w].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, slots_w].set(v_new[:, 0].astype(v_cache.dtype))

    G = H // K
    # keep cache-sized operands in their storage dtype; accumulate fp32 on
    # the MXU (a materialised fp32 copy of a 500k-token cache costs more
    # HBM traffic than the attention itself — §Perf iteration A)
    qr = (q.reshape(B, K, G, Dh) * (Dh ** -0.5)).astype(k_cache.dtype)
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32)       # (B,K,G,Smax)
    slot = (jnp.arange(Smax, dtype=jnp.int32)[None, :]
            if cache_positions is None else cache_positions)  # (1|B, Smax)
    if not cross:
        dpos = positions[:, None] - slot                      # (B, Smax)
        mask = dpos >= 0
        if window is not None:
            win = window
            mask &= jnp.where(win > 0, dpos < win, True)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    pattn = jnp.exp(s - m)
    o = jnp.einsum("bkgt,btkd->bkgd", pattn.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / pattn.sum(-1)[..., None]
    out = o.reshape(B, 1, H * Dh).astype(x.dtype) @ p["wo"]
    return out, k_cache, v_cache
