from .disagg import Decoder, DispatchReq, Prefiller
from .kvpool import PagedKvPool, PoolGeometry
from .scheduler import Scheduler

__all__ = ["Prefiller", "Decoder", "DispatchReq", "PagedKvPool",
           "PoolGeometry", "Scheduler"]
