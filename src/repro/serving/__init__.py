from .disagg import (Decoder, DispatchReq, Prefiller,
                     disagg_unsupported_reason)
from .kvpool import KvPool, PagedKvPool, PoolGeometry
from .scheduler import Scheduler
from .slo import SloTracker

__all__ = ["Prefiller", "Decoder", "DispatchReq", "KvPool", "PagedKvPool",
           "PoolGeometry", "Scheduler", "SloTracker",
           "disagg_unsupported_reason"]
