from .disagg import (Decoder, DispatchReq, Prefiller,
                     disagg_unsupported_reason)
from .kvpool import PagedKvPool, PoolGeometry
from .scheduler import Scheduler

__all__ = ["Prefiller", "Decoder", "DispatchReq", "PagedKvPool",
           "PoolGeometry", "Scheduler", "disagg_unsupported_reason"]
