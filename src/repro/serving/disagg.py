"""Disaggregated inference: KvCache transfer over the TransferEngine (§4).

Faithful implementation of the paper's Appendix A pseudocode:

  decoder:  allocate pages + tail slot -> register ImmCounter expectation
            (n_pages * n_layers + 1) -> SEND DispatchReq -> wait on the
            counter -> decode.
  prefiller: recv loop -> on DispatchReq: run prefill, increment a
            UvmWatcher after each layer's attention output projection ->
            the watcher callback issues that layer's submit_paged_writes ->
            after the last chunk, submit_single_write of the tail context
            (last-token logits) -> poll cnt_done before freeing pages.

Model compute is REAL (a reduced-config jax model); compute time is mapped
onto the virtual clock so the layer-by-layer transfer/compute overlap is
measurable.  A prefiller serves one request at a time (an occupied GPU):
requests queue behind ``_busy_until``, which is what makes queue depth and
TTFT meaningful autoscaling signals.

Elastic membership (§4 "dynamic scaling") runs through ``repro.ctrl``:
pass ``ctrl=`` and the peer JOINs the control plane at startup, publishing
its wire address, KV-pool ``MrDesc``, NIC kind, and pool geometry; leases
renew in the background, DRAIN finishes in-flight work and frees every
page before LEAVE, and a crash (``crash()``) simply stops renewals so the
lease lapses.  All messages — including ``DispatchReq``, formerly an
ad-hoc pickle — go through the typed wire codec of ``repro.ctrl.messages``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import Fabric, MrDesc, NetAddr, Pages
from ..ctrl import ControlClient, ControlPlane
from ..ctrl import messages as m
from ..models import decode_step, init_cache, prefill
from .kvpool import PagedKvPool, PoolGeometry


@m.wire("DREQ")
@dataclass
class DispatchReq:
    input_ids: np.ndarray                 # (S,)
    decoder_addr: NetAddr
    imm: int
    kv_desc: MrDesc
    pages: List[int]                      # decoder page indices, per chunk x layer
    tail_desc: MrDesc
    tail_idx: int
    request_id: int


def disagg_unsupported_reason(cfg) -> Optional[str]:
    """Why the §4 KvCache protocol cannot serve ``cfg`` (None = it can).

    The paged transfer moves a uniform ``(L, S, K, Dh)`` k/v stack.  Archs
    whose reduced cache is *split* — pattern archs (gemma3 local/global,
    vlm cross layers), SSM/hybrid state, or leading dense layers — need a
    per-kind state-handoff schema that doesn't exist yet (ROADMAP item).
    This is the single guard for the whole serving stack: constructors
    raise on it, launchers print it.
    """
    if cfg.family in ("ssm", "hybrid"):
        return (f"family '{cfg.family}' carries SSM state, not a uniform "
                "KV cache")
    if cfg.global_every or cfg.cross_every:
        return ("pattern-split KV cache (lk/lv/sk/sv local+special stacks, "
                "not a uniform k/v stack)")
    if cfg.first_k_dense:
        return "first-k-dense split cache (k0/v0 head layers)"
    return None


def _check_supported(cfg) -> None:
    reason = disagg_unsupported_reason(cfg)
    if reason is not None:
        raise ValueError(
            f"disaggregated serving cannot handle '{cfg.name}': {reason}")


def _geom(cfg, page_tokens: int) -> PoolGeometry:
    return PoolGeometry(n_layers=cfg.n_layers, page_tokens=page_tokens,
                        n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim)


def _geom_wire(geom: PoolGeometry) -> Dict[str, Any]:
    """JSON-safe pool geometry for the control plane's JOIN message."""
    return dict(n_layers=geom.n_layers, page_tokens=geom.page_tokens,
                n_kv=geom.n_kv, head_dim=geom.head_dim,
                dtype=geom.dtype.str, page_bytes=geom.page_bytes)


class Prefiller:
    """Prefill node: owns model params and a KV pool as WRITE source."""

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 layer_compute_us: float = 50.0,
                 ctrl: Optional[ControlPlane] = None,
                 peer_id: Optional[str] = None, renew_us: float = 500.0,
                 max_renewals: int = 256):
        _check_supported(cfg)
        self.cfg = cfg
        self.params = params
        self.engine = fabric.add_engine(node, nic=nic)
        self.fabric = fabric
        self.nic = nic
        self.geom = _geom(cfg, page_tokens)
        self.pool = PagedKvPool(self.engine, self.geom, n_pages)
        self.layer_compute_us = layer_compute_us
        self.stats: Dict[str, float] = {}
        self._cancelled: set = set()
        self.alive = True
        self.draining = False
        self.inflight = 0
        self.served = 0
        self._busy_until = 0.0
        self.engine.submit_recvs(1 << 16, 8, self._on_msg)
        self.client: Optional[ControlClient] = None
        if ctrl is not None:
            self.client = ControlClient(
                self.engine, fabric, ctrl.address(),
                peer_id or node, "prefill", renew_us=renew_us,
                max_renewals=max_renewals,
                alive_fn=lambda: self.alive,
                inflight_fn=lambda: self.inflight,
                free_pages_fn=lambda: len(self.pool._free),
                on_drain=self._on_drain)
            self.client.join(nic=nic, kv_desc=self.pool.desc,
                             geom=_geom_wire(self.geom), n_pages=n_pages)

    def address(self) -> NetAddr:
        return self.engine.address(0)

    def cancel(self, request_id: int) -> None:
        self._cancelled.add(request_id)

    def crash(self) -> None:
        """Simulated process death: stop serving AND stop renewing the
        lease — the control plane notices via lease expiry, never via a
        goodbye message."""
        self.alive = False

    # -- control-plane hooks ------------------------------------------------
    def _on_drain(self, msg: m.Drain) -> None:
        self.draining = True
        self._maybe_finish_drain()

    def _maybe_finish_drain(self) -> None:
        if (self.draining and self.inflight == 0 and self.alive
                and self.client is not None and not self.client.left):
            # every in-flight request finished and freed its staging pages
            self.client.leave()

    # -- data plane ---------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        if not self.alive:
            return
        msg = m.decode(payload)
        if self.client is not None and self.client.handle(msg):
            return
        if isinstance(msg, DispatchReq):
            self._on_request(msg)

    def _on_request(self, req: DispatchReq) -> None:
        if req.request_id in self._cancelled:
            return
        if self.draining:
            # the scheduler never routes to a draining peer; anything that
            # races the drain is dropped (the sender re-routes on the next
            # view) rather than silently extending the drain
            self.stats["rejected"] = self.stats.get("rejected", 0) + 1
            return
        cfg = self.cfg
        S = len(req.input_ids)
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        t_start = self.fabric.now
        self.inflight += 1
        self.served += 1

        # One request occupies the GPU at a time: queue behind _busy_until.
        start = max(t_start, self._busy_until)
        self._busy_until = start + cfg.n_layers * self.layer_compute_us
        delay0 = start - t_start
        self.stats[f"req{req.request_id}_queued_us"] = delay0

        # REAL prefill compute (all layers at once — jax scan); K/V per layer.
        tokens = jnp.asarray(req.input_ids, jnp.int32)[None]
        logits, cache = prefill(self.params, tokens, cfg, max_len=S,
                                moe_mode="dense")
        logits = logits[..., :cfg.vocab]   # drop vocab padding
        k = np.asarray(cache["k"], np.float32)   # (L,1,S,K,Dh)
        v = np.asarray(cache["v"], np.float32)

        # local staging pages: chunk c of layer l -> pool page
        local_pages = self.pool.alloc(n_chunks * cfg.n_layers)
        for l in range(cfg.n_layers):
            for c in range(n_chunks):
                lo, hi = c * page_tokens, min(S, (c + 1) * page_tokens)
                self.pool.write_page(local_pages[l * n_chunks + c],
                                     k[l, 0, lo:hi], v[l, 0, lo:hi])

        # tail context: last-token logits
        tail = np.asarray(logits, np.float32).reshape(-1).view(np.uint8)
        tail_buf = np.zeros(tail.size, np.uint8)
        tail_buf[:] = tail
        tail_handle, _ = self.engine.reg_mr(tail_buf)

        cnt = {"done": 0}
        total_writes = n_chunks * cfg.n_layers + 1

        def send_layers(lo: int, hi: int) -> None:
            # Layers [lo, hi) completed since the last poll land as ONE
            # batched paged-write submission: the UVM poller coalesces
            # increments, so coalesced layers share a single WrBatch.
            if (not self.alive or req.request_id in self._cancelled
                    or hi <= lo):
                return
            src = Pages(indices=tuple(local_pages[lo * n_chunks:hi * n_chunks]),
                        stride=self.geom.page_bytes)
            dst = Pages(indices=tuple(req.pages[lo * n_chunks:hi * n_chunks]),
                        stride=self.geom.page_bytes)
            n_sent = (hi - lo) * n_chunks
            self.engine.submit_paged_writes(
                self.geom.page_bytes, req.imm,
                (self.pool.handle, src), (req.kv_desc, dst),
                on_done=lambda: cnt.__setitem__("done", cnt["done"] + n_sent))

        # UvmWatcher: the "GPU" increments after each layer's attn output
        # projection; the watcher callback sends the completed span (App. A).
        watcher = self.engine.alloc_uvm_watcher(send_layers)
        for l in range(cfg.n_layers):
            self.fabric.loop.schedule(delay0 + (l + 1) * self.layer_compute_us,
                                      lambda l=l: watcher.store(l + 1))

        def send_tail() -> None:
            if not self.alive or req.request_id in self._cancelled:
                return
            self.engine.submit_single_write(
                tail.size, req.imm, (tail_handle, 0), (req.tail_desc,
                                                       req.tail_idx * tail.size),
                on_done=lambda: cnt.__setitem__("done", cnt["done"] + 1))

        self.fabric.loop.schedule(
            delay0 + cfg.n_layers * self.layer_compute_us + 1.0, send_tail)

        def poll_free() -> None:
            if not self.alive:
                return        # crashed: the node (and its pool) is gone
            if req.request_id in self._cancelled:
                self.pool.free(local_pages)
                self.inflight -= 1
                self._maybe_finish_drain()
                return
            if cnt["done"] >= total_writes:
                self.pool.free(local_pages)
                self.inflight -= 1
                self.stats[f"req{req.request_id}_prefill_us"] = \
                    self.fabric.now - t_start
                self._maybe_finish_drain()
            else:
                self.fabric.loop.schedule(5.0, poll_free)

        self.fabric.loop.schedule(
            delay0 + cfg.n_layers * self.layer_compute_us, poll_free)


class Decoder:
    """Decode node: pre-allocates pages, dispatches, decodes on completion.

    With ``ctrl=`` the decoder also serves the elastic wire path: the
    scheduler SENDs ``SubmitReq``s here, completion is reported back via
    ``ReqDone``, and ``CancelReq`` (failover) frees the attempt's pages and
    tail slot so nothing leaks when a prefiller dies mid-transfer.
    """

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 max_tail: int = 16, ctrl: Optional[ControlPlane] = None,
                 peer_id: Optional[str] = None, renew_us: float = 500.0,
                 max_renewals: int = 256):
        _check_supported(cfg)
        self.cfg = cfg
        self.params = params
        self.fabric = fabric
        self.engine = fabric.add_engine(node, nic=nic)
        self.geom = _geom(cfg, page_tokens)
        self.pool = PagedKvPool(self.engine, self.geom, n_pages)
        tail_bytes = cfg.vocab * 4
        self.tail_buf = np.zeros(max_tail * tail_bytes, np.uint8)
        self.tail_handle, self.tail_desc = self.engine.reg_mr(self.tail_buf)
        self._tail_free = list(range(max_tail))
        self._imm_next = 1
        self.alive = True
        self.draining = False
        self.results: Dict[int, Dict] = {}
        self._pending: Dict[int, Dict] = {}   # rid -> in-flight attempt state
        self._attempt: Dict[int, int] = {}    # rid -> newest attempt seen
        self.engine.submit_recvs(1 << 16, 32, self._on_msg)
        self.client: Optional[ControlClient] = None
        if ctrl is not None:
            self.client = ControlClient(
                self.engine, fabric, ctrl.address(),
                peer_id or node, "decode", renew_us=renew_us,
                max_renewals=max_renewals,
                alive_fn=lambda: self.alive,
                inflight_fn=lambda: len(self._pending),
                free_pages_fn=lambda: len(self.pool._free),
                on_drain=self._on_drain)
            self.client.join(nic=nic, kv_desc=self.pool.desc,
                             geom=_geom_wire(self.geom), n_pages=n_pages)

    def address(self) -> NetAddr:
        return self.engine.address(0)

    # -- control-plane hooks ------------------------------------------------
    def _on_drain(self, msg: m.Drain) -> None:
        self.draining = True
        self._maybe_finish_drain()

    def _maybe_finish_drain(self) -> None:
        if (self.draining and not self._pending and self.alive
                and self.client is not None and not self.client.left):
            self.client.leave()

    # -- wire path ----------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        if not self.alive:
            return
        msg = m.decode(payload)
        if self.client is not None and self.client.handle(msg):
            return
        if isinstance(msg, m.SubmitReq):
            if self.draining:
                # racing a drain: drop — once this decoder LEAVEs, the
                # scheduler re-routes every request still pointed at it
                return
            cur = self._attempt.get(msg.request_id, -1)
            if msg.attempt <= cur:
                return      # stale duplicate of an attempt we've superseded
            if msg.request_id in self._pending:
                self.cancel(msg.request_id)   # superseded by a re-route
            self._attempt[msg.request_id] = msg.attempt
            self.submit(msg.request_id, msg.input_ids, msg.prefiller,
                        n_decode=msg.n_decode, reply_to=msg.reply_to,
                        attempt=msg.attempt)
        elif isinstance(msg, m.CancelReq):
            # only the newest attempt may be cancelled; an unordered SEND
            # can deliver a stale CANCEL after its re-route's SUBMIT
            if msg.attempt == self._attempt.get(msg.request_id):
                self.cancel(msg.request_id)

    def cancel(self, request_id: int) -> bool:
        """Abandon an in-flight attempt: free pages + tail slot, drop the
        ImmCounter expectation.  Nothing leaks — failover re-allocates."""
        st = self._pending.pop(request_id, None)
        if st is None:
            return False
        self.engine.counters[0].reset(st["imm"])
        self.pool.free(st["pages"])
        self._tail_free.append(st["tail_idx"])
        self.results.pop(request_id, None)
        self._maybe_finish_drain()
        return True

    # ------------------------------------------------------------------
    def submit(self, request_id: int, input_ids: np.ndarray,
               prefiller: NetAddr, n_decode: int = 4, *,
               reply_to: Optional[NetAddr] = None, attempt: int = 0) -> None:
        cfg = self.cfg
        S = len(input_ids)
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        pages = self.pool.alloc(n_chunks * cfg.n_layers)
        tail_idx = self._tail_free.pop(0)
        imm = self._imm_next
        self._imm_next += 1
        imm_count = n_chunks * cfg.n_layers + 1
        t0 = self.fabric.now
        self._pending[request_id] = {
            "pages": pages, "tail_idx": tail_idx, "imm": imm,
            "attempt": attempt, "reply_to": reply_to, "seq_len": S,
        }

        req = DispatchReq(input_ids=np.asarray(input_ids),
                          decoder_addr=self.address(),
                          imm=imm, kv_desc=self.pool.desc, pages=pages,
                          tail_desc=self.tail_desc, tail_idx=tail_idx,
                          request_id=request_id)

        def on_complete() -> None:
            st = self._pending.get(request_id)
            if st is None or st["imm"] != imm:
                return      # attempt was cancelled / superseded
            self.results[request_id] = {
                "ttft_us": self.fabric.now - t0,
                "pages": pages, "tail_idx": tail_idx, "seq_len": S,
            }
            self._decode(request_id, n_decode)

        self.engine.expect_imm_count(imm, imm_count, on_complete)
        self.engine.submit_send(prefiller, m.encode(req))

    def _assemble_cache(self, request_id: int):
        cfg = self.cfg
        r = self.results[request_id]
        S = r["seq_len"]
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        max_len = S + 64
        cache = init_cache(cfg, 1, max_len)
        k = np.zeros((cfg.n_layers, 1, max_len, cfg.n_kv_heads, cfg.head_dim), np.float32)
        v = np.zeros_like(k)
        for l in range(cfg.n_layers):
            for c in range(n_chunks):
                pk, pv = self.pool.read_page(r["pages"][l * n_chunks + c])
                lo, hi = c * page_tokens, min(S, (c + 1) * page_tokens)
                k[l, 0, lo:hi] = pk[: hi - lo]
                v[l, 0, lo:hi] = pv[: hi - lo]
        cache["k"] = jnp.asarray(k, cache["k"].dtype)
        cache["v"] = jnp.asarray(v, cache["v"].dtype)
        return cache

    def _decode(self, request_id: int, n_decode: int) -> None:
        cfg = self.cfg
        r = self.results[request_id]
        tail_bytes = cfg.vocab * 4
        logits = (self.tail_buf[r["tail_idx"] * tail_bytes:
                                (r["tail_idx"] + 1) * tail_bytes]
                  .view(np.float32).reshape(1, cfg.vocab))
        cache = self._assemble_cache(request_id)
        toks = [int(np.argmax(logits[0]))]
        pos = r["seq_len"]
        for _ in range(n_decode - 1):
            lg, cache = decode_step(self.params, jnp.asarray([[toks[-1]]]),
                                    jnp.asarray([pos], jnp.int32), cache, cfg,
                                    moe_mode="dense")
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        r["tokens"] = toks
        self.pool.free(r["pages"])
        self._tail_free.append(r["tail_idx"])
        st = self._pending.pop(request_id, None)
        if st is not None and st["reply_to"] is not None:
            peer = self.client.peer_id if self.client else ""
            self.engine.submit_send(st["reply_to"], m.encode(m.ReqDone(
                request_id=request_id, attempt=st["attempt"], peer_id=peer,
                ttft_us=r["ttft_us"], tokens=list(toks))))
        self._maybe_finish_drain()
