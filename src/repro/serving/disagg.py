"""Disaggregated inference: KvCache transfer over the TransferEngine (§4).

Faithful implementation of the paper's Appendix A pseudocode:

  decoder:  allocate pages + tail slot -> register ImmCounter expectation
            (n_pages * n_layers + 1) -> submit_send(DispatchReq) -> wait on
            the counter -> decode.
  prefiller: submit_recvs loop -> on DispatchReq: run prefill, increment a
            UvmWatcher after each layer's attention output projection ->
            the watcher callback issues that layer's submit_paged_writes ->
            after the last chunk, submit_single_write of the tail context
            (last-token logits) -> poll cnt_done before freeing pages.

Model compute is REAL (a reduced-config jax model); compute time is mapped
onto the virtual clock so the layer-by-layer transfer/compute overlap is
measurable.  Cancellation + heartbeats implement the §4 error-handling
contract.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Fabric, MrDesc, NetAddr, Pages, TransferEngine
from ..models import decode_step, init_cache, prefill
from .kvpool import PagedKvPool, PoolGeometry


@dataclass
class DispatchReq:
    input_ids: np.ndarray                 # (S,)
    decoder_addr: NetAddr
    imm: int
    kv_desc: MrDesc
    pages: List[int]                      # decoder page indices, per chunk x layer
    tail_desc: MrDesc
    tail_idx: int
    request_id: int
    cancelled: bool = False


def _geom(cfg, page_tokens: int, max_len: int) -> PoolGeometry:
    return PoolGeometry(n_layers=cfg.n_layers, page_tokens=page_tokens,
                        n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim)


class Prefiller:
    """Prefill node: owns model params and a KV pool as WRITE source."""

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 layer_compute_us: float = 50.0):
        self.cfg = cfg
        self.params = params
        self.engine = fabric.add_engine(node, nic=nic)
        self.fabric = fabric
        self.geom = _geom(cfg, page_tokens, 0)
        self.pool = PagedKvPool(self.engine, self.geom, n_pages)
        self.layer_compute_us = layer_compute_us
        self.engine.submit_recvs(1 << 16, 8, self._on_request)
        self.stats: Dict[str, float] = {}
        self._cancelled: set = set()

    def address(self) -> NetAddr:
        return self.engine.address(0)

    def cancel(self, request_id: int) -> None:
        self._cancelled.add(request_id)

    # ------------------------------------------------------------------
    def _on_request(self, payload: bytes) -> None:
        req: DispatchReq = pickle.loads(payload)
        if req.request_id in self._cancelled:
            return
        cfg = self.cfg
        S = len(req.input_ids)
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        t_start = self.fabric.now

        # REAL prefill compute (all layers at once — jax scan); K/V per layer.
        tokens = jnp.asarray(req.input_ids, jnp.int32)[None]
        logits, cache = prefill(self.params, tokens, cfg, max_len=S,
                                moe_mode="dense")
        logits = logits[..., :cfg.vocab]   # drop vocab padding
        k = np.asarray(cache["k"], np.float32)   # (L,1,S,K,Dh)
        v = np.asarray(cache["v"], np.float32)

        # local staging pages: chunk c of layer l -> pool page
        local_pages = self.pool.alloc(n_chunks * cfg.n_layers)
        for l in range(cfg.n_layers):
            for c in range(n_chunks):
                lo, hi = c * page_tokens, min(S, (c + 1) * page_tokens)
                self.pool.write_page(local_pages[l * n_chunks + c],
                                     k[l, 0, lo:hi], v[l, 0, lo:hi])

        # tail context: last-token logits
        tail = np.asarray(logits, np.float32).reshape(-1).view(np.uint8)
        tail_buf = np.zeros(tail.size, np.uint8)
        tail_buf[:] = tail
        tail_handle, _ = self.engine.reg_mr(tail_buf)

        cnt = {"done": 0}
        total_writes = n_chunks * cfg.n_layers + 1

        def send_layers(lo: int, hi: int) -> None:
            # Layers [lo, hi) completed since the last poll land as ONE
            # batched paged-write submission: the UVM poller coalesces
            # increments, so coalesced layers share a single WrBatch.
            if req.request_id in self._cancelled or hi <= lo:
                return
            src = Pages(indices=tuple(local_pages[lo * n_chunks:hi * n_chunks]),
                        stride=self.geom.page_bytes)
            dst = Pages(indices=tuple(req.pages[lo * n_chunks:hi * n_chunks]),
                        stride=self.geom.page_bytes)
            n_sent = (hi - lo) * n_chunks
            self.engine.submit_paged_writes(
                self.geom.page_bytes, req.imm,
                (self.pool.handle, src), (req.kv_desc, dst),
                on_done=lambda: cnt.__setitem__("done", cnt["done"] + n_sent))

        # UvmWatcher: the "GPU" increments after each layer's attn output
        # projection; the watcher callback sends the completed span (App. A).
        watcher = self.engine.alloc_uvm_watcher(send_layers)
        for l in range(cfg.n_layers):
            self.fabric.loop.schedule((l + 1) * self.layer_compute_us,
                                      lambda l=l: watcher.store(l + 1))

        def send_tail() -> None:
            self.engine.submit_single_write(
                tail.size, req.imm, (tail_handle, 0), (req.tail_desc,
                                                       req.tail_idx * tail.size),
                on_done=lambda: cnt.__setitem__("done", cnt["done"] + 1))

        self.fabric.loop.schedule(cfg.n_layers * self.layer_compute_us + 1.0,
                                  send_tail)

        def poll_free() -> None:
            if cnt["done"] >= total_writes:
                self.pool.free(local_pages)
                self.stats[f"req{req.request_id}_prefill_us"] = \
                    self.fabric.now - t_start
            else:
                self.fabric.loop.schedule(5.0, poll_free)

        self.fabric.loop.schedule(cfg.n_layers * self.layer_compute_us, poll_free)


class Decoder:
    """Decode node: pre-allocates pages, dispatches, decodes on completion."""

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 max_tail: int = 8):
        self.cfg = cfg
        self.params = params
        self.fabric = fabric
        self.engine = fabric.add_engine(node, nic=nic)
        self.geom = _geom(cfg, page_tokens, 0)
        self.pool = PagedKvPool(self.engine, self.geom, n_pages)
        tail_bytes = cfg.vocab * 4
        self.tail_buf = np.zeros(max_tail * tail_bytes, np.uint8)
        self.tail_handle, self.tail_desc = self.engine.reg_mr(self.tail_buf)
        self._tail_free = list(range(max_tail))
        self._imm_next = 1
        self.results: Dict[int, Dict] = {}

    def address(self) -> NetAddr:
        return self.engine.address(0)

    # ------------------------------------------------------------------
    def submit(self, request_id: int, input_ids: np.ndarray,
               prefiller: NetAddr, n_decode: int = 4) -> None:
        cfg = self.cfg
        S = len(input_ids)
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        pages = self.pool.alloc(n_chunks * cfg.n_layers)
        tail_idx = self._tail_free.pop(0)
        imm = self._imm_next
        self._imm_next += 1
        imm_count = n_chunks * cfg.n_layers + 1
        t0 = self.fabric.now

        req = DispatchReq(input_ids=np.asarray(input_ids), decoder_addr=self.address(),
                          imm=imm, kv_desc=self.pool.desc, pages=pages,
                          tail_desc=self.tail_desc, tail_idx=tail_idx,
                          request_id=request_id)

        def on_complete() -> None:
            self.results[request_id] = {
                "ttft_us": self.fabric.now - t0,
                "pages": pages, "tail_idx": tail_idx, "seq_len": S,
            }
            self._decode(request_id, n_decode)

        self.engine.expect_imm_count(imm, imm_count, on_complete)
        self.engine.submit_send(prefiller, pickle.dumps(req))

    def _assemble_cache(self, request_id: int):
        cfg = self.cfg
        r = self.results[request_id]
        S = r["seq_len"]
        page_tokens = self.geom.page_tokens
        n_chunks = -(-S // page_tokens)
        max_len = S + 64
        cache = init_cache(cfg, 1, max_len)
        k = np.zeros((cfg.n_layers, 1, max_len, cfg.n_kv_heads, cfg.head_dim), np.float32)
        v = np.zeros_like(k)
        for l in range(cfg.n_layers):
            for c in range(n_chunks):
                pk, pv = self.pool.read_page(r["pages"][l * n_chunks + c])
                lo, hi = c * page_tokens, min(S, (c + 1) * page_tokens)
                k[l, 0, lo:hi] = pk[: hi - lo]
                v[l, 0, lo:hi] = pv[: hi - lo]
        cache["k"] = jnp.asarray(k, cache["k"].dtype)
        cache["v"] = jnp.asarray(v, cache["v"].dtype)
        return cache

    def _decode(self, request_id: int, n_decode: int) -> None:
        cfg = self.cfg
        r = self.results[request_id]
        tail_bytes = cfg.vocab * 4
        logits = (self.tail_buf[r["tail_idx"] * tail_bytes:
                                (r["tail_idx"] + 1) * tail_bytes]
                  .view(np.float32).reshape(1, cfg.vocab))
        cache = self._assemble_cache(request_id)
        toks = [int(np.argmax(logits[0]))]
        pos = r["seq_len"]
        for _ in range(n_decode - 1):
            lg, cache = decode_step(self.params, jnp.asarray([[toks[-1]]]),
                                    jnp.asarray([pos], jnp.int32), cache, cfg,
                                    moe_mode="dense")
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        r["tokens"] = toks
        self.pool.free(r["pages"])
        self._tail_free.append(r["tail_idx"])
