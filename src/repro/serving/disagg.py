"""Disaggregated inference: KvCache transfer over the TransferEngine (§4).

Faithful implementation of the paper's Appendix A pseudocode, generalised
over :mod:`repro.kvlayout` so EVERY cache architecture serves — uniform k/v
stacks, gemma3-style local/global pattern splits, vlm cross layers,
SSM/hybrid state, and first-k-dense head layers:

  decoder:  compile the request's ``TransferPlan`` -> allocate canonical
            pool pages + a tail slot -> arm one ImmCounter expectation per
            schema component (plus the tail) -> SEND DispatchReq -> wait on
            the counters -> reassemble the cache from the plan -> decode.
  prefiller: recv loop -> on DispatchReq: run prefill, stage the whole
            cache pytree into pool slots (plan canonical order), increment
            a UvmWatcher after each model layer -> the watcher callback
            submits the completed layer span as ONE WrBatch
            (``TransferPlan.submit_span`` — one ``submit_scatters`` call
            covering every component's pages for that span, distinct imm
            per component) -> after the last layer, submit_single_write of
            the tail context (last-token logits) -> poll before freeing.

All layout decisions happen at plan-compile time (arXiv 2605.00686's
plan-ahead principle): the per-request hot path is one enqueue per layer
span regardless of schema complexity, asserted via
``TransferEngine.batch_stats`` in the tests.

Model compute is REAL (a reduced-config jax model); compute time is mapped
onto the virtual clock so the layer-by-layer transfer/compute overlap is
measurable.  A prefiller serves one request at a time (an occupied GPU):
requests queue behind ``_busy_until``, which is what makes queue depth and
TTFT meaningful autoscaling signals.

Elastic membership (§4 "dynamic scaling") runs through ``repro.ctrl``:
pass ``ctrl=`` and the peer JOINs the control plane at startup, publishing
its wire address, KV-pool ``MrDesc``, NIC kind, pool geometry AND its
``KvSchema`` — the Scheduler refuses to pair peers whose schemas differ at
routing time, never mid-transfer.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import Fabric, MrDesc, NetAddr
from ..ctrl import ControlClient, ControlPlane, CtrlRetryPolicy
from ..ctrl import messages as m
from ..kvlayout import (DECODE_MARGIN, KvSchema, TransferPlan, fill_cache,
                        schema_from_config, stage_cache)
from ..models import decode_step, init_cache, prefill
from ..obs import traced_phase
from .kvpool import KvPool


@m.wire("DREQ")
@dataclass
class DispatchReq:
    input_ids: np.ndarray                 # (S,)
    decoder_addr: NetAddr
    imm: int                              # base immediate of the imm block
    kv_desc: MrDesc
    pages: List[int]                      # decoder pages, plan canonical order
    tail_desc: MrDesc
    tail_idx: int
    request_id: int
    vision_emb: Optional[np.ndarray] = None   # (Sv, Dv) for vlm archs
    # the decoder's KvSchema wire form: the prefiller validates it against
    # its own schema BEFORE any WRITE — the last line of defence for
    # hand-wired peers that bypass the Scheduler's routing-time gate
    schema: Optional[Dict[str, Any]] = None


def _geom_wire(cfg, schema: KvSchema) -> Dict[str, Any]:
    """JSON-safe pool geometry advertised in the ctrl JOIN."""
    return dict(n_layers=cfg.n_layers, page_tokens=schema.page_tokens,
                slot_bytes=schema.slot_bytes)


def _cached_plan(plans: Dict[int, TransferPlan], schema: KvSchema,
                 seq_len: int) -> TransferPlan:
    plan = plans.get(seq_len)
    if plan is None:
        plan = plans[seq_len] = TransferPlan(schema, seq_len)
    return plan


def disagg_unsupported_reason(cfg) -> Optional[str]:
    """Why the §4 KvCache protocol cannot serve ``cfg`` (None = it can).

    Since ``repro.kvlayout`` every family the model stack produces has a
    transfer schema — uniform k/v, pattern-split (gemma3 local/global, vlm
    cross), SSM/hybrid state, and first-k-dense head layers all serve
    disaggregated.  The guard is retained as the single serving-stack
    capability probe (constructors raise on it, launchers print it) in
    case future families outrun the schema compiler.
    """
    try:
        schema_from_config(cfg)
    except Exception as e:  # pragma: no cover - no current family hits this
        return f"no KvSchema derivation for family '{cfg.family}': {e}"
    return None


def _check_supported(cfg) -> None:
    reason = disagg_unsupported_reason(cfg)
    if reason is not None:  # pragma: no cover - see above
        raise ValueError(
            f"disaggregated serving cannot handle '{cfg.name}': {reason}")


def _vision_batch(cfg, vision_emb) -> Optional[jnp.ndarray]:
    """Wire (Sv, Dv) embeddings -> (1, Sv, Dv); zeros when absent."""
    if cfg.family != "vlm":
        return None
    if vision_emb is None:
        return jnp.zeros((1, cfg.vision_seq, cfg.vision_dim), jnp.float32)
    return jnp.asarray(vision_emb, jnp.float32)[None]


class Prefiller:
    """Prefill node: owns model params and a KV pool as WRITE source."""

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 layer_compute_us: float = 50.0,
                 ctrl: Optional[ControlPlane] = None,
                 peer_id: Optional[str] = None, renew_us: float = 500.0,
                 max_renewals: int = 256, host: Optional[str] = None,
                 ctrl_retry: Optional[CtrlRetryPolicy] = None):
        _check_supported(cfg)
        self.cfg = cfg
        self.params = params
        # host: physical machine identity — a prefiller and decoder placed
        # on the same host move KV pages over NVLink (per-pair resolution)
        self.engine = fabric.add_engine(node, nic=nic, host=host)
        self.fabric = fabric
        self.nic = nic
        self.schema = schema_from_config(cfg, page_tokens)
        self.pool = KvPool(self.engine, self.schema, n_pages)
        self._plans: Dict[int, TransferPlan] = {}   # seq_len -> compiled plan
        self.layer_compute_us = layer_compute_us
        self.stats: Dict[str, float] = {}
        # (rid, lo, hi, n_writes) per submitted span batch; bounded — only
        # tests read it, a long-lived peer must not accumulate per-request
        # tuples forever
        self.span_log: Deque[tuple] = deque(maxlen=256)
        self._cancelled: set = set()
        self.alive = True
        self.draining = False
        self.inflight = 0
        self.inflight_slots = 0   # KV pool slots staged for in-flight reqs
        self.served = 0
        self._busy_until = 0.0
        self.engine.submit_recvs(1 << 16, 8, self._on_msg)
        self.client: Optional[ControlClient] = None
        if ctrl is not None:
            self.client = ControlClient(
                self.engine, fabric, ctrl.address(),
                peer_id or node, "prefill", renew_us=renew_us,
                max_renewals=max_renewals,
                alive_fn=lambda: self.alive,
                # piggybacked load is POOL-SLOT pressure, not request count:
                # the scheduler's least-loaded policy compares it with its
                # own slot-weighted outstanding ledger (same units)
                inflight_fn=lambda: self.inflight_slots,
                free_pages_fn=lambda: len(self.pool._free),
                on_drain=self._on_drain, retry=ctrl_retry)
            self.client.join(nic=nic, kv_desc=self.pool.desc,
                             geom=_geom_wire(cfg, self.schema),
                             n_pages=n_pages, schema=self.schema.to_wire())

    def _plan(self, seq_len: int) -> TransferPlan:
        return _cached_plan(self._plans, self.schema, seq_len)

    def _fence_epoch(self) -> Optional[int]:
        """View epoch stamped onto outbound KV WRITEs (zombie guard).

        Read fresh at every span submission so a WRITE always carries the
        epoch its sender currently believes in — a zombie that kept the
        stale epoch of its lapsed lease is exactly what the receiving
        engine's fence rejects.  None (no ctrl attachment, or JOIN-ACK not
        yet received) posts unstamped, never-fenced WRITEs — pre-PR
        behaviour."""
        return self.client.epoch if self.client is not None else None

    def address(self) -> NetAddr:
        return self.engine.address(0)

    def cancel(self, request_id: int) -> None:
        self._cancelled.add(request_id)

    def crash(self) -> None:
        """Simulated process death: stop serving AND stop renewing the
        lease — the control plane notices via lease expiry, never via a
        goodbye message."""
        self.alive = False

    # -- control-plane hooks ------------------------------------------------
    def _on_drain(self, msg: m.Drain) -> None:
        self.draining = True
        self._maybe_finish_drain()

    def _maybe_finish_drain(self) -> None:
        if (self.draining and self.inflight == 0 and self.alive
                and self.client is not None and not self.client.left):
            # every in-flight request finished and freed its staging pages
            self.client.leave()

    # -- data plane ---------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        if not self.alive:
            return
        msg = m.decode(payload)
        if self.client is not None and self.client.handle(msg):
            return
        if isinstance(msg, DispatchReq):
            self._on_request(msg)

    def _on_request(self, req: DispatchReq) -> None:
        if req.request_id in self._cancelled:
            return
        if self.draining:
            # the scheduler never routes to a draining peer; anything that
            # races the drain is dropped (the sender re-routes on the next
            # view) rather than silently extending the drain
            self.stats["rejected"] = self.stats.get("rejected", 0) + 1
            return
        cfg = self.cfg
        if req.schema is not None:
            reason = self.schema.mismatch(KvSchema.from_wire(req.schema))
            if reason is not None:
                raise ValueError(
                    f"DispatchReq {req.request_id}: decoder KvSchema "
                    f"incompatible with this prefiller: {reason}")
        S = len(req.input_ids)
        plan = self._plan(S)
        t_start = self.fabric.now
        self.inflight += 1
        self.inflight_slots += plan.n_slots
        self.served += 1

        # One request occupies the GPU at a time: queue behind _busy_until.
        start = max(t_start, self._busy_until)
        self._busy_until = start + cfg.n_layers * self.layer_compute_us
        delay0 = start - t_start
        self.stats[f"req{req.request_id}_queued_us"] = delay0
        tr = self.fabric.tracer
        if tr is not None:
            tr.compute_span(f"{self.engine.node} gpu",
                            f"prefill:req{req.request_id}",
                            start, self._busy_until, phase="serving.prefill")

        # REAL prefill compute (all layers at once — jax scan); both ends
        # derive cache geometry from plan.max_len so ring slot assignment
        # and padding agree bit-for-bit.
        tokens = jnp.asarray(req.input_ids, jnp.int32)[None]
        logits, cache = prefill(self.params, tokens, cfg,
                                max_len=plan.max_len, moe_mode="dense",
                                vision_emb=_vision_batch(cfg, req.vision_emb))
        logits = logits[..., :cfg.vocab]   # drop vocab padding

        # stage EVERY schema component into pool slots, canonical order
        local_pages = self.pool.alloc(plan.n_slots)
        stage_cache(plan, self.pool, local_pages, cache)

        # tail context: last-token logits
        tail = np.asarray(logits, np.float32).reshape(-1).view(np.uint8)
        tail_buf = np.zeros(tail.size, np.uint8)
        tail_buf[:] = tail
        tail_handle, _ = self.engine.reg_mr(tail_buf)

        cnt = {"done": 0}
        failed = {"sent": False}
        total_writes = plan.total_writes + 1

        def on_xfer_error(reason: str) -> None:
            # a KV WRITE exhausted its retry budget: abandon THIS attempt
            # (no further spans, pages freed by the poll loop) and surface
            # a structured failure to the decoder, which forwards it to
            # the scheduler for a re-route.  First failure wins — sibling
            # component groups failing later are folded into it; a
            # cancelled attempt stays silent (its decoder-side state is
            # gone, so a late XferFail could only mis-target a re-route).
            # The prefiller doesn't know its attempt number (DispatchReq
            # stays attempt-free so fault-free wire bytes match pre-fault
            # builds bit-exactly) — it sends -1 and the decoder stamps the
            # authoritative attempt from its pending state.
            if (failed["sent"] or not self.alive
                    or req.request_id in self._cancelled):
                return
            failed["sent"] = True
            self.stats["xfer_failures"] = \
                self.stats.get("xfer_failures", 0) + 1
            tr = self.fabric.tracer
            if tr is not None:
                tr.instant("serving", f"xfer_fail:req{req.request_id}",
                           {"reason": reason})
            peer = self.client.peer_id if self.client else self.engine.node
            self.engine.submit_send(req.decoder_addr, m.encode(m.XferFail(
                request_id=req.request_id, attempt=-1,
                peer_id=peer, reason=reason)))

        def send_layers(lo: int, hi: int) -> None:
            # Model layers [lo, hi) completed since the last poll land as
            # ONE batched submission: every component page the span unlocks
            # rides a single WrBatch, distinct imm per component.  The UVM
            # poller coalesces increments, so coalesced layers share it too.
            if (not self.alive or req.request_id in self._cancelled
                    or failed["sent"] or hi <= lo):
                return
            with traced_phase(self.fabric, "serving.kv_span"):
                n = plan.submit_span(
                    self.engine, self.pool.handle, local_pages,
                    req.kv_desc, req.pages, req.imm, lo, hi,
                    on_sent=lambda n: cnt.__setitem__("done", cnt["done"] + n),
                    on_error=on_xfer_error,
                    fence_epoch=self._fence_epoch())
            if n:
                self.span_log.append((req.request_id, lo, hi, n))

        # UvmWatcher: the "GPU" increments after each layer's output is
        # ready; the watcher callback sends the completed span (App. A).
        watcher = self.engine.alloc_uvm_watcher(send_layers)
        for l in range(cfg.n_layers):
            self.fabric.loop.schedule(delay0 + (l + 1) * self.layer_compute_us,
                                      lambda l=l: watcher.store(l + 1))

        def send_tail() -> None:
            if (not self.alive or req.request_id in self._cancelled
                    or failed["sent"]):
                return
            with traced_phase(self.fabric, "serving.tail"):
                self.engine.submit_single_write(
                    tail.size, req.imm + plan.n_imms, (tail_handle, 0),
                    (req.tail_desc, req.tail_idx * tail.size),
                    on_done=lambda: cnt.__setitem__("done", cnt["done"] + 1),
                    on_error=on_xfer_error,
                    fence_epoch=self._fence_epoch())

        self.fabric.loop.schedule(
            delay0 + cfg.n_layers * self.layer_compute_us + 1.0, send_tail)

        def poll_free() -> None:
            if not self.alive:
                return        # crashed: the node (and its pool) is gone
            if req.request_id in self._cancelled or failed["sent"]:
                self.pool.free(local_pages)
                self.inflight -= 1
                self.inflight_slots -= plan.n_slots
                self._maybe_finish_drain()
                return
            if cnt["done"] >= total_writes:
                self.pool.free(local_pages)
                self.inflight -= 1
                self.inflight_slots -= plan.n_slots
                self.stats[f"req{req.request_id}_prefill_us"] = \
                    self.fabric.now - t_start
                self._maybe_finish_drain()
            else:
                self.fabric.loop.schedule(5.0, poll_free)

        self.fabric.loop.schedule(
            delay0 + cfg.n_layers * self.layer_compute_us, poll_free)


class Decoder:
    """Decode node: pre-allocates pages, dispatches, decodes on completion.

    With ``ctrl=`` the decoder also serves the elastic wire path: the
    scheduler SENDs ``SubmitReq``s here, completion is reported back via
    ``ReqDone``, and ``CancelReq`` (failover) frees the attempt's pages and
    tail slot so nothing leaks when a prefiller dies mid-transfer.
    """

    def __init__(self, fabric: Fabric, node: str, cfg, params, *,
                 nic: str = "efa", page_tokens: int = 16, n_pages: int = 512,
                 max_tail: int = 16, ctrl: Optional[ControlPlane] = None,
                 peer_id: Optional[str] = None, renew_us: float = 500.0,
                 max_renewals: int = 256, host: Optional[str] = None,
                 ctrl_retry: Optional[CtrlRetryPolicy] = None):
        _check_supported(cfg)
        self.cfg = cfg
        self.params = params
        self.fabric = fabric
        # host: physical machine identity (NVLink domain) — see Prefiller
        self.engine = fabric.add_engine(node, nic=nic, host=host)
        self.schema = schema_from_config(cfg, page_tokens)
        self.pool = KvPool(self.engine, self.schema, n_pages)
        self._plans: Dict[int, TransferPlan] = {}
        tail_bytes = cfg.vocab * 4
        self.tail_buf = np.zeros(max_tail * tail_bytes, np.uint8)
        self.tail_handle, self.tail_desc = self.engine.reg_mr(self.tail_buf)
        self._tail_free = list(range(max_tail))
        self._imm_next = 1
        self.alive = True
        self.draining = False
        self.results: Dict[int, Dict] = {}
        self._pending: Dict[int, Dict] = {}   # rid -> in-flight attempt state
        self._attempt: Dict[int, int] = {}    # rid -> newest attempt seen
        # (rid, attempt, reason) per XferFail accepted — fault forensics
        self.xfer_failed: List[tuple] = []
        # rid -> (attempt, reply_to, peer_id, reason): the last XferFail
        # forwarded to the scheduler, kept for replay when a retransmitted
        # SUBMIT shows the scheduler never saw it
        self._xfail_sent: Dict[int, tuple] = {}
        self.replayed_dones = 0               # ReqDone replays (lost-ack path)
        self.engine.submit_recvs(1 << 16, 32, self._on_msg)
        self.client: Optional[ControlClient] = None
        if ctrl is not None:
            self.client = ControlClient(
                self.engine, fabric, ctrl.address(),
                peer_id or node, "decode", renew_us=renew_us,
                max_renewals=max_renewals,
                alive_fn=lambda: self.alive,
                inflight_fn=lambda: sum(st["plan"].n_slots
                                        for st in self._pending.values()),
                free_pages_fn=lambda: len(self.pool._free),
                on_drain=self._on_drain, retry=ctrl_retry)
            self.client.join(nic=nic, kv_desc=self.pool.desc,
                             geom=_geom_wire(cfg, self.schema),
                             n_pages=n_pages, schema=self.schema.to_wire())

    def _plan(self, seq_len: int) -> TransferPlan:
        return _cached_plan(self._plans, self.schema, seq_len)

    def address(self) -> NetAddr:
        return self.engine.address(0)

    def crash(self) -> None:
        """Simulated process death (mirror of :meth:`Prefiller.crash`):
        stop decoding and stop renewing the lease — peers learn via lease
        expiry, never via a goodbye message.  KV WRITEs already in flight
        still land in this pool's memory (the NIC outlives the process in
        the model), but no completion callback runs."""
        self.alive = False

    # -- control-plane hooks ------------------------------------------------
    def _on_drain(self, msg: m.Drain) -> None:
        self.draining = True
        self._maybe_finish_drain()

    def _maybe_finish_drain(self) -> None:
        if (self.draining and not self._pending and self.alive
                and self.client is not None and not self.client.left):
            self.client.leave()

    # -- wire path ----------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        if not self.alive:
            return
        msg = m.decode(payload)
        if self.client is not None and self.client.handle(msg):
            return
        if isinstance(msg, m.SubmitReq):
            if self.draining:
                # racing a drain: drop — once this decoder LEAVEs, the
                # scheduler re-routes every request still pointed at it
                return
            cur = self._attempt.get(msg.request_id, -1)
            if msg.attempt < cur:
                return      # stale duplicate of an attempt we've superseded
            if msg.attempt == cur:
                # retransmission of the attempt we're already serving: the
                # scheduler didn't see our reply — replay it (lost-ack
                # recovery), or stay silent while the attempt is in flight
                self._replay_reply(msg)
                return
            if msg.request_id in self._pending:
                self.cancel(msg.request_id)   # superseded by a re-route
            self._attempt[msg.request_id] = msg.attempt
            self.submit(msg.request_id, msg.input_ids, msg.prefiller,
                        n_decode=msg.n_decode, reply_to=msg.reply_to,
                        attempt=msg.attempt, vision_emb=msg.vision_emb)
        elif isinstance(msg, m.CancelReq):
            # fence first, unconditionally: even a CANCEL stale by attempt
            # number carries a valid zombie-writer fence (fences only
            # tighten, so installing twice or out of order is harmless)
            if msg.fence_node is not None and msg.fence_epoch is not None:
                self.engine.set_fence(msg.fence_node, msg.fence_epoch)
            # only the newest attempt may be cancelled; an unordered SEND
            # can deliver a stale CANCEL after its re-route's SUBMIT
            if msg.attempt == self._attempt.get(msg.request_id):
                self.cancel(msg.request_id)
        elif isinstance(msg, m.XferFail):
            # prefiller reports a mid-transfer retry exhaustion: free this
            # attempt's pages + imm expectations and escalate to the
            # scheduler for a re-route.  ``_pending`` presence is the
            # staleness guard — each attempt's prefiller sends at most one
            # XferFail (and none once cancelled), and the re-route that
            # would supersede this attempt is only triggered *by* this
            # message passing through here, so a pending entry always
            # belongs to the reporting prefiller's attempt.  The decoder
            # stamps the authoritative attempt number before forwarding
            # (the prefiller sent -1; DispatchReq carries no attempt so
            # fault-free wire bytes stay bit-identical).
            st = self._pending.get(msg.request_id)
            if st is None:
                return      # attempt already cancelled / completed
            attempt = st["attempt"]
            self.xfer_failed.append(
                (msg.request_id, attempt, msg.reason))
            self.cancel(msg.request_id)
            if st["reply_to"] is not None:
                self._xfail_sent[msg.request_id] = (
                    attempt, st["reply_to"], msg.peer_id, msg.reason)
                self.engine.submit_send(st["reply_to"], m.encode(m.XferFail(
                    request_id=msg.request_id, attempt=attempt,
                    peer_id=msg.peer_id, reason=msg.reason)))

    def _replay_reply(self, msg: m.SubmitReq) -> None:
        """Lost-ack recovery: the scheduler retransmitted a SUBMIT for the
        attempt we already know about, meaning our terminal reply (REQ-DONE
        or forwarded XFER-FAIL) may have been lost — re-send it.  While the
        attempt is still in flight the retransmission is a pure duplicate
        and is dropped (the reply will go out once, when it completes)."""
        r = self.results.get(msg.request_id)
        if r is not None and "tokens" in r and r.get("_attempt") == msg.attempt \
                and r.get("_reply_to") is not None:
            self.replayed_dones += 1
            peer = self.client.peer_id if self.client else ""
            self.engine.submit_send(r["_reply_to"], m.encode(m.ReqDone(
                request_id=msg.request_id, attempt=r["_attempt"],
                peer_id=peer, ttft_us=r["ttft_us"],
                tokens=list(r["tokens"]))))
            return
        xf = self._xfail_sent.get(msg.request_id)
        if xf is not None and xf[0] == msg.attempt:
            attempt, reply_to, peer_id, reason = xf
            self.engine.submit_send(reply_to, m.encode(m.XferFail(
                request_id=msg.request_id, attempt=attempt,
                peer_id=peer_id, reason=reason)))

    def cancel(self, request_id: int) -> bool:
        """Abandon an in-flight attempt: free pages + tail slot, drop every
        component's ImmCounter expectation.  Nothing leaks — failover
        re-allocates."""
        st = self._pending.pop(request_id, None)
        if st is None:
            return False
        for off in range(st["n_imms"] + 1):   # components + tail
            self.engine.counters[0].reset(st["imm"] + off)
        self.pool.free(st["pages"])
        self._tail_free.append(st["tail_idx"])
        self.results.pop(request_id, None)
        self._maybe_finish_drain()
        return True

    # ------------------------------------------------------------------
    def submit(self, request_id: int, input_ids: np.ndarray,
               prefiller: NetAddr, n_decode: int = 4, *,
               reply_to: Optional[NetAddr] = None, attempt: int = 0,
               vision_emb: Optional[np.ndarray] = None) -> None:
        if n_decode > DECODE_MARGIN:
            # the handoff cache holds seq_len + DECODE_MARGIN positions;
            # decoding past it would silently drop cache updates (jax
            # clips out-of-bounds .at[] writes) and diverge from monolithic
            raise ValueError(
                f"n_decode={n_decode} exceeds the handoff cache headroom "
                f"(DECODE_MARGIN={DECODE_MARGIN})")
        S = len(input_ids)
        plan = self._plan(S)
        pages = self.pool.alloc(plan.n_slots)
        tail_idx = self._tail_free.pop(0)
        # one immediate per schema component plus the tail write
        imm = self._imm_next
        self._imm_next += plan.n_imms + 1
        t0 = self.fabric.now
        self._pending[request_id] = {
            "pages": pages, "tail_idx": tail_idx, "imm": imm,
            "n_imms": plan.n_imms, "plan": plan,
            "attempt": attempt, "reply_to": reply_to, "seq_len": S,
        }

        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("serving", f"submit:req{request_id}",
                       {"seq_len": S, "attempt": attempt})
        req = DispatchReq(input_ids=np.asarray(input_ids),
                          decoder_addr=self.address(),
                          imm=imm, kv_desc=self.pool.desc, pages=pages,
                          tail_desc=self.tail_desc, tail_idx=tail_idx,
                          request_id=request_id, vision_emb=vision_emb,
                          schema=self.schema.to_wire())

        expectations = plan.expected_counts() + [(plan.n_imms, 1)]  # + tail
        remaining = {"n": len(expectations)}

        def part_done() -> None:
            if not self.alive:
                return      # crashed mid-handoff: never decode as a zombie
            st = self._pending.get(request_id)
            if st is None or st["imm"] != imm:
                return      # attempt was cancelled / superseded
            remaining["n"] -= 1
            if remaining["n"]:
                return
            self.results[request_id] = {
                "ttft_us": self.fabric.now - t0,
                "pages": pages, "tail_idx": tail_idx, "seq_len": S,
                "plan": plan,
            }
            if tr is not None:
                tr.instant("serving", f"kv_ready:req{request_id}",
                           {"ttft_us": self.fabric.now - t0})
            self._decode(request_id, n_decode)

        for off, count in expectations:
            self.engine.expect_imm_count(imm + off, count, part_done)
        self.engine.submit_send(prefiller, m.encode(req))

    def _assemble_cache(self, request_id: int):
        r = self.results[request_id]
        plan: TransferPlan = r["plan"]
        cache = init_cache(self.cfg, 1, plan.max_len)
        for name, arr in fill_cache(plan, self.pool, r["pages"],
                                    cache).items():
            cache[name] = jnp.asarray(arr, cache[name].dtype)
        return cache

    def _decode(self, request_id: int, n_decode: int) -> None:
        cfg = self.cfg
        r = self.results[request_id]
        tail_bytes = cfg.vocab * 4
        logits = (self.tail_buf[r["tail_idx"] * tail_bytes:
                                (r["tail_idx"] + 1) * tail_bytes]
                  .view(np.float32).reshape(1, cfg.vocab))
        cache = self._assemble_cache(request_id)
        toks = [int(np.argmax(logits[0]))]
        pos = r["seq_len"]
        for _ in range(n_decode - 1):
            lg, cache = decode_step(self.params, jnp.asarray([[toks[-1]]]),
                                    jnp.asarray([pos], jnp.int32), cache, cfg,
                                    moe_mode="dense")
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        r["tokens"] = toks
        self.pool.free(r["pages"])
        self._tail_free.append(r["tail_idx"])
        st = self._pending.pop(request_id, None)
        if st is not None and st["reply_to"] is not None:
            # stash the reply identity so a retransmitted SUBMIT for this
            # attempt can replay the REQ-DONE (lost-ack recovery)
            r["_reply_to"] = st["reply_to"]
            r["_attempt"] = st["attempt"]
            peer = self.client.peer_id if self.client else ""
            self.engine.submit_send(st["reply_to"], m.encode(m.ReqDone(
                request_id=request_id, attempt=st["attempt"], peer_id=peer,
                ttft_us=r["ttft_us"], tokens=list(toks))))
        self._maybe_finish_drain()
