"""Global scheduler for disaggregated serving (paper Fig. 3).

Selects a (prefiller, decoder) pair per request and forwards the request to
the decoder, which pre-allocates KV pages and dispatches to the prefiller.
Heartbeats between peers detect transport failures; a dead prefiller causes
timed-out requests to be cancelled on the decoder (§4 error handling).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..core import Fabric, NetAddr
from .disagg import Decoder, Prefiller

HEARTBEAT_US = 1_000.0
HEARTBEAT_TIMEOUT_US = 5_000.0


class Scheduler:
    def __init__(self, fabric: Fabric, prefillers: List[Prefiller],
                 decoders: List[Decoder]):
        self.fabric = fabric
        self.prefillers = prefillers
        self.decoders = decoders
        self._rr = itertools.count()
        self._req = itertools.count()
        self.last_heartbeat: Dict[NetAddr, float] = {
            p.address(): 0.0 for p in prefillers}
        self.dead: set = set()
        self._start_heartbeats()

    def _start_heartbeats(self, max_beats: int = 64) -> None:
        """Bounded heartbeat train (keeps run_until_idle finite)."""
        state = {"n": 0}

        def beat() -> None:
            for p in self.prefillers:
                addr = p.address()
                if getattr(p, "alive", True):
                    self.last_heartbeat[addr] = self.fabric.now
                elif self.fabric.now - self.last_heartbeat[addr] > HEARTBEAT_TIMEOUT_US:
                    self.dead.add(addr)
            state["n"] += 1
            if state["n"] < max_beats:
                self.fabric.loop.schedule(HEARTBEAT_US, beat)

        self.fabric.loop.schedule(HEARTBEAT_US, beat)

    def live_prefillers(self) -> List[Prefiller]:
        return [p for p in self.prefillers
                if p.address() not in self.dead and getattr(p, "alive", True)]

    def submit(self, input_ids: np.ndarray, n_decode: int = 4) -> int:
        """Route a request; returns request id."""
        rid = next(self._req)
        live = self.live_prefillers()
        if not live:
            raise RuntimeError("no live prefillers")
        p = live[next(self._rr) % len(live)]
        d = self.decoders[rid % len(self.decoders)]
        d.submit(rid, input_ids, p.address(), n_decode=n_decode)
        return rid
