"""Elastic global scheduler for disaggregated serving (paper Fig. 3 + §4
"dynamic scaling").

The scheduler holds NO static peer list and NO peer object references.  It
subscribes to the control plane and routes every request against the
current epoch's :class:`~repro.ctrl.registry.MembershipView`:

* requests enter a backlog and are pumped whenever both a routable (live,
  non-draining) prefiller and decoder exist in the view;
* routing is a wire operation — a typed ``SubmitReq`` SENT to the chosen
  decoder, which dispatches to the chosen prefiller; completion comes back
  as a ``ReqDone`` carrying TTFT and the generated tokens;
* when a peer vanishes from the view (lease expiry == crash, or LEAVE),
  every in-flight request routed through it is cancelled at its decoder
  (freeing the attempt's KV pages) and re-queued with a bumped attempt
  number — post-failure requests complete on the surviving peers;
* liveness is entirely the control plane's lease machinery; the seed's
  hand-rolled heartbeat loop is gone.

``routing_log`` records ``(rid, epoch, prefiller, decoder)`` per route so
tests and benchmarks can prove that all routing went through epoch views.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core import Fabric
from ..ctrl import ControlPlane, MembershipView
from ..ctrl import messages as m

TTFT_EMA_ALPHA = 0.3


class Scheduler:
    def __init__(self, fabric: Fabric, ctrl: ControlPlane, *,
                 node: str = "sched"):
        self.fabric = fabric
        self.ctrl = ctrl
        self.engine = fabric.add_engine(node, nic=ctrl.nic)
        self.engine.submit_recvs(1 << 16, 64, self._on_msg)
        self.view = MembershipView(0, ())
        self.view_epochs: List[int] = []       # every accepted epoch, in order
        self._rr = {"prefill": 0, "decode": 0}
        self._req = itertools.count()
        # (rid, input_ids, n_decode, attempt); appendleft on re-route
        self.backlog: Deque[Tuple[int, np.ndarray, int, int]] = deque()
        self.inflight: Dict[int, Dict] = {}
        self.completed: Dict[int, Dict] = {}
        self.ttft_ema: Optional[float] = None
        self.rerouted: List[int] = []
        self.routing_log: List[Tuple[int, int, str, str]] = []
        ctrl.subscribe(self.engine.address(0))

    # -- signals (read by the Autoscaler) -----------------------------------
    def queue_depth(self) -> int:
        return len(self.backlog) + len(self.inflight)

    def check_drained(self) -> None:
        """Fail fast after the event loop idles: queuing is normal *while*
        the fabric runs (requests may arrive before peers join — that is
        the elasticity contract), but anything still queued or in flight
        once the loop is idle means the fleet was misconfigured (peers
        built without ``ctrl=``, wrong NIC, no decoders, ...)."""
        if self.backlog or self.inflight:
            routable = {role: [p.peer_id for p in self.view.routable(role)]
                        for role in ("prefill", "decode")}
            raise RuntimeError(
                f"{len(self.backlog)} queued + {len(self.inflight)} in-flight "
                f"requests never completed (view epoch {self.view.epoch}, "
                f"routable {routable})")

    # -- submission ---------------------------------------------------------
    def submit(self, input_ids: np.ndarray, n_decode: int = 4) -> int:
        """Queue a request; it is routed when the view offers capacity."""
        rid = next(self._req)
        self.backlog.append((rid, np.asarray(input_ids), n_decode, 0))
        self._pump()
        return rid

    def _pick(self, role: str):
        cands = self.view.routable(role)
        if not cands:
            return None
        c = cands[self._rr[role] % len(cands)]
        self._rr[role] += 1
        return c

    def _pump(self) -> None:
        while self.backlog:
            pf = self._pick("prefill")
            dc = self._pick("decode")
            if pf is None or dc is None:
                return
            rid, ids, n_decode, attempt = self.backlog.popleft()
            self.inflight[rid] = dict(
                ids=ids, n_decode=n_decode, attempt=attempt,
                prefiller=pf.peer_id, decoder=dc.peer_id,
                decoder_addr=dc.addr, epoch=self.view.epoch,
                t_routed=self.fabric.now)
            self.routing_log.append((rid, self.view.epoch,
                                     pf.peer_id, dc.peer_id))
            self.engine.submit_send(dc.addr, m.encode(m.SubmitReq(
                request_id=rid, input_ids=ids, prefiller=pf.addr,
                n_decode=n_decode, reply_to=self.engine.address(0),
                attempt=attempt)))

    # -- wire handling ------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        msg = m.decode(payload)
        if isinstance(msg, m.ViewUpdate):
            if msg.epoch <= self.view.epoch:
                return     # stale/duplicate view: epochs only move forward
            new = MembershipView.from_wire(msg.epoch, msg.peers)
            self.view_epochs.append(new.epoch)
            gone = set(self.view.ids()) - set(new.ids())
            self.view = new
            if gone:
                self._reroute(gone)
            self._pump()
        elif isinstance(msg, m.ReqDone):
            st = self.inflight.get(msg.request_id)
            if st is None or st["attempt"] != msg.attempt:
                return     # stale attempt (already re-routed)
            del self.inflight[msg.request_id]
            self.completed[msg.request_id] = dict(
                ttft_us=msg.ttft_us, tokens=list(msg.tokens),
                decoder=msg.peer_id, prefiller=st["prefiller"],
                attempt=msg.attempt, t_routed=st["t_routed"],
                done_us=self.fabric.now)
            self.ttft_ema = msg.ttft_us if self.ttft_ema is None else (
                TTFT_EMA_ALPHA * msg.ttft_us
                + (1 - TTFT_EMA_ALPHA) * self.ttft_ema)
            self._pump()

    def _reroute(self, gone: set) -> None:
        """Cancel + re-queue every in-flight request touching a gone peer."""
        for rid, st in list(self.inflight.items()):
            if st["prefiller"] not in gone and st["decoder"] not in gone:
                continue
            del self.inflight[rid]
            if st["decoder"] not in gone:
                # free the dead attempt's pages at the (live) decoder
                self.engine.submit_send(st["decoder_addr"], m.encode(
                    m.CancelReq(rid, st["attempt"])))
            self.rerouted.append(rid)
            self.backlog.appendleft(
                (rid, st["ids"], st["n_decode"], st["attempt"] + 1))
