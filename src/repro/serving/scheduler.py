"""Elastic global scheduler for disaggregated serving (paper Fig. 3 + §4
"dynamic scaling").

The scheduler holds NO static peer list and NO peer object references.  It
subscribes to the control plane and routes every request against the
current epoch's :class:`~repro.ctrl.registry.MembershipView`:

* requests enter a backlog and are pumped whenever the view offers a
  routable (live, non-draining) prefiller/decoder pair whose advertised
  ``KvSchema``s match — mismatched cache layouts are refused HERE, at
  routing time, never mid-transfer (``schema_mismatches`` counts refusals);
* routing is a wire operation — a typed ``SubmitReq`` SENT to the chosen
  decoder, which dispatches to the chosen prefiller; completion comes back
  as a ``ReqDone`` carrying TTFT and the generated tokens;
* when a peer vanishes from the view (lease expiry == crash, or LEAVE),
  every in-flight request routed through it is cancelled at its decoder
  (freeing the attempt's KV pages) and re-queued with a bumped attempt
  number — post-failure requests complete on the surviving peers;
* liveness is entirely the control plane's lease machinery.

Routing policy is a knob: ``policy="round-robin"`` (default) rotates
through the routable peers; ``policy="least-loaded"`` orders them by load
— the ``inflight`` signal piggybacked on LEASE-RENEWs (refreshed into
views at every epoch bump) combined with this scheduler's own outstanding
ledger, which is exact between view refreshes.  The local ledger weights
every routed request by its ``TransferPlan.n_slots`` on the chosen
decoder's advertised ``KvSchema`` — actual KV-pool pressure — so a peer
holding one 4000-token prompt is not considered "less loaded" than one
holding three 20-token prompts (schema-less peers weigh 1 per request).

``routing_log`` records ``(rid, epoch, prefiller, decoder)`` per route so
tests and benchmarks can prove that all routing went through epoch views.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core import Fabric
from ..ctrl import ControlPlane, CtrlRetryPolicy, MembershipView
from ..ctrl import messages as m
from ..kvlayout import DECODE_MARGIN, KvSchema, TransferPlan

TTFT_EMA_ALPHA = 0.3

POLICIES = ("round-robin", "least-loaded")


class Scheduler:
    def __init__(self, fabric: Fabric, ctrl: ControlPlane, *,
                 node: str = "sched", policy: str = "round-robin",
                 slo=None, max_attempts: int = 4,
                 retry: Optional[CtrlRetryPolicy] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.fabric = fabric
        self.ctrl = ctrl
        self.policy = policy
        # re-route budget per request under mid-transfer failures
        # (XferFail): attempts beyond this land in ``failed`` terminally
        self.max_attempts = max_attempts
        # optional repro.serving.slo.SloTracker: fed per completion, read
        # by the Autoscaler as its percentile latency signal
        self.slo = slo
        self.engine = fabric.add_engine(node, nic=ctrl.nic)
        self.engine.submit_recvs(1 << 16, 64, self._on_msg)
        self.view = MembershipView(0, ())
        self.view_epochs: List[int] = []       # every accepted epoch, in order
        self._rr = {"prefill": 0, "decode": 0}
        self._req = itertools.count()
        # locally routed, not-yet-done load per peer id, in KV pool slots
        # (exact between view refreshes; the view's inflight is the
        # cross-scheduler signal)
        self._outstanding: Dict[str, int] = {}
        self._slot_cache: Dict[Tuple[str, int], int] = {}
        self.schema_mismatches = 0
        # (rid, input_ids, n_decode, attempt, vision_emb); appendleft on
        # re-route
        self.backlog: Deque[Tuple] = deque()
        self.inflight: Dict[int, Dict] = {}
        self.completed: Dict[int, Dict] = {}
        # rid -> terminal failure record (re-route budget exhausted)
        self.failed: Dict[int, Dict] = {}
        # (rid, attempt, reason) per accepted XferFail — fault forensics
        self.xfer_failures: List[Tuple[int, int, str]] = []
        self.ttft_ema: Optional[float] = None
        self.rerouted: List[int] = []
        self.routing_log: List[Tuple[int, int, str, str]] = []
        # ctrl reliability (PR 10): when a CtrlRetryPolicy is attached every
        # SubmitReq is stamped with (node, seq) and retransmitted on a
        # bounded backoff chain until the attempt resolves — the decoder
        # dedups/replays by attempt, so retransmits are safe.  None keeps
        # the wire bytes bit-identical to the retry-less scheduler.
        self.retry = retry
        self._seq = itertools.count(1)
        self.submit_resends = 0
        self.cancel_resends = 0
        # rids whose SubmitReq retry chain exhausted without resolution
        self.ctrl_retry_exhausted: List[int] = []
        ctrl.subscribe(self.engine.address(0))

    # -- signals (read by the Autoscaler) -----------------------------------
    def queue_depth(self) -> int:
        return len(self.backlog) + len(self.inflight)

    def check_drained(self) -> None:
        """Fail fast after the event loop idles: queuing is normal *while*
        the fabric runs (requests may arrive before peers join — that is
        the elasticity contract), but anything still queued or in flight
        once the loop is idle means the fleet was misconfigured (peers
        built without ``ctrl=``, wrong NIC, no decoders, mismatched
        KvSchemas, ...)."""
        if self.backlog or self.inflight:
            routable = {role: [p.peer_id for p in self.view.routable(role)]
                        for role in ("prefill", "decode")}
            raise RuntimeError(
                f"{len(self.backlog)} queued + {len(self.inflight)} in-flight "
                f"requests never completed (view epoch {self.view.epoch}, "
                f"routable {routable}, "
                f"schema mismatches {self.schema_mismatches})")

    # -- submission ---------------------------------------------------------
    def submit(self, input_ids: np.ndarray, n_decode: int = 4, *,
               vision_emb: Optional[np.ndarray] = None) -> int:
        """Queue a request; it is routed when the view offers capacity."""
        if n_decode > DECODE_MARGIN:
            # reject before routing: the decoder enforces the same bound,
            # but a wire-path rejection would crash the decoder's recv
            # callback mid-run instead of failing the caller cleanly
            raise ValueError(
                f"n_decode={n_decode} exceeds the handoff cache headroom "
                f"(DECODE_MARGIN={DECODE_MARGIN})")
        rid = next(self._req)
        self.backlog.append((rid, np.asarray(input_ids), n_decode, 0,
                             vision_emb))
        if self.slo is not None:
            self.slo.observe_queue_depth(self.queue_depth())
        self._pump()
        return rid

    def _load(self, p) -> int:
        """Effective load of a peer: the LEASE-RENEW-piggybacked inflight
        captured at the last epoch bump, or this scheduler's own
        slot-weighted outstanding ledger when that is fresher."""
        return max(p.inflight, self._outstanding.get(p.peer_id, 0))

    def _req_slots(self, peer, seq_len: int) -> int:
        """Pool-pressure weight of one request: the KV pool slots its
        transfer plan occupies on ``peer`` (1 for schema-less peers)."""
        if peer.schema is None:
            return 1
        key = (peer.peer_id, seq_len)
        n = self._slot_cache.get(key)
        if n is None:
            plan = TransferPlan(KvSchema.from_wire(dict(peer.schema)), seq_len)
            n = self._slot_cache[key] = plan.n_slots
        return n

    def _candidates(self, role: str):
        """Routable peers of ``role`` in policy preference order."""
        cands = list(self.view.routable(role))
        if not cands:
            return []
        if self.policy == "least-loaded":
            return sorted(cands, key=lambda p: (self._load(p), p.peer_id))
        i = self._rr[role] % len(cands)
        return cands[i:] + cands[:i]

    @staticmethod
    def _schemas_match(pf, dc) -> bool:
        if pf.schema is None or dc.schema is None:
            return True      # schema-less (hand-wired) peers: no gating
        return pf.schema == dc.schema

    def _pick_pair(self):
        """First (prefiller, decoder) pair with compatible KvSchemas."""
        dcs = self._candidates("decode")
        rejected = False
        for pf in self._candidates("prefill"):
            for dc in dcs:
                if self._schemas_match(pf, dc):
                    return pf, dc
                rejected = True
        if rejected:
            self.schema_mismatches += 1
        return None

    def _pump(self) -> None:
        while self.backlog:
            pair = self._pick_pair()
            if pair is None:
                return
            pf, dc = pair
            if self.policy == "round-robin":
                self._rr["prefill"] += 1
                self._rr["decode"] += 1
            rid, ids, n_decode, attempt, vis = self.backlog.popleft()
            # both ends stage the same handoff cache: charge each the
            # request's slot footprint on the decoder's advertised schema
            slots = self._req_slots(dc, len(ids))
            self.inflight[rid] = dict(
                ids=ids, n_decode=n_decode, attempt=attempt, vision_emb=vis,
                prefiller=pf.peer_id, decoder=dc.peer_id, slots=slots,
                decoder_addr=dc.addr, epoch=self.view.epoch,
                t_routed=self.fabric.now)
            for pid in (pf.peer_id, dc.peer_id):
                self._outstanding[pid] = self._outstanding.get(pid, 0) + slots
            self.routing_log.append((rid, self.view.epoch,
                                     pf.peer_id, dc.peer_id))
            msg = m.SubmitReq(
                request_id=rid, input_ids=ids, prefiller=pf.addr,
                n_decode=n_decode, reply_to=self.engine.address(0),
                attempt=attempt, vision_emb=vis)
            if self.retry is None:
                self.engine.submit_send(dc.addr, m.encode(msg))
            else:
                payload = m.encode(msg, sender=self.engine.node,
                                   seq=next(self._seq))
                self.engine.submit_send(dc.addr, payload)
                self._arm_submit_retry(rid, attempt, dc.addr, payload, 0)

    def _arm_submit_retry(self, rid: int, attempt: int, addr, payload: bytes,
                          k: int) -> None:
        """Retransmit a SubmitReq until its attempt resolves (done, failed,
        or re-routed) or the retry budget is spent.  The decoder replays
        the terminal ReqDone/XferFail for an already-resolved attempt, so a
        lost *reply* is recovered by the same chain as a lost request."""
        pol = self.retry

        def check() -> None:
            st = self.inflight.get(rid)
            if st is None or st["attempt"] != attempt:
                return      # resolved or re-routed under a newer attempt
            if k >= pol.max_retries:
                self.ctrl_retry_exhausted.append(rid)
                rec = getattr(self.fabric, "recorder", None)
                if rec is not None:
                    rec.note("ctrl", f"submit-retry-exhausted:req{rid}",
                             {"attempt": attempt, "retries": k})
                    rec.dump("ctrl-retry-exhausted")
                return
            self.submit_resends += 1
            self.engine.submit_send(addr, payload)
            self._arm_submit_retry(rid, attempt, addr, payload, k + 1)

        self.fabric.loop.schedule(pol.timeout_us(k), check)

    def _release(self, st: Dict) -> None:
        for pid in (st["prefiller"], st["decoder"]):
            n = self._outstanding.get(pid, 0) - st.get("slots", 1)
            if n > 0:
                self._outstanding[pid] = n
            else:
                self._outstanding.pop(pid, None)

    # -- wire handling ------------------------------------------------------
    def _on_msg(self, payload: bytes) -> None:
        msg = m.decode(payload)
        if isinstance(msg, m.ViewUpdate):
            if msg.epoch <= self.view.epoch:
                return     # stale/duplicate view: epochs only move forward
            # a peer may have re-joined under the same id with a new schema
            self._slot_cache.clear()
            new = MembershipView.from_wire(msg.epoch, msg.peers)
            self.view_epochs.append(new.epoch)
            old_view = self.view
            gone = set(self.view.ids()) - set(new.ids())
            self.view = new
            if gone:
                self._reroute(gone, old_view)
            self._pump()
        elif isinstance(msg, m.ReqDone):
            st = self.inflight.get(msg.request_id)
            if st is None or st["attempt"] != msg.attempt:
                return     # stale attempt (already re-routed)
            del self.inflight[msg.request_id]
            self._release(st)
            self.completed[msg.request_id] = dict(
                ttft_us=msg.ttft_us, tokens=list(msg.tokens),
                decoder=msg.peer_id, prefiller=st["prefiller"],
                attempt=msg.attempt, t_routed=st["t_routed"],
                done_us=self.fabric.now)
            self.ttft_ema = msg.ttft_us if self.ttft_ema is None else (
                TTFT_EMA_ALPHA * msg.ttft_us
                + (1 - TTFT_EMA_ALPHA) * self.ttft_ema)
            if self.slo is not None:
                self.slo.observe_ttft(msg.ttft_us)
                self.slo.observe_queue_depth(self.queue_depth())
            self._pump()
        elif isinstance(msg, m.XferFail):
            # mid-transfer failure escalated by the decoder: both ends
            # already released the attempt's resources — re-route with a
            # bumped attempt, or fail terminally once the budget is spent
            st = self.inflight.get(msg.request_id)
            if st is None or st["attempt"] != msg.attempt:
                return     # stale attempt (already re-routed or done)
            del self.inflight[msg.request_id]
            self._release(st)
            self.xfer_failures.append(
                (msg.request_id, msg.attempt, msg.reason))
            tr = self.fabric.tracer
            if tr is not None:
                tr.instant("serving", f"xfer_fail:req{msg.request_id}",
                           {"attempt": msg.attempt, "reason": msg.reason,
                            "prefiller": msg.peer_id})
            if msg.attempt + 1 >= self.max_attempts:
                self.failed[msg.request_id] = dict(
                    reason=msg.reason, attempts=msg.attempt + 1,
                    prefiller=st["prefiller"], decoder=st["decoder"])
            else:
                self.rerouted.append(msg.request_id)
                self.backlog.appendleft(
                    (msg.request_id, st["ids"], st["n_decode"],
                     msg.attempt + 1, st["vision_emb"]))
            self._pump()

    def _reroute(self, gone: set,
                 old_view: Optional[MembershipView] = None) -> None:
        """Cancel + re-queue every in-flight request touching a gone peer.

        When the gone peer is the request's *prefiller*, the CancelReq
        piggybacks an epoch fence ``(fence_node, fence_epoch)`` naming the
        dead prefiller's fabric node and the new view's epoch: the decoder
        installs it on its engine before freeing the attempt's pages, so a
        zombie prefiller (expired lease, still computing) cannot land late
        WRITEs into reallocated KV pages."""
        for rid, st in list(self.inflight.items()):
            if st["prefiller"] not in gone and st["decoder"] not in gone:
                continue
            del self.inflight[rid]
            self._release(st)
            if st["decoder"] not in gone:
                fence_node = None
                if old_view is not None and st["prefiller"] in gone:
                    p = old_view.peer(st["prefiller"])
                    fence_node = p.addr.node if p is not None else None
                # free the dead attempt's pages at the (live) decoder
                payload = m.encode(m.CancelReq(
                    rid, st["attempt"], fence_node=fence_node,
                    fence_epoch=(self.view.epoch
                                 if fence_node is not None else None)))
                self.engine.submit_send(st["decoder_addr"], payload)
                if self.retry is not None:
                    # CancelReq is idempotent at the decoder (pop of an
                    # absent attempt is a no-op; fences only tighten), so
                    # blind bounded retransmits cover ctrl-SEND loss
                    self._blind_resend(st["decoder_addr"], payload,
                                       "cancel_resends")
            self.rerouted.append(rid)
            self.backlog.appendleft(
                (rid, st["ids"], st["n_decode"], st["attempt"] + 1,
                 st["vision_emb"]))

    def _blind_resend(self, addr, payload: bytes, counter: str) -> None:
        """Schedule bounded blind retransmits of an idempotent ctrl SEND."""
        pol = self.retry
        for k in range(min(2, pol.max_retries)):
            def resend(addr=addr, payload=payload, counter=counter) -> None:
                setattr(self, counter, getattr(self, counter) + 1)
                self.engine.submit_send(addr, payload)
            self.fabric.loop.schedule(pol.timeout_us(k), resend)
