"""Paged KV pools: fixed-size pages in a registered memory region.

Layout follows the paper's §4 note: heads PRECEDE pages ("the KvCaches are
laid out with heads preceding the pages, ensuring continuity within
consecutive heads") — a page is a contiguous block for one layer, so one
RDMA WRITE moves one page.

Two pools live here:

* :class:`PagedKvPool` — the original single-geometry pool (a page is one
  layer's ``page_tokens x n_kv x head_dim x 2`` k+v block).  Kept for
  uniform-stack tooling and control-plane tests.
* :class:`KvPool` — the schema-driven multi-component pool used by the
  serving stack: one page size per component (``KvComponent.page_len``)
  drawn from a SINGLE shared page allocator.  Slots are sized to the
  largest component page, so any free slot can host any component's page
  and the whole pool stays one ``MrDesc`` — a peer's entire reduced-cache
  state is addressable through one registered region regardless of how
  many components its architecture splits into.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from ..core import MrDesc, MrHandle, TransferEngine
from ..kvlayout import KvSchema


@dataclass
class PoolGeometry:
    n_layers: int
    page_tokens: int
    n_kv: int
    head_dim: int
    dtype: np.dtype = np.dtype(np.float32)

    @property
    def page_elems(self) -> int:
        # k and v halves of one page
        return self.page_tokens * self.n_kv * self.head_dim * 2

    @property
    def page_bytes(self) -> int:
        return self.page_elems * self.dtype.itemsize

    def pages_per_seq(self, seq_len: int) -> int:
        return -(-seq_len // self.page_tokens)


class PagedKvPool:
    """A pool of uniform KV pages registered with a TransferEngine."""

    def __init__(self, engine: TransferEngine, geom: PoolGeometry,
                 n_pages: int, device: int = 0):
        self.geom = geom
        self.n_pages = n_pages
        self.buf = np.zeros(n_pages * geom.page_bytes, np.uint8)
        self.handle, self.desc = engine.reg_mr(self.buf, device)
        self._free = list(range(n_pages))

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(f"KV pool exhausted ({n} > {len(self._free)})")
        out = self._free[:n]
        del self._free[:n]
        return out

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)

    # -- numpy views -----------------------------------------------------------
    def page_view(self, page: int) -> np.ndarray:
        g = self.geom
        lo = page * g.page_bytes
        return (self.buf[lo:lo + g.page_bytes]
                .view(g.dtype)
                .reshape(2, g.page_tokens, g.n_kv, g.head_dim))

    def write_page(self, page: int, k: np.ndarray, v: np.ndarray) -> None:
        view = self.page_view(page)
        t = k.shape[0]
        view[0, :t] = k
        view[1, :t] = v

    def read_page(self, page: int) -> Tuple[np.ndarray, np.ndarray]:
        view = self.page_view(page)
        return view[0], view[1]


class KvPool:
    """Schema-driven multi-component pool with a shared page allocator.

    Slot ``i`` occupies bytes ``[i * slot_bytes, (i+1) * slot_bytes)`` of
    one registered region; a component's page uses the first
    ``page_len`` bytes of its slot (``TransferPlan`` WRITEs exactly that
    many).  Allocation order is the plan's canonical slot order, so a flat
    page-id list describes a whole multi-component handoff.
    """

    def __init__(self, engine: TransferEngine, schema: KvSchema,
                 n_pages: int, device: int = 0):
        self.schema = schema
        self.slot_bytes = schema.slot_bytes
        self.n_pages = n_pages
        self.buf = np.zeros(n_pages * self.slot_bytes, np.uint8)
        self.handle, self.desc = engine.reg_mr(self.buf, device)
        self._free = list(range(n_pages))

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(f"KV pool exhausted ({n} > {len(self._free)})")
        out = self._free[:n]
        del self._free[:n]
        return out

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)

    # -- slot access (used by plan.stage_cache / plan.fill_cache) -----------
    def write_slot(self, page: int, data: np.ndarray) -> None:
        lo = page * self.slot_bytes
        self.buf[lo:lo + data.size] = data

    def read_slot(self, page: int, nbytes: int) -> np.ndarray:
        lo = page * self.slot_bytes
        return self.buf[lo:lo + nbytes]
