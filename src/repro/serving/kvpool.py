"""Paged KV pool: fixed-size pages in a registered memory region.

Layout follows the paper's §4 note: heads PRECEDE pages ("the KvCaches are
laid out with heads preceding the pages, ensuring continuity within
consecutive heads") — a page is a contiguous (page_tokens x n_kv x head_dim
x 2) block for one layer, so one RDMA WRITE moves one page.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from ..core import MrDesc, MrHandle, TransferEngine


@dataclass
class PoolGeometry:
    n_layers: int
    page_tokens: int
    n_kv: int
    head_dim: int
    dtype: np.dtype = np.dtype(np.float32)

    @property
    def page_elems(self) -> int:
        # k and v halves of one page
        return self.page_tokens * self.n_kv * self.head_dim * 2

    @property
    def page_bytes(self) -> int:
        return self.page_elems * self.dtype.itemsize

    def pages_per_seq(self, seq_len: int) -> int:
        return -(-seq_len // self.page_tokens)


class PagedKvPool:
    """A pool of KV pages registered with a TransferEngine."""

    def __init__(self, engine: TransferEngine, geom: PoolGeometry,
                 n_pages: int, device: int = 0):
        self.geom = geom
        self.n_pages = n_pages
        self.buf = np.zeros(n_pages * geom.page_bytes, np.uint8)
        self.handle, self.desc = engine.reg_mr(self.buf, device)
        self._free = list(range(n_pages))

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(f"KV pool exhausted ({n} > {len(self._free)})")
        out = self._free[:n]
        del self._free[:n]
        return out

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)

    # -- numpy views -----------------------------------------------------------
    def page_view(self, page: int) -> np.ndarray:
        g = self.geom
        lo = page * g.page_bytes
        return (self.buf[lo:lo + g.page_bytes]
                .view(g.dtype)
                .reshape(2, g.page_tokens, g.n_kv, g.head_dim))

    def write_page(self, page: int, k: np.ndarray, v: np.ndarray) -> None:
        view = self.page_view(page)
        t = k.shape[0]
        view[0, :t] = k
        view[1, :t] = v

    def read_page(self, page: int) -> Tuple[np.ndarray, np.ndarray]:
        view = self.page_view(page)
        return view[0], view[1]
