"""Serving SLO tracker: sliding-window TTFT / queue-depth percentiles.

The Autoscaler's original latency signal is a single TTFT EMA — cheap, but
a mean-like signal that hides tail degradation (one slow pair drags p99
long before the EMA moves).  :class:`SloTracker` keeps bounded sliding
windows of TTFT samples and queue-depth observations and serves
p50/p95/p99 via the same closest-rank interpolation as
:class:`repro.obs.metrics.Histogram` (shared ``rank_percentile``), so SLO
numbers in the autoscaler, the benches and the trace report all agree.

Breach tracking: when ``ttft_slo_us`` is set, every observation checks the
configured percentile against it.  Crossing from ok to breached appends a
breach record, emits an ``slo`` ctrl-plane instant when the fabric has a
tracer, and (first breach only) dumps the flight recorder when one is
attached.  Like the rest of ``repro.obs``, the tracker never schedules
events and never draws RNG — attaching it leaves runs bit-identical.
"""

from __future__ import annotations

from collections import deque
from typing import List, Optional

from ..obs.metrics import rank_percentile


class SloTracker:
    """Sliding-window TTFT/queue-depth percentiles + breach detection.

    ``window`` bounds both sample deques; ``ttft_slo_us`` (optional)
    arms breach detection on ``percentile`` (default p95) once at least
    ``min_samples`` TTFTs are in the window.  Attach to a scheduler by
    passing ``slo=...`` to its constructor; the autoscaler picks it up
    through ``scheduler.slo``.
    """

    def __init__(self, fabric=None, *, window: int = 256,
                 ttft_slo_us: Optional[float] = None,
                 percentile: float = 95.0, min_samples: int = 16):
        self.fabric = fabric
        self.window = int(window)
        self.ttft_slo_us = ttft_slo_us
        self.pct = float(percentile)
        self.min_samples = int(min_samples)
        self.ttfts: deque = deque(maxlen=self.window)
        self.depths: deque = deque(maxlen=self.window)
        self.n_ttft = 0                  # total ever observed
        self.breaches: List[dict] = []
        self.in_breach = False

    # -- observation --------------------------------------------------------
    def observe_ttft(self, ttft_us: float) -> None:
        """Record one completed request's TTFT; runs breach detection."""
        self.ttfts.append(float(ttft_us))
        self.n_ttft += 1
        if self.ttft_slo_us is None or len(self.ttfts) < self.min_samples:
            return
        p = self.ttft_percentile(self.pct)
        if p > self.ttft_slo_us:
            if not self.in_breach:
                self.in_breach = True
                self._breach(p)
        else:
            self.in_breach = False

    def observe_queue_depth(self, depth: int) -> None:
        """Record one scheduler queue-depth sample."""
        self.depths.append(int(depth))

    def _breach(self, p: float) -> None:
        now = self.fabric.now if self.fabric is not None else 0.0
        rec = {"t": now, f"p{self.pct:g}_us": p,
               "slo_us": self.ttft_slo_us, "n": self.n_ttft}
        self.breaches.append(rec)
        if self.fabric is None:
            return
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("slo", f"ttft_p{self.pct:g}_breach",
                       {"value_us": p, "slo_us": self.ttft_slo_us})
        recorder = getattr(self.fabric, "recorder", None)
        if recorder is not None:
            if tr is None:
                recorder.note("slo", f"ttft_p{self.pct:g}_breach",
                              {"value_us": p, "slo_us": self.ttft_slo_us})
            if len(self.breaches) == 1:
                recorder.dump("slo-breach")

    # -- readout ------------------------------------------------------------
    def ttft_percentile(self, p: float) -> float:
        """TTFT percentile over the current window (0.0 when empty)."""
        return rank_percentile(sorted(self.ttfts), p)

    def queue_percentile(self, p: float) -> float:
        """Queue-depth percentile over the current window (0.0 when empty)."""
        return rank_percentile(sorted(self.depths), p)

    def summary(self) -> dict:
        """Flat scalar summary (bench JSON rows)."""
        return {
            "ttft_n": self.n_ttft,
            "ttft_p50_us": self.ttft_percentile(50),
            "ttft_p95_us": self.ttft_percentile(95),
            "ttft_p99_us": self.ttft_percentile(99),
            "queue_p50": self.queue_percentile(50),
            "queue_p95": self.queue_percentile(95),
            "queue_p99": self.queue_percentile(99),
            "breaches": len(self.breaches),
        }
