"""Deterministic token data pipeline.

Sources:
  * ``SyntheticCorpus`` — seeded Zipfian token stream with local structure
    (Markov bigram mixing) so models actually learn something in examples.
  * ``FileCorpus``     — memory-maps a binary token file (uint16/uint32).

``Batcher`` yields (tokens, targets) next-token batches, sharded by
(data-parallel rank, num_ranks) with a deterministic per-step layout —
every rank computes its slice independently, no coordination (the same
property the paper's P2P design exploits: no synchronized initialization).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class SyntheticCorpus:
    """Infinite pseudo-corpus: Zipf unigrams blended with a bigram chain."""

    def __init__(self, vocab: int, seed: int = 0, alpha: float = 1.2):
        self.vocab = vocab
        self.seed = seed
        self.alpha = alpha
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._p = ranks ** -alpha
        self._p /= self._p.sum()
        # sparse bigram successor table: each token has 4 preferred successors
        self._succ = rng.integers(0, vocab, size=(vocab, 4), dtype=np.int64)

    def block(self, index: int, length: int) -> np.ndarray:
        """Deterministic block of ``length`` tokens for block ``index``."""
        rng = np.random.default_rng((self.seed, index))
        base = rng.choice(self.vocab, size=length + 1, p=self._p)
        mix = rng.random(length + 1) < 0.5
        out = base.copy()
        for i in range(1, length + 1):
            if mix[i]:
                out[i] = self._succ[out[i - 1], rng.integers(0, 4)]
        return out.astype(np.int32)


class FileCorpus:
    """Binary token file (np.uint16 or np.uint32 flat array)."""

    def __init__(self, path: str, dtype=np.uint16):
        self._data = np.memmap(path, dtype=dtype, mode="r")

    def block(self, index: int, length: int) -> np.ndarray:
        n = self._data.size
        start = (index * length) % max(1, n - length - 1)
        return np.asarray(self._data[start:start + length + 1], np.int32)


@dataclasses.dataclass
class Batcher:
    corpus: object
    global_batch: int
    seq_len: int
    rank: int = 0
    num_ranks: int = 1

    def __post_init__(self):
        if self.global_batch % self.num_ranks:
            raise ValueError("global batch must divide across ranks")
        self.local_batch = self.global_batch // self.num_ranks

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = np.empty((self.local_batch, self.seq_len), np.int32)
        tgts = np.empty_like(toks)
        for i in range(self.local_batch):
            seq_index = step * self.global_batch + self.rank * self.local_batch + i
            blk = self.corpus.block(seq_index, self.seq_len)
            toks[i] = blk[:-1]
            tgts[i] = blk[1:]
        return {"tokens": toks, "targets": tgts}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
