from .pipeline import Batcher, FileCorpus, SyntheticCorpus

__all__ = ["Batcher", "SyntheticCorpus", "FileCorpus"]
