"""UvmWatcher: host-initiated transfers driven by GPU progress (paper §3.3).

The paper allocates a unified-memory word that device kernels increment
(CUDA-graph compatible); a dedicated CPU thread polls it via GDRCopy and
invokes a callback with (old, new) — changes may be coalesced, so the
callback must handle skipped intermediate values.

In the simulator the "GPU" is the serving engine advancing through layers in
virtual time; ``store()`` models the device-side ``scalar_inc_`` and the
poller delivers the callback after a PCIe polling delay.  Coalescing is
faithfully modeled: if several stores land before the poller wakes, the
callback observes a single (old, new) jump.
"""

from __future__ import annotations

from typing import Callable, Optional

from .netsim import EventLoop, PCIE_POLL_US


class UvmWatcher:
    """Polls a device-incremented word and reports (old, new) jumps (§3.3)."""

    def __init__(self, loop: EventLoop, cb: Callable[[int, int], None],
                 poll_us: float = PCIE_POLL_US):
        self.loop = loop
        self.cb = cb
        self.poll_us = poll_us
        self.value = 0            # device-visible word
        self._observed = 0        # last value seen by the poller
        self._poll_scheduled = False

    def store(self, value: int) -> None:
        """Device-side write (e.g. after a layer's attention output proj)."""
        self.value = value
        self._schedule_poll()

    def inc(self) -> None:
        """Device-side ``scalar_inc_``: bump the watched word by one."""
        self.store(self.value + 1)

    def _schedule_poll(self) -> None:
        if self._poll_scheduled:
            return
        self._poll_scheduled = True

        def poll() -> None:
            self._poll_scheduled = False
            old, new = self._observed, self.value
            if new != old:
                self._observed = new
                self.cb(old, new)
            if self.value != self._observed:  # raced with another store
                self._schedule_poll()

        self.loop.schedule(self.poll_us, poll)
