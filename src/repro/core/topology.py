"""Topology model: per-pair transport selection for heterogeneous fabrics.

Through PR 5 the fabric enforced ONE NIC kind per :class:`~repro.core.Fabric`
(``add_engine`` raised on a mismatch) and the NVLink fast path only triggered
between devices of a single engine — so every same-node byte between two
*engines* rode the simulated NIC, and a CX7 cluster could never talk to an
EFA cluster at all.  This module converts that global invariant into a
**per-pair decision**:

* every registered endpoint (a ``DomainGroup`` address) carries a
  :class:`TopoEntry` — its physical **host** identity, its NIC preset, and
  whether NVLink reaches its host-local peers;
* each ``(src, dst)`` address pair resolves — once, lazily, at first channel
  use — to a :class:`ChannelPlan` naming the transport preset that pair
  rides: NVLink for same-host pairs, the sender's NIC for same-kind pairs,
  or a derived cross-fabric preset (:func:`cross_spec`) for mixed-NIC pairs
  (paper §6 moves intra-node MoE payloads over NVLink; Holmes,
  arXiv 2312.03549, trains across CX7 and EFA clusters in one job).

Resolution rules, in order (documented with worked numbers in
``docs/TOPOLOGY.md``):

1. **Unknown endpoints** (directly constructed ``DomainGroup``s outside a
   fabric): legacy node-string rule — same ``NetAddr.node`` and different
   device means NVLink, anything else rides the sender's NIC.  This keeps
   standalone unit fixtures byte-identical.
2. **Same host, different address, both NVLink-capable** → the ``NVLINK``
   preset on a dedicated per-pair queue (ordered, no SRD jitter, the NIC
   stays free for cross-node traffic).
3. **Same NIC spec on both ends** → the sender's NIC queue, exactly the
   pre-PR path (seeds, jitter streams and event order are bit-identical —
   pinned by ``tests/test_topology.py`` goldens).
4. **Different NIC specs** → :func:`cross_spec` derives a per-pair cost
   model (bottleneck bandwidth, summed wire latency, the weaker ordering
   contract) served by a dedicated per-pair queue.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from .netsim import NVLINK, NicSpec


@dataclass(frozen=True)
class TopoEntry:
    """Static topology facts about one registered endpoint address.

    ``host`` is the physical machine identity — distinct engines (one per
    rank is the common pattern) that share a host reach each other over
    NVLink when both sides set ``nvlink``.  ``nic`` is the engine's NIC
    preset name; ``spec`` its per-NIC :class:`~repro.core.netsim.NicSpec`.
    """

    host: str
    nic: str
    spec: NicSpec
    nvlink: bool = True


@dataclass(frozen=True)
class ChannelPlan:
    """The resolved transport for one ``(src, dst)`` address pair.

    ``kind`` is ``"nvlink"`` | ``"nic"`` | ``"cross"``.  ``spec`` governs
    the channel's wire behaviour (bandwidth, MTU chunking, ordering,
    jitter).  ``dedicated`` says the pair gets its own queue instead of
    sharing the sender NIC's serialised pipeline — true for the off-NIC
    transports (NVLink, cross-fabric), false for the plain NIC path.
    """

    kind: str
    spec: NicSpec
    dedicated: bool


_CROSS_CACHE: Dict[Tuple[str, str], NicSpec] = {}


def cross_spec(a: NicSpec, b: NicSpec) -> NicSpec:
    """Derive the per-pair cost model for a mixed-NIC (cross-fabric) pair.

    A CX7 endpoint talking to an EFA endpoint crosses two fabrics joined at
    a gateway (the Holmes inter-zone shape), so the pair behaves like the
    *weaker composition* of both NICs — symmetric in its arguments:

    * ``bw_gbps`` / ``eff``: the bottleneck link (min of both sides);
    * ``base_latency_us``: both wire hops are paid (sum);
    * ``rtt_us``: the completion ack crosses both fabrics too (sum);
    * ``fixed_us``: the slower per-op engine dominates (max);
    * ``mtu_bytes``: the path MTU is the smaller of the two (min);
    * ``ordered``: only if BOTH sides guarantee ordering — one SRD hop
      makes the whole pair unordered (events cannot collapse);
    * ``srd_jitter_us``: the jitteriest hop dominates (max).

    Results are cached per unordered name pair, so every channel of one
    pair kind shares a single spec instance.
    """
    key = (a.name, b.name) if a.name <= b.name else (b.name, a.name)
    spec = _CROSS_CACHE.get(key)
    if spec is None:
        spec = NicSpec(
            name=f"x:{key[0]}+{key[1]}",
            bw_gbps=min(a.bw_gbps, b.bw_gbps),
            base_latency_us=a.base_latency_us + b.base_latency_us,
            rtt_us=a.rtt_us + b.rtt_us,
            fixed_us=max(a.fixed_us, b.fixed_us),
            eff=min(a.eff, b.eff),
            mtu_bytes=min(a.mtu_bytes, b.mtu_bytes),
            ordered=a.ordered and b.ordered,
            srd_jitter_us=max(a.srd_jitter_us, b.srd_jitter_us),
        )
        _CROSS_CACHE[key] = spec
    return spec


class Topology:
    """Address book + pair resolver for one fabric.

    The :class:`~repro.core.Fabric` registers a :class:`TopoEntry` per
    ``DomainGroup`` address at engine construction; every ``Domain``
    consults :meth:`plan` when it first opens a channel to a peer.  Plans
    are cached per ``(src, dst)`` pair — the pair-keyed channel table the
    per-pair refactor is named for.
    """

    def __init__(self) -> None:
        self._entries: Dict[object, TopoEntry] = {}
        self._plans: Dict[Tuple[object, object], ChannelPlan] = {}

    def register(self, addr, entry: TopoEntry) -> None:
        """Record topology facts for ``addr`` (one entry per address)."""
        self._entries[addr] = entry

    def entry(self, addr) -> Optional[TopoEntry]:
        """The :class:`TopoEntry` for ``addr``, or None if unregistered."""
        return self._entries.get(addr)

    def plan(self, src, src_spec: NicSpec, dst) -> ChannelPlan:
        """Resolve the transport preset for the ``(src, dst)`` pair.

        ``src_spec`` is the posting Domain's own NIC spec (used verbatim on
        the same-kind path so the pre-PR behaviour is bit-identical).  See
        the module docstring for the rule order.
        """
        key = (src, dst)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._resolve(src, src_spec, dst)
            self._plans[key] = plan
        return plan

    def _resolve(self, src, src_spec: NicSpec, dst) -> ChannelPlan:
        se = self._entries.get(src)
        de = self._entries.get(dst)
        if se is None or de is None:
            # Legacy node-string rule for endpoints outside any fabric
            # topology (standalone DomainGroups in unit fixtures).
            if dst.node == src.node and dst.dev != src.dev:
                return ChannelPlan("nvlink", NVLINK, dedicated=True)
            return ChannelPlan("nic", src_spec, dedicated=False)
        if src != dst and se.host == de.host and se.nvlink and de.nvlink:
            return ChannelPlan("nvlink", NVLINK, dedicated=True)
        if de.spec.name == src_spec.name:
            return ChannelPlan("nic", src_spec, dedicated=False)
        return ChannelPlan("cross", cross_spec(src_spec, de.spec),
                           dedicated=True)
