"""Deterministic transport fault injection + per-WR retry/timeout budgets.

Real SRD/RC fabrics lose packets, flush QPs on error, and drop whole peers
mid-transfer; until this module the simulator only modeled *slowdowns*
(``Fabric.degrade_pair``), so a lost WRITE would wedge an update forever.
:class:`FaultPlan` closes that gap with three properties:

* **Deterministic** — every verdict draws from the plan's own
  ``stable_hash``-derived RNG streams (one per (src, dst) node pair), never
  from the channels' jitter RNGs, so a seeded fault schedule replays
  bit-identically across processes and ``PYTHONHASHSEED`` values.
* **Zero-overhead when absent** — with no plan attached the hot path costs
  one ``is None`` check; no events are scheduled, no RNG is drawn, and all
  existing golden latencies stay byte-identical.
* **Exactly-once completion** — a replayed WriteImm is idempotent on
  payload (same bytes, same remote offset) but its completion callbacks are
  deduplicated per work request, so :class:`~repro.core.imm_counter.\
ImmCounter` increments exactly once per logical WRITE no matter how many
  replays raced a spurious timeout.

Fault model (per (src, dst) *node* pair).  WRITE knobs:

* ``drop_prob`` — the WR vanishes on the wire; detected by the delivery
  timeout, then retried with exponential backoff.
* ``error_prob`` — the NIC completes the WR in error after ~RTT (QP flush);
  retried with backoff without waiting for the timeout.
* ``burst(n)`` — the next ``n`` WRs on the pair all drop (loss burst).
* ``kill_peer(node)`` — NIC-down: all outstanding tracked WRs touching the
  node fail at once (channel-level error state) and every later WR or SEND
  to/from it fails immediately, skipping the retry budget.

SENDs are never retried *by the transport* — replaying a SEND is not
idempotent at this layer, so recovery lives one level up in ``repro.ctrl``
(``(sender, seq)`` stamping + receiver dedup windows + bounded ack-tracked
retransmission; see ``ctrl.retry``).  What the plan injects on SENDs is
the loss itself, via :meth:`FaultPlan.inject_ctrl`:

* ``drop_prob`` — the SEND vanishes (accounting stays clean, delivery
  never comes);
* ``dup_prob`` — it is delivered twice (the duplicate after ``delay_us``),
  probing receiver idempotency;
* ``delay_prob`` — delivery is delayed by ``delay_us`` (reordering probe).

Ctrl verdicts draw from their own ``stable_hash`` streams (one per pair,
distinct from the WRITE streams) and keep counters in ``ctrl_stats`` —
WRITE-side ``stats`` and golden traces stay byte-identical when no ctrl
knob is active.

On retry exhaustion the WR takes its terminal ``on_error`` path (see
``WriteState.on_error`` / ``BatchState.note_error`` in ``core.engine``);
with no handler installed a :class:`TransferError` propagates out of
``Fabric.run()`` — loud, never a silent hang.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .netsim import stable_hash


class TransferError(RuntimeError):
    """A data-plane transfer failed terminally (retry budget exhausted or
    peer dead) and no ``on_error`` handler was installed to absorb it."""


class BackpressureError(TransferError):
    """The receiver-not-ready requeue path hit its depth cap.

    Raised (or passed to ``TransferEngine.on_backpressure``) when a SEND
    arrives at an engine whose pending-send queue for the target device is
    already ``max_pending_sends`` deep — the simulated analog of RNR-retry
    exhaustion.  Carries the receiver ``node``, ``device`` and queue
    ``depth`` for structured handling.
    """

    def __init__(self, node: str, device: int, depth: int):
        super().__init__(
            f"pending-send queue for {node}/gpu{device} full at depth "
            f"{depth}: receiver posts no RECVs (RNR backpressure)")
        self.node = node
        self.device = device
        self.depth = depth


class _OpTrack:
    """Retry bookkeeping for one in-flight work request (one wire op)."""

    __slots__ = ("op", "group", "dst_group", "nic_index", "src", "dst",
                 "attempts", "timer", "done", "sent")

    def __init__(self, op, group, dst_group, nic_index, src, dst):
        self.op = op
        self.group = group
        self.dst_group = dst_group
        self.nic_index = nic_index
        self.src = src
        self.dst = dst
        self.attempts = 0          # retries consumed (0 = first attempt)
        self.timer: Optional[int] = None
        self.done = False          # delivered or terminally failed
        self.sent = False          # sender-side CQE already surfaced


class FaultPlan:
    """Seeded per-pair fault schedule + per-WR retry/timeout policy.

    Constructing the plan attaches it to ``fabric`` (every current and
    future :class:`~repro.core.domain.DomainGroup` gets a ``faults`` ref)
    and registers it as an auditable, so ``Fabric.audit()`` reports any WR
    left tracked-but-unresolved at loop idle.

    Policy knobs: a WR that misses ``timeout_us`` (or completes in error)
    is reposted after ``backoff_us * backoff_factor**k`` for retry ``k``,
    up to ``max_retries`` replays; exhaustion takes the WR's ``on_error``
    terminal path.  All knobs are plain floats — no RNG is involved in the
    retry schedule itself, only in the per-pair fault verdicts.
    """

    def __init__(self, fabric, *, seed: int = 0, timeout_us: float = 5000.0,
                 max_retries: int = 4, backoff_us: float = 50.0,
                 backoff_factor: float = 2.0):
        self.fabric = fabric
        self.loop = fabric.loop
        self.seed = stable_hash(fabric.seed, "faults", seed)
        self.timeout_us = float(timeout_us)
        self.max_retries = int(max_retries)
        self.backoff_us = float(backoff_us)
        self.backoff_factor = float(backoff_factor)
        self._pair_cfg: Dict[Tuple[str, str], dict] = {}
        self._rngs: Dict[Tuple[str, str], np.random.Generator] = {}
        # ctrl-SEND injection draws from its own streams so enabling it
        # never perturbs the WRITE verdict sequence (and vice versa)
        self._crngs: Dict[Tuple[str, str], np.random.Generator] = {}
        self.dead: set = set()
        self._tracked: Dict[int, _OpTrack] = {}
        self.stats: Dict[str, int] = {
            "drops": 0, "errors": 0, "retries": 0, "recovered": 0,
            "exhausted": 0, "killed": 0, "blackholed_sends": 0}
        # separate dict: WRITE-side stats stay exactly the seed's shape
        self.ctrl_stats: Dict[str, int] = {"drops": 0, "dups": 0, "delays": 0}
        fabric.attach_faults(self)
        fabric.register_auditable("faults", self)

    # -- configuration ------------------------------------------------------

    @staticmethod
    def _node(x) -> str:
        """Coerce a node name / NetAddr / engine-ish object to a node str."""
        return getattr(x, "node", x if isinstance(x, str) else str(x))

    def inject(self, src, dst, *, drop_prob: float = 0.0,
               error_prob: float = 0.0) -> None:
        """Set probabilistic loss on the (src, dst) node pair (WRITEs only).

        ``drop_prob``: the WR silently vanishes (timeout-detected);
        ``error_prob``: the NIC flushes it with a completion error after
        ~RTT.  One uniform draw per WR decides: ``u < drop`` => drop,
        ``u < drop + error`` => error.  Replaces any previous setting for
        the pair; probabilities of 0 restore the clean fast path (a pair
        with no active knobs draws no RNG).
        """
        if not (0.0 <= drop_prob <= 1.0 and 0.0 <= error_prob <= 1.0
                and drop_prob + error_prob <= 1.0):
            raise ValueError(
                f"invalid probabilities drop={drop_prob} error={error_prob}")
        key = (self._node(src), self._node(dst))
        cfg = self._pair_cfg.setdefault(key, {})
        cfg["drop"] = float(drop_prob)
        cfg["error"] = float(error_prob)

    def inject_ctrl(self, src, dst, *, drop_prob: float = 0.0,
                    dup_prob: float = 0.0, delay_prob: float = 0.0,
                    delay_us: float = 200.0) -> None:
        """Set probabilistic loss/duplication/delay on ctrl SENDs for the
        (src, dst) node pair.

        One uniform draw per SEND decides: ``u < drop`` => the SEND
        vanishes; ``u < drop + dup`` => delivered twice (duplicate lands
        ``delay_us`` later); ``u < drop + dup + delay`` => delivery delayed
        by ``delay_us``.  Replaces any previous ctrl setting for the pair;
        all-zero knobs restore the clean fast path (no RNG drawn).  SENDs
        are not retried here — recovery is the ctrl layer's seq/dedup +
        retransmission machinery, which these knobs exist to exercise.
        """
        if not (0.0 <= drop_prob <= 1.0 and 0.0 <= dup_prob <= 1.0
                and 0.0 <= delay_prob <= 1.0
                and drop_prob + dup_prob + delay_prob <= 1.0):
            raise ValueError(f"invalid ctrl probabilities drop={drop_prob} "
                             f"dup={dup_prob} delay={delay_prob}")
        key = (self._node(src), self._node(dst))
        cfg = self._pair_cfg.setdefault(key, {})
        cfg["c_drop"] = float(drop_prob)
        cfg["c_dup"] = float(dup_prob)
        cfg["c_delay"] = float(delay_prob)
        cfg["c_delay_us"] = float(delay_us)

    def burst(self, src, dst, n: int) -> None:
        """Drop the next ``n`` WRITEs on the pair unconditionally (adds to
        any burst already pending) — a deterministic loss burst."""
        if n < 0:
            raise ValueError(f"negative burst {n}")
        key = (self._node(src), self._node(dst))
        cfg = self._pair_cfg.setdefault(key, {})
        cfg["burst"] = cfg.get("burst", 0) + int(n)

    def kill_peer(self, node) -> None:
        """NIC-down for ``node``: every outstanding tracked WR to/from it
        fails now (one event each, skipping the retry budget — the
        channel-level error state of a flushed QP), and all later WRs and
        SENDs touching the node fail/blackhole immediately."""
        name = self._node(node)
        self.dead.add(name)
        for tr in list(self._tracked.values()):
            if tr.done or (tr.src != name and tr.dst != name):
                continue
            self.stats["killed"] += 1
            self.loop.schedule(0.0, lambda tr=tr: self._exhaust(
                tr, f"peer {name} died with WR outstanding"))

    def clear(self, src=None, dst=None) -> None:
        """Remove fault knobs: for one pair when given, else every pair and
        every dead peer (retry policy and RNG streams are kept)."""
        if src is None and dst is None:
            self._pair_cfg.clear()
            self.dead.clear()
            return
        self._pair_cfg.pop((self._node(src), self._node(dst)), None)

    # -- hot path (called from DomainGroup.post_write) ----------------------

    def on_post(self, group, dst_group, op, ch, delay: float,
                nic_index: int) -> None:
        """Decide one WR post's fate: deliver, drop, error, or fail-fast.

        Called by ``DomainGroup.post_write`` in place of the direct channel
        post whenever a plan is attached; also re-entered by retries (the
        tracked op re-runs the verdict, so a retry can be lost again).
        """
        src = group.addr.node
        dst = dst_group.addr.node
        if op.kind != "write":
            # SENDs: never retried by the transport (replay is not
            # idempotent here — the ctrl layer's seq/dedup machinery owns
            # recovery). Dead peers blackhole them: accounting stays clean,
            # delivery never comes, lease expiry provides failure detection.
            if src in self.dead or dst in self.dead:
                self.stats["blackholed_sends"] += 1
                self._note("send_blackholed", src, dst, op)
                self.fabric.inflight_sends -= 1
                return
            verdict = self._ctrl_verdict(src, dst)
            if verdict == "drop":
                self.ctrl_stats["drops"] += 1
                self._note("ctrl_drop", src, dst, op)
                self.fabric.inflight_sends -= 1
                return
            if verdict == "dup":
                self.ctrl_stats["dups"] += 1
                self._note("ctrl_dup", src, dst, op)
                # second delivery: same op, fresh closures per post on the
                # unordered channel — receiver idempotency is the probe
                self.fabric.inflight_sends += 1
                cfg = self._pair_cfg[(src, dst)]
                self.loop.schedule(delay, lambda: ch.post(op))
                self.loop.schedule(delay + cfg["c_delay_us"],
                                   lambda: ch.post(op))
                return
            if verdict == "delay":
                self.ctrl_stats["delays"] += 1
                self._note("ctrl_delay", src, dst, op)
                cfg = self._pair_cfg[(src, dst)]
                self.loop.schedule(delay + cfg["c_delay_us"],
                                   lambda: ch.post(op))
                return
            self.loop.schedule(delay, lambda: ch.post(op))
            return
        track = self._tracked.get(id(op))
        if track is None:
            track = _OpTrack(op, group, dst_group, nic_index, src, dst)
            self._wrap(track)
            self._tracked[id(op)] = track
        if src in self.dead or dst in self.dead:
            self.stats["killed"] += 1
            self.loop.schedule(delay, lambda: self._exhaust(
                track, f"peer dead ({src}->{dst})"))
            return
        verdict = self._verdict(src, dst)
        if verdict == "drop":
            self.stats["drops"] += 1
            self._note("drop", src, dst, op)
            track.timer = self.loop.schedule_cancelable(
                delay + self.timeout_us, lambda: self._timeout(track))
            return
        if verdict == "error":
            self.stats["errors"] += 1
            self._note("error", src, dst, op)
            self.loop.schedule(delay + ch.spec.rtt_us,
                               lambda: self._on_attempt_failed(
                                   track, "completion-with-error"))
            return
        self.loop.schedule(delay, lambda: ch.post(op))
        track.timer = self.loop.schedule_cancelable(
            delay + self.timeout_us, lambda: self._timeout(track))

    def _ctrl_verdict(self, src: str, dst: str) -> str:
        """One fault verdict for a ctrl SEND: ok / drop / dup / delay.

        Draws from the pair's dedicated "ctrl" RNG stream, and only when a
        ctrl knob is active — pairs without ctrl injection stay on the
        zero-RNG fast path (byte-identical to an un-injected plan)."""
        cfg = self._pair_cfg.get((src, dst))
        if cfg is None:
            return "ok"
        dp = cfg.get("c_drop", 0.0)
        up = cfg.get("c_dup", 0.0)
        lp = cfg.get("c_delay", 0.0)
        if dp <= 0.0 and up <= 0.0 and lp <= 0.0:
            return "ok"
        key = (src, dst)
        rng = self._crngs.get(key)
        if rng is None:
            rng = np.random.default_rng(
                stable_hash(self.seed, "ctrl", src, dst))
            self._crngs[key] = rng
        u = float(rng.random())
        if u < dp:
            return "drop"
        if u < dp + up:
            return "dup"
        if u < dp + up + lp:
            return "delay"
        return "ok"

    def _verdict(self, src: str, dst: str) -> str:
        """One fault verdict for a WRITE on the pair: ok / drop / error."""
        cfg = self._pair_cfg.get((src, dst))
        if cfg is None:
            return "ok"
        if cfg.get("burst", 0) > 0:
            cfg["burst"] -= 1
            return "drop"
        dp = cfg.get("drop", 0.0)
        ep = cfg.get("error", 0.0)
        if dp <= 0.0 and ep <= 0.0:
            return "ok"
        key = (src, dst)
        rng = self._rngs.get(key)
        if rng is None:
            rng = np.random.default_rng(
                stable_hash(self.seed, "pair", src, dst))
            self._rngs[key] = rng
        u = float(rng.random())
        if u < dp:
            return "drop"
        if u < dp + ep:
            return "error"
        return "ok"

    # -- retry machinery ----------------------------------------------------

    def _wrap(self, track: _OpTrack) -> None:
        """Intercept the op's completion callbacks: first completion wins,
        duplicates from raced replays are suppressed (the exactly-once
        ImmCounter contract — payload replays are idempotent, callbacks are
        not)."""
        op = track.op
        orig_delivered = op.on_delivered

        def delivered(o, now: float) -> None:
            if track.done:
                return
            track.done = True
            if track.attempts:
                self.stats["recovered"] += 1
            self._cancel_timer(track)
            self._tracked.pop(id(op), None)
            orig_delivered(o, now)

        op.on_delivered = delivered
        if op.on_fenced is not None:
            orig_fenced = op.on_fenced

            def fenced(o, now: float) -> None:
                # epoch fence rejection is terminal: fences only tighten,
                # so retrying the WR could never succeed — resolve the
                # track (no retry timer, no exhaustion) and let the fence
                # path's own on_error handle escalation
                if track.done:
                    return
                track.done = True
                self._cancel_timer(track)
                self._tracked.pop(id(op), None)
                orig_fenced(o, now)

            op.on_fenced = fenced
        if op.on_sent is not None:
            orig_sent = op.on_sent

            def sent(now: float) -> None:
                if track.sent:
                    return
                track.sent = True
                orig_sent(now)

            op.on_sent = sent

    def _timeout(self, track: _OpTrack) -> None:
        """Delivery timeout fired: the attempt is presumed lost (it may in
        fact still be in flight — the dedup in :meth:`_wrap` makes the
        resulting replay harmless)."""
        track.timer = None
        self._on_attempt_failed(track, "delivery-timeout")

    def _on_attempt_failed(self, track: _OpTrack, why: str) -> None:
        """Retry with exponential backoff, or exhaust the budget."""
        if track.done:
            return
        if track.attempts >= self.max_retries:
            self._exhaust(track, why)
            return
        track.attempts += 1
        self.stats["retries"] += 1
        self._note("retry", track.src, track.dst, track.op,
                   attempt=track.attempts, why=why)
        back = self.backoff_us * (self.backoff_factor ** (track.attempts - 1))
        self.loop.schedule(back, lambda: self._repost(track))

    def _repost(self, track: _OpTrack) -> None:
        """Replay the WR through the normal posting path (same NIC index,
        fresh posting cost, fresh fault verdict)."""
        if track.done:
            return
        track.group.post_write(track.dst_group, track.op,
                               nic_index=track.nic_index)

    def _exhaust(self, track: _OpTrack, why: str) -> None:
        """Terminal failure: budget exhausted or peer dead.  Takes the op's
        ``on_error`` path (raising :class:`TransferError` if none) and dumps
        the flight recorder when one is attached."""
        if track.done:
            return
        track.done = True
        self._cancel_timer(track)
        self._tracked.pop(id(track.op), None)
        self.stats["exhausted"] += 1
        reason = (f"WR {track.src}->{track.dst} failed after "
                  f"{track.attempts} retr{'y' if track.attempts == 1 else 'ies'}: {why}")
        self._note("exhausted", track.src, track.dst, track.op, why=why)
        rec = getattr(self.fabric, "recorder", None)
        if rec is not None:
            rec.dump("retry-exhausted")
        op = track.op
        if op.on_error is not None:
            op.on_error(op, reason)
        else:
            raise TransferError(reason)

    def _cancel_timer(self, track: _OpTrack) -> None:
        """Disarm the track's pending timeout, if any."""
        if track.timer is not None:
            self.loop.cancel(track.timer)
            track.timer = None

    def _note(self, kind: str, src: str, dst: str, op, **info) -> None:
        """Feed the observability loop: HealthMonitor counter + tracer
        instant (mirrored into the flight-recorder ring when only the
        recorder is attached).  Pure bookkeeping — no events, no RNG."""
        mon = self.fabric.health
        if mon is not None:
            mon.on_fault(kind)
        args = {"src": src, "dst": dst, "nbytes": op.nbytes}
        args.update(info)
        tr = self.fabric.tracer
        if tr is not None:
            tr.instant("fault", f"{kind}:{src}>{dst}", args)
        else:
            rec = getattr(self.fabric, "recorder", None)
            if rec is not None:
                rec.note("fault", f"{kind}:{src}>{dst}", args)

    # -- audit --------------------------------------------------------------

    def outstanding(self) -> List[Tuple[str, str, str, int]]:
        """Unresolved tracked WRs as (src, dst, kind, attempts) tuples."""
        return [(t.src, t.dst, t.op.kind, t.attempts)
                for t in self._tracked.values() if not t.done]

    def audit_leaks(self) -> Dict[str, int]:
        """Auditable hook: tracked-but-unresolved WRs at loop idle (empty
        dict = clean — every WR either delivered or took its error path)."""
        out = self.outstanding()
        return {"tracked_wrs": len(out)} if out else {}
