"""Domains and DomainGroups: per-NIC workers and multi-NIC aggregation.

Mirrors the paper's architecture (Fig. 1): a *TransferEngine* spawns one
worker per GPU managing a ``DomainGroup``; each ``Domain`` inside the group
is specialised to a single NIC (queue-pair management, work submission,
completion polling).  Transfers submitted to the group are sharded and
rotated across the available NICs — essential on EFA where 2-4 NICs must be
aggregated to reach 400 Gbps.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .netsim import EventLoop, NicQueue, NicSpec, POST_US, stable_hash
from .transport import Channel, WireOp


@dataclass(frozen=True)
class NetAddr:
    """Serializable network address of a DomainGroup (paper: ``NetAddr``)."""

    node: str
    dev: int

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.node}/gpu{self.dev}"


@dataclass(frozen=True)
class Pages:
    """Indirect page addressing: ``addr = base + indices[i]*stride + offset``."""

    indices: Tuple[int, ...]
    stride: int
    offset: int = 0

    def resolve(self, page_len: int) -> List[int]:
        return [int(i) * self.stride + self.offset for i in self.indices]


class MemoryRegion:
    """A registered memory region backed by a numpy byte buffer."""

    _ids = itertools.count()

    def __init__(self, buf: np.ndarray, device: int):
        if buf.dtype != np.uint8 or buf.ndim != 1:
            raise ValueError("MemoryRegion requires a flat uint8 view")
        self.buf = buf
        self.device = device
        self.region_id = next(MemoryRegion._ids)

    def __len__(self) -> int:
        return self.buf.size

    def write_bytes(self, offset: int, data) -> None:
        """Land ``data`` (any buffer-protocol object) at ``offset``."""
        n = len(data)
        if offset < 0 or offset + n > self.buf.size:
            raise IndexError(
                f"remote write out of bounds: [{offset}, {offset+n}) "
                f"into region of {self.buf.size} bytes")
        self.buf[offset:offset + n] = np.frombuffer(data, np.uint8)

    def read_bytes(self, offset: int, nbytes: int) -> bytes:
        if offset < 0 or offset + nbytes > self.buf.size:
            raise IndexError("local read out of bounds")
        return self.buf[offset:offset + nbytes].tobytes()

    def snapshot(self, offset: int, nbytes: int) -> memoryview:
        """One-copy payload snapshot (the WRITE's "don't touch src until
        completion" contract).  All downstream NIC striping and MTU
        chunking slices this view zero-copy; the snapshot never aliases
        the live region buffer."""
        return memoryview(self.read_bytes(offset, nbytes))


@dataclass(frozen=True)
class MrHandle:
    """Local handle for a registered region (source of transfers)."""

    region_id: int
    owner: NetAddr


@dataclass(frozen=True)
class MrDesc:
    """Serializable descriptor exchanged with peers (paper: ptr + rkeys).

    ``rkeys`` carries one (nic_index, rkey) pair per NIC in the owning
    DomainGroup, like the paper's ``Vec<(NetAddr, u64)>``.
    """

    region_id: int
    owner: NetAddr
    nbytes: int
    rkeys: Tuple[Tuple[int, int], ...]


@dataclass(frozen=True)
class ScatterDst:
    len: int
    src: int                      # offset into the scatter source MR
    dst: Tuple[MrDesc, int]       # (remote descriptor, remote offset)


@dataclass(frozen=True)
class PayloadDst:
    """A scatter destination that carries its own payload bytes.

    The gather-into-snapshot fast path: the caller hands a freshly
    gathered, contiguous uint8 buffer that IS the submission snapshot —
    no staging copy into a registered region and no second snapshot copy.
    The caller must honour the WRITE contract (don't touch the buffer
    until completion); a fancy-indexing gather result trivially does.
    """

    payload: object               # contiguous 1-D uint8 buffer
    dst: Tuple[MrDesc, int]       # (remote descriptor, remote offset)


class WrBatch:
    """A template of N work requests posted in ONE event-loop entry.

    Mirrors the paper's WR templating (§3.4): the application pays one
    app->worker enqueue for the whole batch, while each WR still pays the
    per-WR posting cost on the DomainGroup's worker — so per-request
    submission overhead is amortised without changing the NIC-side timing
    of any individual WRITE.  WRs are stored as bare tuples: this is the
    hot path of every scatter/paged submission.
    """

    __slots__ = ("group", "wrs", "nbytes")

    def __init__(self, group: "DomainGroup"):
        self.group = group
        # (op, dst_group, nic_index, extra_post_us) per templated WR
        self.wrs: List[Tuple[WireOp, "DomainGroup", Optional[int], float]] = []
        self.nbytes = 0    # total payload bytes templated into this batch

    def add(self, op: WireOp, dst_group: "DomainGroup",
            nic_index: Optional[int] = None, extra_post_us: float = 0.0) -> None:
        self.wrs.append((op, dst_group, nic_index, extra_post_us))
        self.nbytes += op.nbytes

    def __len__(self) -> int:
        return len(self.wrs)

    def post(self) -> None:
        """Post every WR back-to-back on the owning group's worker."""
        post_write = self.group.post_write
        for op, dst_group, nic_index, extra_post_us in self.wrs:
            post_write(dst_group, op, nic_index=nic_index,
                       extra_post_us=extra_post_us)


class Domain:
    """One NIC: owns a NicQueue and per-peer channels (queue pairs).

    Same-node peers bypass the NIC through an NVLink-class channel (paper
    §6: intra-node payloads move over NVLink while RDMA transfers run in
    the background)."""

    def __init__(self, loop: EventLoop, spec: NicSpec, addr: NetAddr, index: int, seed: int):
        self.loop = loop
        self.spec = spec
        self.addr = addr
        self.index = index
        self.nic = NicQueue(loop, spec)
        self._channels: Dict[Tuple[NetAddr, int], Channel] = {}
        self._nvlink: Dict[NetAddr, Channel] = {}
        self._seed = seed

    def channel_to(self, peer: NetAddr, peer_index: int) -> Channel:
        if peer.node == self.addr.node and peer.dev != self.addr.dev:
            if peer not in self._nvlink:
                from .netsim import NVLINK
                seed = stable_hash(self._seed, self.addr, peer, "nvl")
                self._nvlink[peer] = Channel(
                    self.loop, NicQueue(self.loop, NVLINK), seed)
            return self._nvlink[peer]
        key = (peer, peer_index)
        if key not in self._channels:
            # Deterministic per-channel seed (process-stable).
            seed = stable_hash(self._seed, self.addr, self.index, peer, peer_index)
            self._channels[key] = Channel(self.loop, self.nic, seed)
        return self._channels[key]


class DomainGroup:
    """All NICs serving one GPU; shards transfers across them.

    The paper requires all peers to use the same number of NICs per GPU so
    any transfer has full knowledge of both sides' NICs; we enforce that at
    fabric construction.
    """

    def __init__(self, loop: EventLoop, addr: NetAddr, specs: Sequence[NicSpec], seed: int):
        self.loop = loop
        self.addr = addr
        self.domains = [Domain(loop, s, addr, i, seed + i) for i, s in enumerate(specs)]
        self._rr = 0
        self.post_us = POST_US.get(specs[0].name, 0.1)
        self._post_busy_until = 0.0
        self.regions: Dict[int, MemoryRegion] = {}
        self.posted_writes = 0

    # -- memory ---------------------------------------------------------
    def register(self, buf: np.ndarray, device: int) -> Tuple[MrHandle, MrDesc]:
        region = MemoryRegion(buf, device)
        self.regions[region.region_id] = region
        rkeys = tuple((d.index, stable_hash(region.region_id, d.index))
                      for d in self.domains)
        return (MrHandle(region.region_id, self.addr),
                MrDesc(region.region_id, self.addr, buf.size, rkeys))

    def region(self, region_id: int) -> MemoryRegion:
        return self.regions[region_id]

    # -- posting --------------------------------------------------------
    def _post_delay(self) -> float:
        """Serialise WR posting on the worker thread (Table 8/9 overhead)."""
        start = max(self.loop.now, self._post_busy_until)
        self._post_busy_until = start + self.post_us
        self.posted_writes += 1
        return self._post_busy_until - self.loop.now

    def next_domain(self) -> Domain:
        d = self.domains[self._rr % len(self.domains)]
        self._rr += 1
        return d

    def post_write(self, dst_group: "DomainGroup", op: WireOp,
                   nic_index: Optional[int] = None,
                   extra_post_us: float = 0.0) -> None:
        """Post a single WRITE, optionally pinned to a NIC by index.

        ``extra_post_us`` models additional per-WR descriptor setup beyond
        the batched-posting fast path (scatter/barrier; Table 9)."""
        d = self.domains[nic_index] if nic_index is not None else self.next_domain()
        if extra_post_us:
            self._post_busy_until = max(self.loop.now, self._post_busy_until) + extra_post_us
        delay = self._post_delay()
        ch = d.channel_to(dst_group.addr, d.index)
        self.loop.schedule(delay, lambda: ch.post(op))

    def split_across_nics(self, nbytes: int) -> List[Tuple[int, int, int]]:
        """Split a large WRITE into (nic_index, offset, length) stripes."""
        n = len(self.domains)
        if n == 1 or nbytes == 0:
            return [(0, 0, nbytes)]
        stripe = -(-nbytes // n)
        out = []
        for i in range(n):
            lo = i * stripe
            hi = min(nbytes, lo + stripe)
            if hi > lo:
                out.append((i, lo, hi - lo))
        return out
