"""Domains and DomainGroups: per-NIC workers and multi-NIC aggregation.

Mirrors the paper's architecture (Fig. 1): a *TransferEngine* spawns one
worker per GPU managing a ``DomainGroup``; each ``Domain`` inside the group
is specialised to a single NIC (queue-pair management, work submission,
completion polling).  Transfers submitted to the group are sharded and
rotated across the available NICs — essential on EFA where 2-4 NICs must be
aggregated to reach 400 Gbps.

Channel selection is **per destination pair** (heterogeneous-fabric
refactor): each Domain keeps a pair-keyed channel table and asks the
fabric's :class:`~repro.core.topology.Topology` which transport a peer pair
rides — NVLink for same-host pairs, the Domain's own NIC for same-kind
pairs, or a derived cross-fabric preset for mixed-NIC pairs.  Off-NIC
transports (NVLink, cross) are served by dedicated per-pair queues so the
NIC pipeline stays free for the traffic that actually crosses it (paper §6).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .netsim import EventLoop, NicQueue, NicSpec, POST_US, stable_hash
from .topology import ChannelPlan, Topology
from .transport import Channel, WireOp


@dataclass(frozen=True)
class NetAddr:
    """Serializable network address of a DomainGroup (paper: ``NetAddr``)."""

    node: str
    dev: int

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.node}/gpu{self.dev}"


@dataclass(frozen=True)
class Pages:
    """Indirect page addressing: ``addr = base + indices[i]*stride + offset``."""

    indices: Tuple[int, ...]
    stride: int
    offset: int = 0

    def resolve(self, page_len: int) -> List[int]:
        """Byte offsets of each page within the owning region."""
        return [int(i) * self.stride + self.offset for i in self.indices]


class MemoryRegion:
    """A registered memory region backed by a numpy byte buffer."""

    _ids = itertools.count()

    def __init__(self, buf: np.ndarray, device: int):
        if buf.dtype != np.uint8 or buf.ndim != 1:
            raise ValueError("MemoryRegion requires a flat uint8 view")
        self.buf = buf
        self.device = device
        self.region_id = next(MemoryRegion._ids)

    def __len__(self) -> int:
        return self.buf.size

    def write_bytes(self, offset: int, data) -> None:
        """Land ``data`` (any buffer-protocol object) at ``offset``."""
        n = len(data)
        if offset < 0 or offset + n > self.buf.size:
            raise IndexError(
                f"remote write out of bounds: [{offset}, {offset+n}) "
                f"into region of {self.buf.size} bytes")
        self.buf[offset:offset + n] = np.frombuffer(data, np.uint8)

    def read_bytes(self, offset: int, nbytes: int) -> bytes:
        """Copy ``nbytes`` out of the region (bounds-checked)."""
        if offset < 0 or offset + nbytes > self.buf.size:
            raise IndexError("local read out of bounds")
        return self.buf[offset:offset + nbytes].tobytes()

    def snapshot(self, offset: int, nbytes: int) -> memoryview:
        """One-copy payload snapshot (the WRITE's "don't touch src until
        completion" contract).  All downstream NIC striping and MTU
        chunking slices this view zero-copy; the snapshot never aliases
        the live region buffer."""
        return memoryview(self.read_bytes(offset, nbytes))


@dataclass(frozen=True)
class MrHandle:
    """Local handle for a registered region (source of transfers)."""

    region_id: int
    owner: NetAddr


@dataclass(frozen=True)
class MrDesc:
    """Serializable descriptor exchanged with peers (paper: ptr + rkeys).

    ``rkeys`` carries one (nic_index, rkey) pair per NIC in the owning
    DomainGroup, like the paper's ``Vec<(NetAddr, u64)>``.
    """

    region_id: int
    owner: NetAddr
    nbytes: int
    rkeys: Tuple[Tuple[int, int], ...]


@dataclass(frozen=True)
class ScatterDst:
    """One scatter destination: a slice of the source MR -> a remote offset."""

    len: int
    src: int                      # offset into the scatter source MR
    dst: Tuple[MrDesc, int]       # (remote descriptor, remote offset)


@dataclass(frozen=True)
class PayloadDst:
    """A scatter destination that carries its own payload bytes.

    The gather-into-snapshot fast path: the caller hands a freshly
    gathered, contiguous uint8 buffer that IS the submission snapshot —
    no staging copy into a registered region and no second snapshot copy.
    The caller must honour the WRITE contract (don't touch the buffer
    until completion); a fancy-indexing gather result trivially does.
    """

    payload: object               # contiguous 1-D uint8 buffer
    dst: Tuple[MrDesc, int]       # (remote descriptor, remote offset)


class WrBatch:
    """A template of N work requests posted in ONE event-loop entry.

    Mirrors the paper's WR templating (§3.4): the application pays one
    app->worker enqueue for the whole batch, while each WR still pays the
    per-WR posting cost on the DomainGroup's worker — so per-request
    submission overhead is amortised without changing the NIC-side timing
    of any individual WRITE.  WRs are stored as bare tuples: this is the
    hot path of every scatter/paged submission.
    """

    __slots__ = ("group", "wrs", "nbytes")

    def __init__(self, group: "DomainGroup"):
        self.group = group
        # (op, dst_group, nic_index, extra_post_us) per templated WR
        self.wrs: List[Tuple[WireOp, "DomainGroup", Optional[int], float]] = []
        self.nbytes = 0    # total payload bytes templated into this batch

    def add(self, op: WireOp, dst_group: "DomainGroup",
            nic_index: Optional[int] = None, extra_post_us: float = 0.0) -> None:
        """Template one WR into the batch (posted later, in batch order)."""
        self.wrs.append((op, dst_group, nic_index, extra_post_us))
        self.nbytes += op.nbytes

    def __len__(self) -> int:
        return len(self.wrs)

    def post(self) -> None:
        """Post every WR back-to-back on the owning group's worker."""
        post_write = self.group.post_write
        for op, dst_group, nic_index, extra_post_us in self.wrs:
            post_write(dst_group, op, nic_index=nic_index,
                       extra_post_us=extra_post_us)


class Domain:
    """One NIC: owns a NicQueue and a pair-keyed table of peer channels.

    Same-host peers bypass the NIC through an NVLink-class channel (paper
    §6: intra-node payloads move over NVLink while RDMA transfers run in
    the background); mixed-NIC peers ride a derived cross-fabric preset.
    Which transport a peer gets is resolved per pair through the owning
    fabric's :class:`~repro.core.topology.Topology` (or, for standalone
    groups, the legacy same-node-string rule)."""

    def __init__(self, loop: EventLoop, spec: NicSpec, addr: NetAddr, index: int,
                 seed: int, topology: Optional[Topology] = None):
        self.loop = loop
        self.spec = spec
        self.addr = addr
        self.index = index
        self.nic = NicQueue(loop, spec)
        self.topology = topology
        self._channels: Dict[Tuple[NetAddr, int], Channel] = {}
        self._nvlink: Dict[NetAddr, Channel] = {}
        self._cross: Dict[Tuple[NetAddr, int], Channel] = {}
        self._seed = seed

    def plan_for(self, peer: NetAddr) -> ChannelPlan:
        """The resolved :class:`ChannelPlan` for traffic from here to
        ``peer`` (cached per pair inside the topology)."""
        if self.topology is not None:
            return self.topology.plan(self.addr, self.spec, peer)
        # Standalone group (no fabric topology): legacy node-string rule.
        if peer.node == self.addr.node and peer.dev != self.addr.dev:
            from .netsim import NVLINK
            return ChannelPlan("nvlink", NVLINK, dedicated=True)
        return ChannelPlan("nic", self.spec, dedicated=False)

    def channel_to(self, peer: NetAddr, peer_index: int) -> Channel:
        """The (lazily created) channel carrying WireOps to ``peer``.

        NVLink channels are keyed per peer address; NIC and cross-fabric
        channels per ``(peer, peer NIC index)`` — one queue pair per remote
        NIC, like the paper's per-QP domains.  Seed derivations on the
        NVLink and same-kind NIC paths are unchanged from the single-kind
        fabric, keeping their jitter streams bit-identical."""
        plan = self.plan_for(peer)
        if plan.kind == "nvlink":
            if peer not in self._nvlink:
                seed = stable_hash(self._seed, self.addr, peer, "nvl")
                self._nvlink[peer] = Channel(
                    self.loop, NicQueue(self.loop, plan.spec), seed,
                    label=f"{self.addr}>{peer} nvlink")
            return self._nvlink[peer]
        if plan.kind == "cross":
            key = (peer, peer_index)
            if key not in self._cross:
                seed = stable_hash(self._seed, self.addr, self.index, peer,
                                   peer_index, "x", plan.spec.name)
                self._cross[key] = Channel(
                    self.loop, NicQueue(self.loop, plan.spec), seed,
                    label=f"{self.addr}[{self.index}]>{peer} "
                          f"x:{plan.spec.name}")
            return self._cross[key]
        key = (peer, peer_index)
        if key not in self._channels:
            # Deterministic per-channel seed (process-stable).
            seed = stable_hash(self._seed, self.addr, self.index, peer, peer_index)
            # All peers of one Domain share its NIC queue: the label names
            # the QUEUE (trace tracks are per queue, not per peer).
            self._channels[key] = Channel(self.loop, self.nic, seed,
                                          label=f"{self.addr} nic{self.index}")
        return self._channels[key]


class DomainGroup:
    """All NICs serving one GPU; shards transfers across them.

    The paper requires all peers to use the same number of NICs per GPU so
    any transfer has full knowledge of both sides' NICs.  The simulator
    relaxes that to *per pair* knowledge: sender-side striping uses this
    group's own NIC count, and mixed-NIC pairs resolve their transport
    through the fabric topology (Holmes-style heterogeneous clusters).
    """

    def __init__(self, loop: EventLoop, addr: NetAddr, specs: Sequence[NicSpec],
                 seed: int, topology: Optional[Topology] = None):
        self.loop = loop
        self.addr = addr
        self.domains = [Domain(loop, s, addr, i, seed + i, topology=topology)
                        for i, s in enumerate(specs)]
        self._rr = 0
        self.post_us = POST_US.get(specs[0].name, 0.1)
        self._post_busy_until = 0.0
        self.regions: Dict[int, MemoryRegion] = {}
        self.posted_writes = 0
        # observability hooks (repro.obs); None => zero-cost guarded check
        self.tracer = None
        self.health = None
        # fault-injection hook (repro.core.faults.FaultPlan); None => the
        # direct channel post below, bit-identical to the pre-fault fabric
        self.faults = None

    # -- memory ---------------------------------------------------------
    def register(self, buf: np.ndarray, device: int) -> Tuple[MrHandle, MrDesc]:
        """Register ``buf`` as an MR; returns (local handle, wire descriptor)."""
        region = MemoryRegion(buf, device)
        self.regions[region.region_id] = region
        rkeys = tuple((d.index, stable_hash(region.region_id, d.index))
                      for d in self.domains)
        return (MrHandle(region.region_id, self.addr),
                MrDesc(region.region_id, self.addr, buf.size, rkeys))

    def region(self, region_id: int) -> MemoryRegion:
        """The registered :class:`MemoryRegion` for ``region_id``."""
        return self.regions[region_id]

    # -- posting --------------------------------------------------------
    def _post_delay(self) -> float:
        """Serialise WR posting on the worker thread (Table 8/9 overhead)."""
        start = max(self.loop.now, self._post_busy_until)
        self._post_busy_until = start + self.post_us
        self.posted_writes += 1
        return self._post_busy_until - self.loop.now

    def next_domain(self) -> Domain:
        """Round-robin NIC selection for un-pinned WRs."""
        d = self.domains[self._rr % len(self.domains)]
        self._rr += 1
        return d

    def post_write(self, dst_group: "DomainGroup", op: WireOp,
                   nic_index: Optional[int] = None,
                   extra_post_us: float = 0.0) -> None:
        """Post a single WRITE, optionally pinned to a NIC by index.

        ``extra_post_us`` models additional per-WR descriptor setup beyond
        the batched-posting fast path (scatter/barrier; Table 9)."""
        d = self.domains[nic_index] if nic_index is not None else self.next_domain()
        if extra_post_us:
            self._post_busy_until = max(self.loop.now, self._post_busy_until) + extra_post_us
        delay = self._post_delay()
        ch = d.channel_to(dst_group.addr, d.index)
        if self.tracer is not None:
            self.tracer._on_post(op, ch, self, extra_post_us)
        if self.health is not None:
            self.health._on_post(op, ch, self, extra_post_us)
        if self.faults is not None:
            self.faults.on_post(self, dst_group, op, ch, delay, d.index)
            return
        self.loop.schedule(delay, lambda: ch.post(op))

    def split_across_nics(self, nbytes: int) -> List[Tuple[int, int, int]]:
        """Split a large WRITE into (nic_index, offset, length) stripes."""
        n = len(self.domains)
        if n == 1 or nbytes == 0:
            return [(0, 0, nbytes)]
        stripe = -(-nbytes // n)
        out = []
        for i in range(n):
            lo = i * stripe
            hi = min(nbytes, lo + stripe)
            if hi > lo:
                out.append((i, lo, hi - lo))
        return out
