"""TransferEngine: the Fig. 2 API over the simulated fabric.

One ``TransferEngine`` per node manages a ``DomainGroup`` per GPU (worker
threads in the paper; event-loop continuations here).  A ``Fabric`` owns the
event loop and routes descriptors between engines.

Faithfulness notes:
* There are NO ordering guarantees across any operations — all completion
  notification goes through the ImmCounter or sender-side callbacks.
* ``submit_send`` copies the payload at submission (caller may reuse the
  buffer immediately); one-sided WRITEs are zero-copy in the paper — the
  simulator takes ONE snapshot at submission (modeling the "don't touch src
  until completion" contract); all NIC striping and MTU chunking slice that
  snapshot as zero-copy memoryviews.
* WRITE submissions are batched: every ``submit_*`` templates its work
  requests into a ``WrBatch`` posted in a single event-loop entry (one
  ``ENQUEUE_US`` per submission, per-WR ``post_us`` on the worker — §3.4).
* SEND/RECV uses only the first NIC of a group (paper §3.3).
* Large single WRITEs are striped across all NICs; paged writes, scatter and
  barrier rotate across NICs (paper §3.4 "Sharding inside a DOMAINGROUP").
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .domain import (DomainGroup, MemoryRegion, MrDesc, MrHandle, NetAddr,
                     Pages, PayloadDst, ScatterDst, WrBatch)
from .faults import BackpressureError, TransferError
from .imm_counter import ImmCounter
from .netsim import (ENQUEUE_US, EventLoop, NicSpec, CX7, EFA_100, EFA_200,
                     degrade, stable_hash)
from .topology import ChannelPlan, TopoEntry, Topology, cross_spec
from .transport import WireOp
from .uvm import UvmWatcher

# Extra per-WR posting overhead on the scatter/barrier path (WR templating
# still leaves per-peer descriptor setup; calibrated to Table 9).
SCATTER_EXTRA_US = {"cx7": 0.045, "efa": 0.0, "efa4": 0.0, "nvlink": 0.02}

NIC_PRESETS: Dict[str, Tuple[NicSpec, int]] = {
    # name -> (per-NIC spec, NICs per GPU)
    "cx7": (CX7, 1),          # H100 + 1 x 400 Gbps ConnectX-7
    "efa": (EFA_200, 2),      # H200 + 2 x 200 Gbps EFA (p5en)
    "efa4": (EFA_100, 4),     # H100 + 4 x 100 Gbps EFA (p5)
}


class Flag:
    """Atomic-flag completion target (paper: ``OnDone::Flag``)."""

    def __init__(self) -> None:
        self._set = False

    def set(self) -> None:
        """Mark the flag (fired by the transport on completion)."""
        self._set = True

    def is_set(self) -> bool:
        """True once the associated operation completed."""
        return self._set


OnDone = Union[Callable[[], None], Flag, None]


def _fire(done: OnDone) -> None:
    if done is None:
        return
    if isinstance(done, Flag):
        done.set()
    else:
        done()


class BatchStats:
    """Per-engine submission-batching counters (ROADMAP: WRs/enqueue for the
    ablation bench).  One ``record`` per event-loop enqueue; derived ratios
    say how well WR templating amortises the app->worker handoff.

    ``wrs_by_dst`` tracks posted WRs per destination DomainGroup address —
    the accounting behind per-peer WR-budget assertions (the moekit decode
    fast path's "at most 2 data WRITEs per peer per round" invariant is
    tested as deltas of this map)."""

    __slots__ = ("batches", "wrs", "nbytes", "wrs_by_dst")

    def __init__(self) -> None:
        self.batches = 0
        self.wrs = 0
        self.nbytes = 0
        self.wrs_by_dst: Dict = {}

    def record(self, batch: WrBatch) -> None:
        """Account one enqueued WrBatch (called per event-loop handoff)."""
        self.batches += 1
        self.wrs += len(batch)
        self.nbytes += batch.nbytes
        per = self.wrs_by_dst
        for _op, dst_group, _nic, _extra in batch.wrs:
            addr = dst_group.addr
            per[addr] = per.get(addr, 0) + 1

    def snapshot_by_dst(self) -> Dict:
        """Copy of the per-destination WR counts (diff two snapshots to get
        per-peer WRs over a protocol phase)."""
        return dict(self.wrs_by_dst)

    @property
    def wrs_per_enqueue(self) -> float:
        """Mean WRs amortised per app->worker handoff (templating win)."""
        return self.wrs / self.batches if self.batches else 0.0

    @property
    def bytes_per_batch(self) -> float:
        """Mean payload bytes per enqueued batch."""
        return self.nbytes / self.batches if self.batches else 0.0

    def as_dict(self) -> Dict[str, float]:
        """All counters + derived ratios as a flat dict (bench rows)."""
        return {"batches": self.batches, "wrs": self.wrs,
                "nbytes": self.nbytes,
                "wrs_per_enqueue": self.wrs_per_enqueue,
                "bytes_per_batch": self.bytes_per_batch}


class BatchState:
    """Sender-side completion state shared by every logical write of one
    batched submission (replaces the per-op ``{"sent": n}`` dict closures):
    fires ``on_done`` exactly once, when all logical writes report sent.

    ``on_error`` is the terminal failure path (retry exhaustion or peer
    death under a :class:`~repro.core.faults.FaultPlan`): the FIRST failed
    logical write fires it once with a reason string, ``on_done`` is
    permanently suppressed, and with no handler installed a
    :class:`TransferError` propagates out of ``Fabric.run()`` — loud,
    never a silent hang."""

    __slots__ = ("remaining", "on_done", "on_error", "failed")

    def __init__(self, n_logical: int, on_done: OnDone,
                 on_error: Optional[Callable[[str], None]] = None):
        self.remaining = n_logical
        self.on_done = on_done
        self.on_error = on_error
        self.failed = False

    def note_sent(self) -> None:
        """One logical write finished sending; fires ``on_done`` at zero."""
        self.remaining -= 1
        if self.remaining == 0 and not self.failed:
            _fire(self.on_done)

    def note_error(self, reason: str) -> None:
        """One logical write failed terminally; first failure wins."""
        if self.failed:
            return
        self.failed = True
        if self.on_error is not None:
            self.on_error(reason)
        else:
            raise TransferError(reason)


class WriteState:
    """Completion state for ONE logical WRITE (possibly striped over NICs).

    The receiver-side immediate fires exactly once, when the last stripe's
    payload is fully visible; the sender side notifies the owning
    ``BatchState`` once all stripes have local completions.  A stripe that
    exhausts its retry budget marks the whole logical write ``failed`` —
    late deliveries of sibling stripes are then ignored (the immediate
    never fires for a failed write) and the batch takes its error path."""

    __slots__ = ("n_parts", "delivered", "sent", "imm", "counter", "batch",
                 "fabric", "failed")

    def on_fenced(self, op, now: float) -> None:
        """Epoch-fence rejection (zombie-writer guard): the receiving
        engine's fence table holds a higher epoch than this WRITE's stamp —
        the bytes were not written and the immediate must never fire.
        Surfaces through the standard terminal ``on_error`` path (first
        failure wins) after feeding the observability loop: a ``fenced``
        fault count, a tracer/recorder instant, and a rate-limited flight
        dump carrying the fenced WR and its stale epoch."""
        if self.failed:
            return
        fence = op.fences.get(op.src_node)
        reason = (f"fenced: WRITE from {op.src_node} carries view epoch "
                  f"{op.fence_epoch} below fence {fence}")
        fab = self.fabric
        if fab is not None:
            mon = fab.health
            if mon is not None:
                mon.on_fault("fenced")
            args = {"src": op.src_node, "imm": op.imm, "nbytes": op.nbytes,
                    "epoch": op.fence_epoch, "fence": fence}
            tr = fab.tracer
            if tr is not None:
                tr.instant("fault", f"fenced:{op.src_node}", args)
            rec = getattr(fab, "recorder", None)
            if rec is not None:
                if tr is None:
                    rec.note("fault", f"fenced:{op.src_node}", args)
                rec.dump("fence-rejected")
        self.on_error(op, reason)

    def __init__(self, n_parts: int, imm: Optional[int],
                 counter: Optional[ImmCounter], batch: BatchState,
                 fabric: Optional["Fabric"] = None):
        self.n_parts = n_parts
        self.delivered = 0
        self.sent = 0
        self.imm = imm
        self.counter = counter
        self.batch = batch
        self.fabric = fabric
        self.failed = False

    def on_delivered(self, op, now: float) -> None:
        """Receiver-side stripe landing; fires the immediate on the last."""
        if self.failed:
            return
        fab = self.fabric
        if fab is not None and fab.health is not None and op.span is not None:
            fab.health.on_deliver(op.span)
        self.delivered += 1
        if self.delivered == self.n_parts:
            if fab is not None:
                fab.inflight_writes -= 1
            if self.imm is not None:
                self.counter.increment(self.imm, now)

    def on_sent(self, now: float) -> None:
        """Sender-side stripe completion; notifies the batch on the last."""
        if self.failed:
            return
        self.sent += 1
        if self.sent == self.n_parts:
            self.batch.note_sent()

    def on_error(self, op, reason: str) -> None:
        """Terminal stripe failure (from the FaultPlan): fail the logical
        write once — release the in-flight accounting, never fire the
        immediate, and surface the error through the batch."""
        if self.failed:
            return
        self.failed = True
        if self.fabric is not None:
            self.fabric.inflight_writes -= 1
        self.batch.note_error(reason)


class TransferEngine:
    """The paper's Fig. 2 uniform transfer API for one node's GPUs.

    One engine per (simulated) process: it owns a :class:`DomainGroup` per
    device, the per-device :class:`ImmCounter`s, and the two-sided SEND/
    RECV pools.  ``host`` names the physical machine the engine runs on —
    engines sharing a host reach each other over NVLink (when ``nvlink``)
    regardless of NIC kind; it defaults to ``node``, so a single-engine-
    per-name fabric behaves exactly as before the heterogeneous-fabric
    refactor."""

    def __init__(self, fabric: "Fabric", node: str, nic: str, num_devices: int,
                 host: Optional[str] = None, nvlink: bool = True):
        self.fabric = fabric
        self.loop = fabric.loop
        self.node = node
        self.host = host if host is not None else node
        self.nvlink = nvlink
        spec, default_n = NIC_PRESETS[nic]
        self.nic_name = nic
        self.nic_spec = spec
        self.groups: Dict[int, DomainGroup] = {}
        self.counters: Dict[int, ImmCounter] = {}
        self._recv_pools: Dict[int, List] = {}
        self._pending_sends: Dict[int, List] = {}
        # RNR backpressure bound: a NIC RNR-retries only so long before the
        # QP errors out — cap the parked-send queue per device and surface a
        # structured BackpressureError (via on_backpressure when set, else
        # raised) instead of growing without bound
        self.max_pending_sends = 256
        self.on_backpressure: Optional[Callable[[BackpressureError], None]] = None
        self.dropped_sends = 0
        # device -> (WrBatch, created_at): SENDs submitted in the same loop
        # entry coalesce into one enqueue (flushed ENQUEUE_US later)
        self._send_batches: Dict[int, Tuple[WrBatch, float]] = {}
        # epoch fences (repro.ctrl zombie-writer guard): src node -> minimum
        # acceptable view epoch.  Inbound WRITEs stamped with a lower epoch
        # are rejected at landing; empty table = no checks anywhere.
        self.fences: Dict[str, int] = {}
        self.batch_stats = BatchStats()
        for dev in range(num_devices):
            addr = NetAddr(node, dev)
            seed = fabric.seed ^ (stable_hash(addr) & 0xFFFF)
            self.groups[dev] = DomainGroup(self.loop, addr, [spec] * default_n,
                                           seed, topology=fabric.topology)
            self.counters[dev] = ImmCounter()
            fabric._register_group(addr, self.groups[dev], self)

    # -- identity ---------------------------------------------------------
    def main_address(self) -> NetAddr:
        """The engine's device-0 address (control-plane endpoint)."""
        return NetAddr(self.node, 0)

    def address(self, device: int = 0) -> NetAddr:
        """The :class:`NetAddr` of one of this engine's devices."""
        return NetAddr(self.node, device)

    # -- epoch fencing ------------------------------------------------------
    def set_fence(self, src_node: str, min_epoch: int) -> None:
        """Reject future WRITE landings from ``src_node`` stamped with a
        view epoch below ``min_epoch`` (the zombie-writer guard — installed
        when the ctrl plane evicts a peer whose pages are being
        reallocated).  Fences only tighten: a lower ``min_epoch`` than the
        current fence is ignored, so a delayed duplicate CANCEL can never
        loosen the guard."""
        cur = self.fences.get(src_node)
        if cur is None or min_epoch > cur:
            self.fences[src_node] = int(min_epoch)

    # -- memory region management ------------------------------------------
    def reg_mr(self, buf: np.ndarray, device: int = 0) -> Tuple[MrHandle, MrDesc]:
        """Register a flat uint8 buffer; returns (local handle, peer desc)."""
        return self.groups[device].register(buf, device)

    def region_of(self, handle: MrHandle) -> MemoryRegion:
        """The backing :class:`MemoryRegion` for a local handle."""
        return self.fabric.group(handle.owner).region(handle.region_id)

    # -- two-sided SEND/RECV ------------------------------------------------
    def submit_recvs(self, length: int, count: int,
                     cb: Callable[[bytes], None], device: int = 0) -> None:
        """Post ``count`` RECV buffers of ``length`` bytes; ``cb`` gets each
        arriving payload and the buffer is auto re-posted (paper §3.3)."""
        pool = self._recv_pools.setdefault(device, [])
        for _ in range(count):
            pool.append((length, cb))
        # Drain sends that arrived before receives were posted (the fabric
        # queues them, as a NIC would RNR-retry).
        addr = self.address(device)
        pending = self._pending_sends.pop(device, [])
        for payload in pending:
            self._deliver_send(device, payload)

    def _deliver_send(self, device: int, payload: bytes) -> None:
        pool = self._recv_pools.get(device, [])
        if not pool:
            # RNR path: park the payload until a RECV is posted — bounded.
            # At the cap the SEND is dropped (accounting already settled by
            # the caller) and the backpressure error is surfaced.
            pending = self._pending_sends.setdefault(device, [])
            if len(pending) >= self.max_pending_sends:
                self.dropped_sends += 1
                err = BackpressureError(self.node, device, len(pending))
                if self.on_backpressure is not None:
                    self.on_backpressure(err)
                    return
                raise err
            pending.append(payload)
            return
        length, cb = pool.pop(0)
        if len(payload) > length:
            raise ValueError(f"SEND of {len(payload)} bytes exceeds posted RECV of {length}")
        cb(payload)
        # Buffer is automatically re-posted after the callback (paper §3.3).
        pool.append((length, cb))

    def submit_send(self, addr: NetAddr, msg: bytes,
                    cb: OnDone = None, device: int = 0) -> None:
        """RPC-style two-sided send; copies ``msg`` at submission.

        SENDs ride a :class:`WrBatch` (§3.4): every send submitted in the
        same event-loop entry joins the pending batch and the whole train is
        posted by ONE flush ``ENQUEUE_US`` later — control-plane bursts
        (view broadcasts, lease sweeps) pay one app->worker handoff instead
        of one per message.  Submission order is preserved; per-WR posting
        cost on the worker is unchanged.
        """
        payload = bytes(msg)
        src = self.groups[device]
        fab = self.fabric
        dst_group, dst_engine = fab._lookup(addr)
        fab.inflight_sends += 1

        def on_delivered(op: WireOp, now: float) -> None:
            fab.inflight_sends -= 1
            if fab.health is not None and op.span is not None:
                fab.health.on_deliver(op.span)
            dst_engine._deliver_send(addr.dev, payload)

        op = WireOp(kind="send", payload=None, dst_region=None, dst_offset=0,
                    imm=None, on_delivered=on_delivered,
                    on_sent=(lambda now: _fire(cb)) if cb is not None else None,
                    nbytes=len(payload))
        tr = fab.tracer
        mon = fab.health
        if tr is not None:
            op.span = tr.begin_wr("send", addr, len(payload), None,
                                  src=str(src.addr))
        elif mon is not None:
            op.span = mon.begin_wr("send", addr, len(payload), None,
                                   src=str(src.addr))
        pending = self._send_batches.get(device)
        if pending is not None and pending[1] == self.loop.now:
            # SEND/RECV uses only the first NIC in the group.
            pending[0].add(op, dst_group, nic_index=0)
            return
        batch = WrBatch(src)
        batch.add(op, dst_group, nic_index=0)
        self._send_batches[device] = (batch, self.loop.now)

        def flush() -> None:
            cur = self._send_batches.get(device)
            if cur is not None and cur[0] is batch:
                del self._send_batches[device]
            # batch_stats stays a one-sided-WRITE submission metric
            # (bench_ablation/kvlayout hot-path assertions count on it)
            batch.post()

        self.loop.schedule(ENQUEUE_US, flush)

    # -- completion notification --------------------------------------------
    def expect_imm_count(self, imm: int, count: int,
                         cb: Callable[[], None], device: int = 0) -> None:
        """Fire ``cb`` when ``count`` WRITEIMMs carrying ``imm`` have landed."""
        self.counters[device].expect(imm, count, cb)

    def imm_value(self, imm: int, device: int = 0) -> int:
        """Current landed-WRITEIMM count for ``imm`` on ``device``."""
        return self.counters[device].value(imm)

    # -- one-sided WRITE ------------------------------------------------------
    def _add_logical_write(self, batch: WrBatch, batch_state: BatchState,
                           payload, dst: MrDesc, dst_offset: int,
                           imm: Optional[int], stripe: bool,
                           nic_rr: Optional[int] = None,
                           extra_post_us: float = 0.0,
                           synthetic_bytes: Optional[int] = None,
                           fence_epoch: Optional[int] = None) -> None:
        """Template one logical WRITE into ``batch``, striping across NICs
        when ``stripe``.  ``payload`` is a zero-copy buffer view (already
        snapshotted by the caller); stripes slice it without copying.

        ``synthetic_bytes``: timing-only write of that size (no payload copy)
        — used by cluster-scale benchmarks where materialising terabytes of
        real bytes is pointless; all protocol behaviour is identical.

        ``fence_epoch``: stamp the WRITE with the sender's current view
        epoch; the receiving engine rejects it at landing if its fence
        table demands a higher epoch from this node (zombie-writer guard).
        None (default) posts an unstamped, never-fenced WRITE."""
        src_group = batch.group
        fab = self.fabric
        dst_group, dst_engine = fab._lookup(dst.owner)
        dst_region = dst_group.region(dst.region_id) if synthetic_bytes is None else None
        nbytes = (len(payload) if payload is not None else 0) \
            if synthetic_bytes is None else synthetic_bytes
        parts = src_group.split_across_nics(nbytes) if stripe else [(None, 0, nbytes)]
        fab.inflight_writes += 1
        state = WriteState(len(parts), imm,
                           dst_engine.counters[dst.owner.dev], batch_state,
                           fab)
        tr = fab.tracer
        mon = fab.health
        obs_src = (str(src_group.addr)
                   if tr is not None or mon is not None else "")
        for nic_index, off, ln in parts:
            chunk = payload[off:off + ln] if payload is not None else None
            op = WireOp(kind="write", payload=chunk, dst_region=dst_region,
                        dst_offset=dst_offset + off, imm=imm,
                        on_delivered=state.on_delivered, on_sent=state.on_sent,
                        nbytes=ln, on_error=state.on_error)
            if fence_epoch is not None:
                op.fence_epoch = int(fence_epoch)
                op.src_node = src_group.addr.node
                op.fences = dst_engine.fences
                op.on_fenced = state.on_fenced
            if tr is not None:
                op.span = tr.begin_wr("write", dst.owner, ln, imm, src=obs_src)
            elif mon is not None:
                op.span = mon.begin_wr("write", dst.owner, ln, imm,
                                       src=obs_src)
            idx = nic_index if stripe else (nic_rr if nic_rr is not None else None)
            batch.add(op, dst_group, nic_index=idx, extra_post_us=extra_post_us)

    def _enqueue_batch(self, batch: WrBatch) -> None:
        """One application->worker handoff for the whole batch (§3.4)."""
        self.batch_stats.record(batch)
        tr = self.fabric.tracer
        if tr is not None:
            tr.n_batches += 1
            tr.n_wrs += len(batch)
            tr.n_bytes += batch.nbytes
        mon = self.fabric.health
        if mon is not None:
            mon.on_enqueue(str(batch.group.addr), len(batch), batch.nbytes)
        self.loop.schedule(ENQUEUE_US, batch.post)

    def submit_single_write(self, length: int, imm: Optional[int],
                            src: Tuple[MrHandle, int], dst: Tuple[MrDesc, int],
                            on_done: OnDone = None,
                            on_error: Optional[Callable[[str], None]] = None,
                            fence_epoch: Optional[int] = None
                            ) -> None:
        """One-sided WRITE of ``length`` bytes, striped across all NICs;
        ``imm`` (if set) increments the receiver's counter once, when the
        last stripe lands.  ``on_error`` is the terminal failure path under
        fault injection (see :class:`BatchState`); ``fence_epoch`` stamps
        the WRITE for the receiver's epoch fence (zombie-writer guard)."""
        handle, src_off = src
        desc, dst_off = dst
        src_group = self.fabric.group(handle.owner)
        payload = src_group.region(handle.region_id).snapshot(src_off, length)
        batch = WrBatch(src_group)
        self._add_logical_write(batch, BatchState(1, on_done, on_error),
                                payload, desc, dst_off, imm, stripe=True,
                                fence_epoch=fence_epoch)
        self._enqueue_batch(batch)

    def submit_write_batch(self, writes: Sequence[Tuple[int, Optional[int],
                                                        Tuple[MrHandle, int],
                                                        Tuple[MrDesc, int]]],
                           on_done: OnDone = None, device: int = 0,
                           on_error: Optional[Callable[[str], None]] = None
                           ) -> None:
        """Batched single-write submission: N ``(length, imm, (handle,
        src_off), (desc, dst_off))`` WRITEs templated and posted in one
        event-loop entry.  Each entry keeps ``submit_single_write``
        semantics (NIC striping, per-write immediate); ``on_done`` fires
        after ALL entries have sender-side completions; ``on_error`` fires
        once on the first entry that fails terminally."""
        src_group = self.groups[device]
        n = len(writes)
        if n == 0:
            _fire(on_done)
            return
        batch = WrBatch(src_group)
        batch_state = BatchState(n, on_done, on_error)
        for length, imm, (handle, src_off), (desc, dst_off) in writes:
            if handle.owner != src_group.addr:
                raise ValueError("submit_write_batch: mixed source groups")
            payload = src_group.region(handle.region_id).snapshot(src_off, length)
            self._add_logical_write(batch, batch_state, payload, desc,
                                    dst_off, imm, stripe=True)
        self._enqueue_batch(batch)

    def submit_paged_writes(self, page_len: int, imm: Optional[int],
                            src: Tuple[MrHandle, Pages], dst: Tuple[MrDesc, Pages],
                            on_done: OnDone = None,
                            on_error: Optional[Callable[[str], None]] = None,
                            fence_epoch: Optional[int] = None
                            ) -> None:
        """One WRITE per page; pages rotate across NICs.  All pages are
        templated into a single ``WrBatch`` (one enqueue, per-WR posting
        cost amortised on the worker).

        Each page's WRITEIMM increments the receiver's counter by one (the
        KvCache protocol counts ``n_pages * n_layers + 1`` total events).
        """
        handle, src_pages = src
        desc, dst_pages = dst
        if len(src_pages.indices) != len(dst_pages.indices):
            raise ValueError("src/dst page counts differ")
        src_group = self.fabric.group(handle.owner)
        region = src_group.region(handle.region_id)
        src_offs = src_pages.resolve(page_len)
        dst_offs = dst_pages.resolve(page_len)
        n = len(src_offs)
        if n == 0:
            _fire(on_done)
            return
        batch = WrBatch(src_group)
        batch_state = BatchState(n, on_done, on_error)
        n_nics = len(src_group.domains)
        for k, (so, do) in enumerate(zip(src_offs, dst_offs)):
            self._add_logical_write(batch, batch_state,
                                    region.snapshot(so, page_len), desc, do,
                                    imm, stripe=False, nic_rr=k % n_nics,
                                    fence_epoch=fence_epoch)
        self._enqueue_batch(batch)

    # -- peer groups: scatter / barrier ---------------------------------------
    def add_peer_group(self, addrs: Sequence[NetAddr]) -> int:
        """Register a peer group for scatter/barrier; returns its id."""
        return self.fabric._add_peer_group(list(addrs))

    def submit_scatter(self, handle: MrHandle, dsts: Sequence[ScatterDst],
                       imm: Optional[int] = None, on_done: OnDone = None,
                       device: int = 0,
                       on_error: Optional[Callable[[str], None]] = None
                       ) -> None:
        """WRITE a distinct slice of ``handle`` to each peer (paper §3.3).

        WR-templating in the paper amortises descriptor setup; posting cost
        is modeled by the DomainGroup's per-WR posting delay (Table 9).
        """
        self.submit_scatters([(handle, dsts, imm, on_done, on_error)],
                             device=device)

    def submit_scatters(self, groups: Sequence[Tuple],
                        device: int = 0) -> None:
        """Batched scatter submission: several ``(handle, dsts, imm,
        on_done)`` scatters templated into ONE WrBatch / event-loop entry.
        A group may carry an optional 5th element ``on_error`` — the
        per-scatter terminal failure callback under fault injection — and
        an optional 6th element ``fence_epoch`` stamping the scatter's
        WRITEs for the receiver's epoch fence (zombie-writer guard).

        Completion state stays per-scatter (each ``on_done`` fires when its
        own destinations have sender-side completions; each imm counts its
        own WRITEs) — only the submission is coalesced.

        Destinations may be :class:`ScatterDst` (payload sliced from the
        group's ``handle`` region at submission, the snapshot copy) or
        :class:`PayloadDst` (caller-gathered bytes used AS the snapshot —
        zero staging copies; ``handle`` may then be None)."""
        src_group = self.groups[device]
        extra = SCATTER_EXTRA_US.get(self.nic_name, 0.0)
        n_nics = len(src_group.domains)
        batch = WrBatch(src_group)
        for handle, dsts, imm, on_done, *rest in groups:
            on_error = rest[0] if rest else None
            fence_epoch = rest[1] if len(rest) > 1 else None
            n = len(dsts)
            if n == 0:
                _fire(on_done)
                continue
            region = (src_group.region(handle.region_id)
                      if handle is not None else None)
            batch_state = BatchState(n, on_done, on_error)
            for k, sd in enumerate(dsts):
                desc, off = sd.dst
                if isinstance(sd, PayloadDst):
                    payload = sd.payload
                else:
                    payload = region.snapshot(sd.src, sd.len)
                self._add_logical_write(batch, batch_state, payload,
                                        desc, off, imm, stripe=False,
                                        nic_rr=k % n_nics,
                                        extra_post_us=extra,
                                        fence_epoch=fence_epoch)
        if len(batch):
            self._enqueue_batch(batch)

    def submit_synthetic_write(self, nbytes: int, imm: Optional[int],
                               dst: MrDesc, on_done: OnDone = None,
                               device: int = 0,
                               on_error: Optional[Callable[[str], None]] = None
                               ) -> None:
        """Timing-only single write (no payload) — cluster-scale benches."""
        src_group = self.groups[device]
        batch = WrBatch(src_group)
        self._add_logical_write(batch, BatchState(1, on_done, on_error),
                                None, dst, 0,
                                imm, stripe=True, synthetic_bytes=nbytes)
        self._enqueue_batch(batch)

    def submit_synthetic_batch(self, writes: Sequence[Tuple],
                               device: int = 0) -> None:
        """Batched timing-only writes: N ``(nbytes, imm, desc, on_done)``
        entries templated into ONE WrBatch / event-loop entry.  An entry may
        carry an optional 5th element ``on_error`` (terminal failure
        callback under fault injection).  Each entry keeps
        ``submit_synthetic_write`` semantics (NIC striping, its own
        immediate and sender-side ``on_done``) — only the submission is
        coalesced, mirroring ``submit_scatters`` for the payload-free path
        used by cluster-scale benches."""
        src_group = self.groups[device]
        if not writes:
            return
        batch = WrBatch(src_group)
        for nbytes, imm, desc, on_done, *rest in writes:
            on_error = rest[0] if rest else None
            self._add_logical_write(batch, BatchState(1, on_done, on_error),
                                    None, desc, 0, imm, stripe=True,
                                    synthetic_bytes=nbytes)
        self._enqueue_batch(batch)

    def submit_barrier(self, dsts: Sequence[MrDesc], imm: int,
                       on_done: OnDone = None, device: int = 0,
                       on_error: Optional[Callable[[str], None]] = None
                       ) -> None:
        """Immediate-only zero-length WRITE to each peer.

        EFA diverges from the RDMA spec and requires a valid descriptor even
        for zero-sized writes — callers must therefore pass real MrDescs.
        """
        src_group = self.groups[device]
        n = len(dsts)
        if n == 0:
            _fire(on_done)
            return
        batch = WrBatch(src_group)
        batch_state = BatchState(n, on_done, on_error)
        n_nics = len(src_group.domains)
        for k, desc in enumerate(dsts):
            self._add_logical_write(batch, batch_state, b"", desc, 0, imm,
                                    stripe=False, nic_rr=k % n_nics)
        self._enqueue_batch(batch)

    # -- UVM watcher -----------------------------------------------------------
    def alloc_uvm_watcher(self, cb: Callable[[int, int], None]) -> UvmWatcher:
        """A :class:`UvmWatcher` for GPU-progress-driven transfers (§3.3)."""
        return UvmWatcher(self.loop, cb)

    # -- stats -------------------------------------------------------------------
    def bytes_sent(self, device: int = 0) -> int:
        """Total payload bytes this device's NICs have transmitted."""
        return sum(d.nic.bytes_sent for d in self.groups[device].domains)

    # -- leak audit --------------------------------------------------------------
    def audit(self) -> Dict[str, object]:
        """Leaked per-engine state at loop-idle: SENDs parked waiting for a
        RECV that was never posted, SEND batches submitted but not yet
        flushed, and unfulfilled ImmCounter expectations (imm, have, need).
        Empty dict = clean.  Aggregated by :meth:`Fabric.audit`."""
        report: Dict[str, object] = {}
        for dev, pend in self._pending_sends.items():
            if pend:
                report[f"pending_sends[{self.node}/{dev}]"] = len(pend)
        for dev, (batch, _t) in self._send_batches.items():
            if len(batch):
                report[f"unflushed_send_batch[{self.node}/{dev}]"] = len(batch)
        for dev, counter in self.counters.items():
            out = counter.outstanding()
            if out:
                report[f"unfulfilled_imms[{self.node}/{dev}]"] = out
        return report


class Fabric:
    """A simulated cluster: nodes x GPUs x NICs sharing one event loop.

    Engines of different NIC kinds may coexist in one fabric (the
    heterogeneous-fabric refactor): the per-fabric :class:`Topology`
    resolves each (src, dst) address pair to its transport — NVLink for
    same-host pairs, the sender's NIC for same-kind pairs, a derived
    cross-fabric preset for mixed-NIC pairs (see ``docs/TOPOLOGY.md``).
    """

    def __init__(self, seed: int = 0):
        self.loop = EventLoop()
        self.seed = seed
        self.topology = Topology()
        self._groups: Dict[NetAddr, Tuple[DomainGroup, TransferEngine]] = {}
        self._peer_groups: List[List[NetAddr]] = []
        self.nic_kinds: set = set()
        # observability (repro.obs): None => every hook is a single guarded
        # attribute check; attach via Tracer(fabric) / attach_tracer,
        # HealthMonitor(fabric) / attach_health, FlightRecorder(fabric) /
        # attach_recorder
        self.tracer = None
        self.health = None
        self.recorder = None
        # fault injection (repro.core.faults): None => post_write's hot path
        # pays one attribute check and nothing else; attach via
        # FaultPlan(fabric, ...) which calls attach_faults
        self.faults = None
        # always-on leak accounting (plain int bumps, no timing impact)
        self.inflight_writes = 0
        self.inflight_sends = 0
        self._auditables: List[Tuple[str, object]] = []

    def add_engine(self, node: str, nic: str = "cx7", num_devices: int = 1,
                   host: Optional[str] = None,
                   nvlink: bool = True) -> TransferEngine:
        """Add one engine (node name, NIC preset, GPU count) to the fabric.

        ``host`` is the physical machine identity used for NVLink pair
        resolution; it defaults to ``node``, so distinct engines stay on
        distinct hosts unless told otherwise.  ``nvlink=False`` pins even
        same-host pairs to the NIC.  The pre-PR one-NIC-kind-per-fabric
        restriction is gone — mixed-kind pairs ride a derived cross-fabric
        cost model (:func:`~repro.core.topology.cross_spec`)."""
        self.nic_kinds.add(nic)
        return TransferEngine(self, node, nic, num_devices,
                              host=host, nvlink=nvlink)

    @staticmethod
    def _addr(a) -> NetAddr:
        """Coerce a NetAddr, a bare node name, or a ``str(NetAddr)``
        rendering (``node/gpuN`` — what observability spans carry)."""
        if not isinstance(a, str):
            return a
        node, sep, dev = a.rpartition("/gpu")
        if sep and dev.isdigit():
            return NetAddr(node, int(dev))
        return NetAddr(a, 0)

    def pair_spec(self, src, dst) -> NicSpec:
        """The per-pair transport spec the ``(src, dst)`` pair rides —
        the NVLink preset, a NIC preset, or a derived cross-fabric spec.

        Accepts ``NetAddr``s, bare node-name strings (device 0), or
        ``node/gpuN`` strings (the span address rendering)."""
        src = self._addr(src)
        dst = self._addr(dst)
        src_group = self.group(src)
        return src_group.domains[0].plan_for(dst).spec

    def degrade_pair(self, src, dst, *, bw_scale: float = 1.0,
                     extra_jitter_us: float = 0.0) -> int:
        """Fault injection: degrade every channel carrying (src, dst)
        traffic (see :func:`repro.core.netsim.degrade`).  Channels are
        created on demand — their CRC-derived seeds are order-independent,
        so pre-creating them here never perturbs a clean run's RNG streams.
        Returns the number of channels degraded."""
        src_addr = self._addr(src)
        dst_addr = self._addr(dst)
        src_group = self.group(src_addr)
        n = 0
        for d in src_group.domains:
            # post_write always selects channel_to(dst, d.index)
            degrade(d.channel_to(dst_addr, d.index),
                    bw_scale=bw_scale, extra_jitter_us=extra_jitter_us)
            n += 1
        return n

    def _register_group(self, addr: NetAddr, group: DomainGroup, engine: TransferEngine) -> None:
        if addr in self._groups:
            raise ValueError(f"duplicate address {addr}")
        self._groups[addr] = (group, engine)
        self.topology.register(addr, TopoEntry(
            host=engine.host, nic=engine.nic_name,
            spec=engine.nic_spec, nvlink=engine.nvlink))
        if self.tracer is not None:
            self._wire_tracer(addr, group, engine)
        if self.health is not None:
            group.health = self.health
        if self.faults is not None:
            group.faults = self.faults

    # -- observability (repro.obs) ----------------------------------------------
    def _wire_tracer(self, addr: NetAddr, group: DomainGroup,
                     engine: TransferEngine) -> None:
        group.tracer = self.tracer
        counter = engine.counters.get(addr.dev)
        if counter is not None:
            counter.tracer = self.tracer
            counter.label = str(addr)

    def attach_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer` (or None to detach): wires
        every existing and future DomainGroup and ImmCounter.  Tracing
        never perturbs simulated time — hooks are pure bookkeeping."""
        self.tracer = tracer
        for addr, (group, engine) in self._groups.items():
            group.tracer = tracer
            counter = engine.counters.get(addr.dev)
            if counter is not None:
                counter.tracer = tracer
                counter.label = str(addr)

    def attach_health(self, monitor) -> None:
        """Attach a :class:`repro.obs.HealthMonitor` (or None to detach):
        wires every existing and future DomainGroup's posting hook.  Like
        the tracer, the monitor never perturbs simulated time — an
        always-on-monitored run is bit-identical to an unmonitored one."""
        self.health = monitor
        for group, _engine in self._groups.values():
            group.health = monitor

    def attach_recorder(self, recorder) -> None:
        """Attach a :class:`repro.obs.FlightRecorder` (or None to detach).
        The recorder is fed by the health monitor's delivery stream and by
        ctrl-plane instants; it dumps its ring on failure paths only."""
        self.recorder = recorder

    def attach_faults(self, plan) -> None:
        """Attach a :class:`repro.core.faults.FaultPlan` (or None to
        detach): wires every existing and future DomainGroup's posting
        path through the plan's WR interception.  An attached plan with no
        injected pairs is bit-identical to no plan at all — it draws no
        RNG and its guard timers cancel without advancing virtual time."""
        self.faults = plan
        for group, _engine in self._groups.values():
            group.faults = plan

    def register_auditable(self, name: str, obj) -> None:
        """Register an object exposing ``audit_leaks() -> dict`` (empty =
        clean) for inclusion in :meth:`audit` — e.g. rlweights pipelines
        reporting unreleased staging reservations."""
        self._auditables.append((name, obj))

    def audit(self) -> Dict[str, object]:
        """Fabric-wide leak report, meaningful at loop-idle: logical
        WRITEs/SENDs without a final delivery, per-engine leftovers
        (parked SENDs, unfulfilled ImmCounter expectations) and registered
        auditables.  ``report["clean"]`` is the single pass/fail bit; see
        :func:`repro.obs.assert_clean` for the test-teardown wrapper."""
        engines: Dict[str, object] = {}
        seen: set = set()
        for addr, (group, engine) in self._groups.items():
            if id(engine) in seen:
                continue
            seen.add(id(engine))
            rep = engine.audit()
            if rep:
                engines[engine.node] = rep
        auditables: Dict[str, object] = {}
        for name, obj in self._auditables:
            rep = obj.audit_leaks()
            if rep:
                auditables[name] = rep
        report: Dict[str, object] = {
            "inflight_writes": self.inflight_writes,
            "inflight_sends": self.inflight_sends,
            "engines": engines,
            "auditables": auditables,
            "pending_events": self.loop.pending,
        }
        report["clean"] = not (self.inflight_writes or self.inflight_sends
                               or engines or auditables)
        return report

    def _lookup(self, addr: NetAddr) -> Tuple[DomainGroup, TransferEngine]:
        return self._groups[addr]

    def group(self, addr: NetAddr) -> DomainGroup:
        """The :class:`DomainGroup` registered at ``addr``."""
        return self._groups[addr][0]

    def _add_peer_group(self, addrs: List[NetAddr]) -> int:
        self._peer_groups.append(addrs)
        return len(self._peer_groups) - 1

    # -- execution helpers -------------------------------------------------------
    def run(self) -> float:
        """Drain the event loop; returns the final virtual time (us)."""
        return self.loop.run_until_idle()

    def run_until(self, pred: Callable[[], bool]) -> float:
        """Run events until ``pred()`` holds; returns the virtual time."""
        return self.loop.run_until(pred)

    @property
    def now(self) -> float:
        """Current virtual time (us)."""
        return self.loop.now
