"""Discrete-event network simulator underpinning the fabric.

Everything in the fabric (``repro.core``) runs in *virtual time* measured in
microseconds.  The simulator is deterministic: given a seed, every run
produces the same event order, which is what the property tests rely on.

The NIC service model is calibrated against Table 2 of the paper:

    service_time(bytes) = fixed_us + bytes * 8e-3 / (bw_gbps * eff)   [us]

with a per-DomainGroup posting-rate cap (``post_us`` per work request) and a
round-trip completion overhead ``rtt_us`` for serially-issued single writes.
With the constants below the simulated Table 2 matches the measured numbers
within ~15% across all message sizes for both EFA and ConnectX-7.
"""

from __future__ import annotations

import heapq
import itertools
import zlib
from dataclasses import dataclass, field
from typing import Callable, List, Optional


def stable_hash(*parts) -> int:
    """Process-stable hash for deriving RNG seeds.

    Python's builtin ``hash()`` randomises str hashing per process
    (PYTHONHASHSEED), which silently broke the simulator's determinism
    guarantee across processes — two identical runs drew different SRD
    jitter.  CRC32 over the repr is stable everywhere.
    """
    return zlib.crc32(repr(parts).encode()) & 0x7FFFFFFF


class EventLoop:
    """Deterministic discrete-event loop (virtual microseconds)."""

    def __init__(self) -> None:
        self._queue: List = []
        self._counter = itertools.count()
        self._cancelled: set = set()
        self.now: float = 0.0
        self._running = False

    def schedule(self, delay_us: float, fn: Callable[[], None]) -> None:
        """Run ``fn`` ``delay_us`` virtual microseconds from now (FIFO at ties)."""
        if delay_us < 0:
            raise ValueError(f"negative delay {delay_us}")
        heapq.heappush(self._queue, (self.now + delay_us, next(self._counter), fn))

    def schedule_at(self, t_us: float, fn: Callable[[], None]) -> None:
        """Run ``fn`` at absolute virtual time ``t_us`` (clamped to now)."""
        self.schedule(max(0.0, t_us - self.now), fn)

    def schedule_cancelable(self, delay_us: float, fn: Callable[[], None]) -> int:
        """Like :meth:`schedule` but returns a handle for :meth:`cancel`.

        Used for guard timers (per-WR delivery timeouts): a cancelled entry
        is skipped when popped WITHOUT advancing ``now``, so an armed-then-
        cancelled timer never inflates the run's final virtual time — a
        fault-plan run whose timers all cancel ends at the same ``now`` as
        one that never armed them.
        """
        if delay_us < 0:
            raise ValueError(f"negative delay {delay_us}")
        seq = next(self._counter)
        heapq.heappush(self._queue, (self.now + delay_us, seq, fn))
        return seq

    def cancel(self, handle: int) -> None:
        """Cancel a handle from :meth:`schedule_cancelable` (lazy removal)."""
        self._cancelled.add(handle)

    def run_until_idle(self, max_events: int = 10_000_000) -> float:
        """Run until no events remain.  Returns the final virtual time."""
        n = 0
        while self._queue:
            t, seq, fn = heapq.heappop(self._queue)
            if self._cancelled and seq in self._cancelled:
                self._cancelled.discard(seq)
                continue
            self.now = max(self.now, t)
            fn()
            n += 1
            if n > max_events:
                raise RuntimeError("event loop runaway (possible livelock)")
        return self.now

    def run_until(self, pred: Callable[[], bool], max_events: int = 10_000_000) -> float:
        """Run until ``pred()`` is true (checked after each event)."""
        n = 0
        while self._queue and not pred():
            t, seq, fn = heapq.heappop(self._queue)
            if self._cancelled and seq in self._cancelled:
                self._cancelled.discard(seq)
                continue
            self.now = max(self.now, t)
            fn()
            n += 1
            if n > max_events:
                raise RuntimeError("event loop runaway (possible livelock)")
        if not pred():
            raise RuntimeError("event queue drained before predicate held")
        return self.now

    @property
    def pending(self) -> int:
        """Number of not-yet-run events in the queue (cancelled excluded)."""
        return len(self._queue) - len(self._cancelled)


@dataclass(frozen=True)
class NicSpec:
    """Static description of one NIC's performance envelope."""

    name: str
    bw_gbps: float            # line rate of this NIC
    base_latency_us: float    # one-way wire latency
    rtt_us: float             # submit->sender-completion overhead (single write)
    fixed_us: float           # per-op fixed service time on the NIC
    eff: float                # achievable fraction of line rate
    mtu_bytes: int            # max transfer unit for chunking
    ordered: bool             # True => RC-style in-order delivery
    srd_jitter_us: float = 0.0  # delivery jitter for unordered transports

    def service_us(self, nbytes: int) -> float:
        """NIC service time for one op: fixed cost + wire time (Table 2)."""
        return self.fixed_us + nbytes * 8e-3 / (self.bw_gbps * self.eff)


# Calibrated against Table 2 (see module docstring).
CX7 = NicSpec(
    name="cx7", bw_gbps=400.0, base_latency_us=2.5, rtt_us=10.5,
    fixed_us=0.04, eff=0.95, mtu_bytes=4096, ordered=True,
)
# One EFA adapter on a p5en instance (2 x 200 Gbps per GPU).
EFA_200 = NicSpec(
    name="efa200", bw_gbps=200.0, base_latency_us=15.0, rtt_us=31.0,
    fixed_us=0.476, eff=1.0, mtu_bytes=8928, ordered=False, srd_jitter_us=2.0,
)
# One EFA adapter on a p5 instance (4 x 100 Gbps per GPU).
EFA_100 = NicSpec(
    name="efa100", bw_gbps=100.0, base_latency_us=15.0, rtt_us=31.0,
    fixed_us=0.476, eff=1.0, mtu_bytes=8928, ordered=False, srd_jitter_us=2.0,
)

# Intra-node fast path (paper §6 uses NVLink for same-node peers).
NVLINK = NicSpec(
    name="nvlink", bw_gbps=3600.0, base_latency_us=0.3, rtt_us=1.0,
    fixed_us=0.5, eff=0.9, mtu_bytes=1 << 20, ordered=True,
)

# Per-DomainGroup work-request posting overhead (Table 8/9): the host proxy
# posts WRITEs one by one; this is the per-WR CPU cost.
POST_US = {"cx7": 0.09, "efa200": 0.476, "efa100": 0.476, "nvlink": 0.09}

# PCIe/GDRCopy polling latency for the UVM watcher (Table 4: 2.5-6.3 us).
PCIE_POLL_US = 3.0
# App -> worker-thread enqueue latency (Table 8: ~0.98 us p50 combined).
ENQUEUE_US = 0.98


class NicQueue:
    """A single NIC's serialised send pipeline.

    Work requests are served FIFO; the queue tracks ``busy_until`` so that
    back-to-back posts pipeline (throughput = 1/service_time) while an idle
    NIC adds only its own service time.
    """

    def __init__(self, loop: EventLoop, spec: NicSpec):
        self.loop = loop
        self.spec = spec
        self.busy_until = 0.0
        self.bytes_sent = 0
        self.ops_sent = 0
        # fault injection: <1.0 slows every op on this NIC (whole-NIC
        # degradation); per-channel degradation rides the submit() svc_scale
        self.bw_scale = 1.0

    def backlog_us(self, now: float) -> float:
        """Queued-but-unserialised service time at ``now`` (µs) — the
        queue-occupancy gauge sampled by ``repro.obs``: 0 when idle."""
        return max(0.0, self.busy_until - now)

    def submit(self, nbytes: int, on_wire: Callable[[float], None],
               charge_fixed: bool = True, svc_scale: float = 1.0) -> float:
        """Queue ``nbytes`` for transmission.

        ``on_wire(t_delivered)`` is invoked (scheduled) for the time the last
        byte arrives at the remote NIC.  Returns the local send-completion
        time (used for sender-side CQEs).  ``charge_fixed=False`` skips the
        per-op fixed cost (continuation chunks of one WRITE: the NIC charges
        per work request, not per wire packet).  ``svc_scale`` multiplies the
        per-byte serialisation cost (fault injection: a degraded channel
        passes >1.0); the per-op fixed cost is never scaled.
        """
        start = max(self.loop.now, self.busy_until)
        svc = nbytes * 8e-3 / (self.spec.bw_gbps * self.spec.eff)
        scale = svc_scale / self.bw_scale
        if scale != 1.0:
            # guarded so the clean path computes the bit-identical float
            svc *= scale
        if charge_fixed:
            svc += self.spec.fixed_us
        done_tx = start + svc
        self.busy_until = done_tx
        self.bytes_sent += nbytes
        self.ops_sent += 1
        arrive = done_tx + self.spec.base_latency_us
        on_wire(arrive)
        return done_tx


def degrade(channel, bw_scale: float = 1.0, extra_jitter_us: float = 0.0) -> None:
    """Fault injection: degrade one transport channel in place.

    ``bw_scale`` < 1.0 scales the channel's effective bandwidth down (its
    per-byte serialisation cost is multiplied by ``1/bw_scale``; the per-op
    fixed cost and other channels sharing the same NIC queue are untouched,
    so injected faults stay attributable to one (src, dst) pair).
    ``extra_jitter_us`` adds deterministic pseudo-random delivery jitter on
    top of the transport's own (RC channels, normally jitter-free, start
    drawing from their seeded RNG only once this is non-zero — a clean
    fabric's RNG stream is bit-identical to one that never imported this).

    Duck-typed on :class:`repro.core.transport.Channel` to avoid an import
    cycle; ``Fabric.degrade_pair`` applies it to every channel of a pair.
    """
    if bw_scale <= 0.0:
        raise ValueError(f"bw_scale must be > 0, got {bw_scale}")
    channel.svc_scale = 1.0 / bw_scale
    channel.extra_jitter_us = float(extra_jitter_us)
