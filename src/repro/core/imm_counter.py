"""ImmCounter: order-agnostic completion notification (paper §3.3).

Per-immediate counters are incremented on completion-queue events.  The key
property — proven by the hypothesis tests — is that correctness never
depends on delivery *order*: a consumer registers ``expect_imm_count(imm,
count, cb)`` and the callback fires exactly when ``count`` WRITEIMM payloads
carrying ``imm`` have *fully landed*, no matter how the transport permuted
them.

Counters can be observed three ways, mirroring the paper: a callback
(dedicated thread in the paper, event-loop continuation here), an atomic
flag (``wait()`` polling), or direct inspection (GDRCopy-style).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class ImmCounter:
    """Per-immediate completion counters with threshold callbacks (§3.3)."""

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        # imm -> list of (threshold, callback, fired?)
        self._watchers: Dict[int, List[List]] = {}
        self.events: List[Tuple[float, int]] = []  # (time, imm) audit trail
        # observability (repro.obs): set by Fabric.attach_tracer
        self.tracer = None
        self.label = ""

    def expect(self, imm: int, count: int, cb: Callable[[], None]) -> None:
        """Fire ``cb`` once, when ``imm``'s counter reaches ``count``."""
        if count <= 0:
            cb()
            return
        w = [count, cb, False]
        self._watchers.setdefault(imm, []).append(w)
        self._maybe_fire(imm)

    def increment(self, imm: int, now: float, by: int = 1) -> None:
        """Count a landed WRITEIMM (transport-side; logs to the audit trail)."""
        self.counts[imm] = self.counts.get(imm, 0) + by
        self.events.append((now, imm))
        self._maybe_fire(imm)

    def value(self, imm: int) -> int:
        """Current count for ``imm`` (GDRCopy-style direct inspection)."""
        return self.counts.get(imm, 0)

    def reset(self, imm: int) -> None:
        """Drop ``imm``'s counter and watchers (reuse across protocol rounds)."""
        self.counts.pop(imm, None)
        self._watchers.pop(imm, None)

    def outstanding(self) -> List[Tuple[int, int, int]]:
        """Unfired watcher expectations as ``(imm, have, need)`` triples —
        the leak-audit view: non-empty at loop-idle means a protocol armed
        an expectation whose WRITEs never all landed."""
        return [(imm, self.counts.get(imm, 0), w[0])
                for imm, ws in self._watchers.items()
                for w in ws if not w[2]]

    def _maybe_fire(self, imm: int) -> None:
        have = self.counts.get(imm, 0)
        for w in self._watchers.get(imm, []):
            if not w[2] and have >= w[0]:
                w[2] = True
                if self.tracer is not None:
                    self.tracer.instant(
                        "imm", f"{self.label} imm={imm:#x}",
                        {"have": have, "need": w[0]})
                w[1]()
