"""fabric-lib core: portable point-to-point communication (paper §3).

The simulated-fabric reproduction of the TransferEngine: reliable-but-
unordered transports (RC/SRD), multi-NIC DomainGroups, the Fig. 2 API and
the ImmCounter completion primitive.
"""

from .domain import (MrDesc, MrHandle, NetAddr, Pages, PayloadDst,
                     ScatterDst, WrBatch)
from .engine import (BatchState, BatchStats, Fabric, Flag, TransferEngine,
                     WriteState, NIC_PRESETS)
from .faults import BackpressureError, FaultPlan, TransferError
from .imm_counter import ImmCounter
from .netsim import CX7, EFA_100, EFA_200, NVLINK, EventLoop, NicSpec
from .topology import ChannelPlan, TopoEntry, Topology, cross_spec
from .uvm import UvmWatcher

__all__ = [
    "Fabric", "TransferEngine", "Flag", "NIC_PRESETS",
    "MrDesc", "MrHandle", "NetAddr", "Pages", "PayloadDst", "ScatterDst",
    "WrBatch", "BatchState", "BatchStats", "WriteState",
    "FaultPlan", "TransferError", "BackpressureError",
    "ImmCounter", "UvmWatcher",
    "EventLoop", "NicSpec", "CX7", "EFA_100", "EFA_200", "NVLINK",
    "Topology", "TopoEntry", "ChannelPlan", "cross_spec",
]
