"""Transport semantics: reliable-ordered (RC) vs reliable-unordered (SRD).

The paper's key insight is that ConnectX RC and AWS EFA SRD share *reliable
but unordered* delivery as a common denominator (Table 1).  We model both:

* ``RC``    — reliable, in-order per queue pair (ConnectX).  fabric-lib
              deliberately IGNORES the ordering guarantee.
* ``SRD``   — reliable, connectionless, out-of-order (EFA).  Per-packet
              delivery times receive deterministic pseudo-random jitter, so
              packets of different WRITEs (and chunks of one WRITE) arrive
              in a permuted order.

Atomicity contract (paper §3.3 "Completion Notification"): the CQE carrying
the immediate value of a WRITEIMM is raised only after the *entire* payload
of that WRITE is visible in the destination buffer — regardless of the
ordering of other in-flight WRITEs.  The simulator enforces exactly this and
nothing more, which is what the property tests probe.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from .netsim import EventLoop, NicQueue, NicSpec


@dataclass
class WireOp:
    """One WRITE (or SEND) as it crosses the wire.

    ``payload`` is any buffer-protocol object (``memoryview`` on the batch
    path, ``bytes`` for SEND) snapshotting the source at post time; the
    channel slices it zero-copy per MTU chunk."""

    kind: str                      # "write" | "send" | "barrier"
    payload: Optional[object]      # snapshot of the source bytes (None for 0-size)
    dst_region: Optional[object]   # resolved on the receiver (MemoryRegion)
    dst_offset: int
    imm: Optional[int]
    on_delivered: Callable[["WireOp", float], None]  # receiver-side hook
    on_sent: Optional[Callable[[float], None]] = None  # sender-side CQE hook
    nbytes: int = 0
    # observability (repro.obs): lifecycle span stamped by the transport
    # hooks when a tracer is attached; None => hooks are no-ops
    span: Optional[object] = None
    # terminal failure hook (repro.core.faults): invoked as
    # ``on_error(op, reason)`` when the retry budget is exhausted or the
    # peer dies; None on SENDs and on fabrics without a FaultPlan
    on_error: Optional[Callable[["WireOp", str], None]] = None
    # epoch fencing (repro.ctrl zombie-writer guard): a WRITE stamped with
    # the sender's view epoch is rejected — bytes never written, the
    # ``on_fenced`` hook fires instead of ``on_delivered`` — when the
    # receiving engine's fence table holds a higher epoch for ``src_node``.
    # All None/default => the check compiles to one ``is not None`` test.
    fence_epoch: Optional[int] = None
    src_node: str = ""
    fences: Optional[dict] = None   # live ref: receiving engine's fence table
    on_fenced: Optional[Callable[["WireOp", float], None]] = None


class Channel:
    """A unidirectional transport channel between two Domains over one NIC.

    Chunks ops to the NIC MTU, applies transport ordering semantics, and
    delivers payload bytes into the destination memory region at the
    simulated arrival time.  The immediate/CQE for an op fires when its last
    chunk has been delivered (RDMA spec: payload before immediate).
    """

    def __init__(self, loop: EventLoop, nic: NicQueue, seed: int,
                 ordered: Optional[bool] = None, label: str = ""):
        self.loop = loop
        self.nic = nic
        self.spec = nic.spec
        self.ordered = self.spec.ordered if ordered is None else ordered
        self.rng = np.random.default_rng(seed)
        self._last_delivery = 0.0  # for RC in-order enforcement
        self.label = label         # queue/track name for trace export
        # fault injection (repro.core.netsim.degrade): per-channel service
        # scaling and added delivery jitter; defaults are bit-identical to
        # the un-injectable channel
        self.svc_scale = 1.0
        self.extra_jitter_us = 0.0

    MAX_CHUNKS = 64  # coarse chunking: bounds event count for GB-scale writes

    def post(self, op: WireOp) -> None:
        """Submit one WireOp: MTU-chunk, queue on the NIC, deliver with the
        transport's ordering contract (RC collapse vs per-chunk SRD jitter)."""
        sp = op.span
        if sp is not None:
            # queue wait ends when the NIC starts serialising this op
            sp.t_wire = max(self.loop.now, self.nic.busy_until)
        if self.ordered:
            return self._post_ordered(op)
        nbytes = op.nbytes
        mtu = self.spec.mtu_bytes
        nchunks = min(max(1, (nbytes + mtu - 1) // mtu), self.MAX_CHUNKS)
        per = -(-max(nbytes, 1) // nchunks)
        remaining = [nchunks]  # chunks not yet delivered
        last_tx = 0.0
        # memoryview so per-chunk slices below are zero-copy even when the
        # submitter handed us plain bytes
        payload = memoryview(op.payload) if op.payload is not None else None

        def deliver_chunk(idx: int, arrive: float) -> None:
            if self.ordered:
                # RC: monotonic delivery per channel.
                arrive = max(arrive, self._last_delivery)
                self._last_delivery = arrive
            else:
                # SRD: deterministic pseudo-random reordering jitter.  When
                # MAX_CHUNKS makes a coarse chunk span several wire packets
                # (GB-scale writes), the chunk is only fully visible once its
                # slowest packet lands — draw per-packet jitter and take the
                # max, instead of pretending the whole span is one packet.
                # Single-packet chunks keep the exact scalar draw (bit-
                # identical RNG stream for every sub-571KB EFA write).
                lo_ = idx * per
                npkt = max(1, (min(nbytes, lo_ + per) - lo_ + mtu - 1) // mtu)
                jit = self.spec.srd_jitter_us + self.extra_jitter_us
                if npkt == 1:
                    arrive = arrive + float(self.rng.uniform(0.0, jit))
                else:
                    # max of npkt iid U(0, j) via inverse CDF — one draw,
                    # same distribution, O(1) for millions of packets
                    arrive = arrive + jit * float(
                        self.rng.random()) ** (1.0 / npkt)

            def land() -> None:
                # Epoch fence (zombie-writer guard): evaluated per chunk —
                # fences only tighten monotonically, so once any chunk sees
                # the sender fenced, every later chunk does too and the
                # terminal callback decision is consistent at the last one.
                fenced = (op.fences is not None and op.fence_epoch
                          < op.fences.get(op.src_node, op.fence_epoch))
                if not fenced and payload is not None \
                        and op.dst_region is not None:
                    lo = idx * per
                    hi = min(nbytes, lo + per)
                    if hi > lo:
                        op.dst_region.write_bytes(op.dst_offset + lo, payload[lo:hi])
                remaining[0] -= 1
                if remaining[0] == 0:
                    # Entire payload visible => CQE/immediate may fire.
                    if op.span is not None:
                        op.span.t_deliver = self.loop.now
                    if fenced and op.on_fenced is not None:
                        op.on_fenced(op, self.loop.now)
                    else:
                        op.on_delivered(op, self.loop.now)

            self.loop.schedule_at(arrive, land)

        for i in range(nchunks):
            lo = i * per
            hi = min(nbytes, lo + per) if nbytes else 0
            sz = max(0, hi - lo)
            # Zero-size barrier writes still consume a descriptor (the paper
            # notes EFA requires a valid descriptor even for imm-only writes).
            # Per-op fixed cost is charged once (first chunk only).
            tx_done = self.nic.submit(max(sz, 1),
                                      lambda arrive, i=i: deliver_chunk(i, arrive),
                                      charge_fixed=(i == 0),
                                      svc_scale=self.svc_scale)
            last_tx = max(last_tx, tx_done)

        if op.on_sent is not None:
            # Sender-side completion: after the NIC has serialised everything
            # plus the transport's completion round trip (ack).
            self.loop.schedule_at(last_tx + self.spec.rtt_us, lambda: op.on_sent(self.loop.now))

    def _post_ordered(self, op: WireOp) -> None:
        """RC fast path: ONE delivery event per op instead of one per MTU
        chunk.  Timing-exact with the chunked path — chunks of one op
        pipeline back-to-back on the same NIC queue (per-op fixed cost
        charged once), so the last chunk's arrival equals the whole
        payload's service time plus wire latency; in-order delivery means
        no earlier chunk is ever observable before the op completes, and RC
        draws no jitter.  Collapsing the per-chunk events bounds simulator
        wall-clock for MB-scale WRITEs (the MoE decode hot path posts
        hundreds of them per round)."""
        nbytes = op.nbytes

        def deliver(arrive: float) -> None:
            if self.extra_jitter_us > 0.0:
                # fault injection only: a clean RC channel draws no RNG
                arrive = arrive + float(self.rng.uniform(0.0, self.extra_jitter_us))
            arrive = max(arrive, self._last_delivery)
            self._last_delivery = arrive

            def land() -> None:
                # Epoch fence (zombie-writer guard) — see the unordered path
                fenced = (op.fences is not None and op.fence_epoch
                          < op.fences.get(op.src_node, op.fence_epoch))
                if not fenced and op.payload is not None \
                        and op.dst_region is not None and nbytes:
                    op.dst_region.write_bytes(op.dst_offset,
                                              memoryview(op.payload)[:nbytes])
                if op.span is not None:
                    op.span.t_deliver = self.loop.now
                if fenced and op.on_fenced is not None:
                    op.on_fenced(op, self.loop.now)
                else:
                    op.on_delivered(op, self.loop.now)

            self.loop.schedule_at(arrive, land)

        tx_done = self.nic.submit(max(nbytes, 1), deliver,
                                  svc_scale=self.svc_scale)
        if op.on_sent is not None:
            self.loop.schedule_at(tx_done + self.spec.rtt_us,
                                  lambda: op.on_sent(self.loop.now))
