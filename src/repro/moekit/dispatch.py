"""Host-proxy MoE dispatch/combine over the TransferEngine (paper §6).

Low-latency decode fast path.  Protocol per rank and MoE layer invocation:

  dispatch (two phases, ONE WrBatch enqueue each):
    1. count tokens per expert (GPU kernel; modeled as KERNEL_LAUNCH_US)
    2. phase 1 — scatter ROUTES (the (E,) per-expert counts) to every peer
       and speculatively scatter the first ``t_priv`` tokens per destination
       into private per-source buffers (hides route latency — Fig. 11)
    3. once all peers' routes arrive (ImmCounter), phase 2 — coalesce ALL
       remaining tokens for receiver ``r`` into ONE contiguous WRITE landing
       in r's per-source shared region (source-major layout)
    4. receiver completion = ImmCounter over token writes; the grouped-GEMM
       layout is recovered from the exchanged routes ALONE (no peeking at
       peer state) as a route-derived permutation executed by a single
       fancy-index gather (``repro.kernels.ops.moe_pack_host``)
    => at most TWO data WRITEs per inter-node peer per round (private +
       shared), plus the route write — the paper's §6 bound, honestly.

  combine:
    expert outputs are returned with a SINGLE zero-copy scatter per source:
    a route-derived permutation packs them (source-major) and the per-source
    row slices ride as ``PayloadDst`` gather-into-snapshot payloads (no
    staging copy).  Each source un-permutes and reduces with its gates in
    fp32 via ``repro.kernels.ops.moe_combine_host``.

Offsets are derived on BOTH sides purely from ``routes_buf``: endpoints
exchange only :class:`PeerPorts` (rank + MrDescs), so no endpoint can read
another endpoint's context or buffers except through posted WRITEs.

Payload bytes move for real; tests validate the packed layout and the
combined output against a dense oracle.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import (Fabric, MrDesc, PayloadDst, ScatterDst, TransferEngine,
                    TransferError)
from ..obs import traced_phase

KERNEL_LAUNCH_US = 15.0      # launch -> first transfer (paper §6.2)
ROUTE_PROC_US = 20.0         # host-side route processing before the second
                             # round of transfers ("tens of microseconds",
                             # §6.2) — the latency the private buffers hide
ROUTE_IMM = 0x520
TOK_IMM = 0x521
COMB_IMM = 0x522
BARRIER_IMM = 0x523


class DispatchError(TransferError):
    """A MoE dispatch/combine WRITE exhausted its retry budget (dead or
    unreachable peer).  Raised out of ``fabric.run()`` — instead of the
    round silently hanging on an ImmCounter that can never fire — after
    the endpoint's round state has been cleaned up via
    :meth:`MoEEndpoint.abort_round`."""

    def __init__(self, rank: int, round_id: int, reason: str):
        super().__init__(
            f"moe rank{rank} round {round_id} dispatch failed: {reason}")
        self.rank = rank
        self.round_id = round_id
        self.reason = reason


def multi_arange(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenated ``[starts[i], starts[i]+counts[i])`` ranges, vectorised
    (the route-derived permutations below are built from these)."""
    counts = np.asarray(counts, np.int64).reshape(-1)
    starts = np.asarray(starts, np.int64).reshape(-1)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    ends = np.cumsum(counts)
    idx = np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)
    return idx + np.repeat(starts, counts)


@dataclass
class MoEConfig:
    n_ranks: int
    n_experts: int             # global
    top_k: int
    max_tokens: int            # T per rank
    token_bytes: int           # payload bytes per token (e.g. 7168 fp8)
    t_priv: int = 32           # private-buffer tokens per (src, dst) pair

    @property
    def e_local(self) -> int:
        return self.n_experts // self.n_ranks

    @property
    def src_region_tokens(self) -> int:
        # paper bound (§6.1): one source contributes at most
        # T * min(top_k, E/N) token copies to one receiver
        return self.max_tokens * min(self.top_k, self.e_local)

    @property
    def recv_cap(self) -> int:
        # total tokens that can land on a rank across all sources
        return self.n_ranks * self.src_region_tokens


@dataclass(frozen=True)
class PeerPorts:
    """Everything an endpoint may know about a peer: its rank and the
    MrDescs of its receive windows.  Serializable — in a real deployment
    this is the JOIN payload.  All placement offsets are derived from the
    exchanged routes, never from peer-side state."""

    rank: int
    d_routes: MrDesc
    d_priv: MrDesc
    d_shared: MrDesc
    d_comb: MrDesc


class MoEEndpoint:
    """One expert-parallel rank: buffers + proxy logic."""

    def __init__(self, fabric: Fabric, cfg: MoEConfig, rank: int,
                 engine: TransferEngine):
        self.fabric = fabric
        self.cfg = cfg
        self.rank = rank
        self.engine = engine
        tb, N, T = cfg.token_bytes, cfg.n_ranks, cfg.max_tokens
        # One backing allocation for both receive windows so the receiver
        # shuffle is a SINGLE fancy-index gather over its row view:
        #   rows [0, N*t_priv)                 — private per-source regions
        #   rows [N*t_priv, +N*src_region)     — shared  per-source regions
        self._n_priv_rows = N * cfg.t_priv
        self._n_shared_rows = N * cfg.src_region_tokens
        self.recv_buf = np.zeros((self._n_priv_rows + self._n_shared_rows) * tb,
                                 np.uint8)
        self.priv_buf = self.recv_buf[:self._n_priv_rows * tb]
        self.shared_buf = self.recv_buf[self._n_priv_rows * tb:]
        self.routes_buf = np.zeros(N * cfg.n_experts * 4, np.uint8)
        self.comb_buf = np.zeros(T * cfg.top_k * tb, np.uint8)
        self.h_routes, self.d_routes = engine.reg_mr(self.routes_buf)
        self.h_priv, self.d_priv = engine.reg_mr(self.priv_buf)
        self.h_shared, self.d_shared = engine.reg_mr(self.shared_buf)
        self.h_comb, self.d_comb = engine.reg_mr(self.comb_buf)
        # tiny staging region for the route counts (token payloads ride
        # PayloadDst gather-into-snapshot — no send staging at all)
        self.route_send = np.zeros(cfg.n_experts * 4, np.uint8)
        self.h_route_send, _ = engine.reg_mr(self.route_send)
        self.ports: List[PeerPorts] = []
        self.stats: Dict[str, float] = {}
        self.round = 0          # per-layer round: scopes imm values

    # -- wiring ------------------------------------------------------------
    def port(self) -> PeerPorts:
        return PeerPorts(rank=self.rank, d_routes=self.d_routes,
                         d_priv=self.d_priv, d_shared=self.d_shared,
                         d_comb=self.d_comb)

    def connect(self, ports: List[PeerPorts]) -> None:
        if [p.rank for p in ports] != list(range(self.cfg.n_ranks)):
            raise ValueError("ports must cover ranks 0..N-1 in order")
        self.ports = ports

    # -- fault cleanup ------------------------------------------------------
    def abort_round(self) -> None:
        """Drop the current round's immediate expectations (route, token
        and combine counters) so a failed round leaves no unfulfilled
        watchers behind — ``Fabric.audit()`` stays clean and the next
        round's (round-scoped) immediates start fresh."""
        ctr = self.engine.counters[0]
        for base in (ROUTE_IMM, TOK_IMM, COMB_IMM):
            ctr.reset(base + (self.round << 8))

    def _fail(self, ctx: Dict, phase: str, reason: str,
              on_error: Optional[Callable[["DispatchError"], None]]) -> None:
        if ctx.get("failed"):
            return               # sibling WRITE of the same round already did
        ctx["failed"] = True
        self.stats["failures"] = self.stats.get("failures", 0) + 1
        self.abort_round()
        err = DispatchError(self.rank, self.round, f"{phase}: {reason}")
        if on_error is not None:
            on_error(err)
            return
        raise err

    # -- dispatch ------------------------------------------------------------
    def dispatch(self, tokens: np.ndarray, eids: np.ndarray,
                 on_complete: Callable[[], None],
                 on_error: Optional[Callable[["DispatchError"], None]] = None
                 ) -> Dict:
        """tokens: (T, token_bytes) uint8; eids: (T, top_k) int32 global ids.

        Returns a context dict used later by combine; ``on_complete`` fires
        when this rank has received ALL tokens routed to its local experts
        (and can run its grouped GEMM).  Under fault injection a WRITE that
        exhausts its retry budget (e.g. a dead peer) aborts the round:
        expectations are reset (:meth:`abort_round`) and a
        :class:`DispatchError` is raised out of ``fabric.run()`` — or
        handed to ``on_error`` when provided."""
        cfg = self.cfg
        N, E, R = cfg.n_ranks, cfg.n_experts, cfg.top_k
        T = tokens.shape[0]
        t0 = self.fabric.now
        self.round += 1
        route_imm = ROUTE_IMM + (self.round << 8)
        tok_imm = TOK_IMM + (self.round << 8)

        # Top-k slots must be distinct experts: the per-source shared
        # regions are sized T * min(top_k, E/N), which duplicate slots
        # overflow (silently corrupting a neighbour region mid-round).
        if T:
            es = np.sort(eids, axis=1)
            if (es[:, 1:] == es[:, :-1]).any():
                raise ValueError("eids rows must hold distinct experts")

        # 1. per-expert counts
        counts = np.bincount(eids.reshape(-1), minlength=E).astype(np.int32)

        # flat assignment list in (expert, token) order
        fe = eids.reshape(-1)
        ft = np.repeat(np.arange(T), R)
        order = np.lexsort((ft, fe))            # stable by expert then token
        fe_s, ft_s = fe[order], ft[order]
        dest = fe_s // cfg.e_local

        ctx = {"counts": counts, "order": order, "eids": eids,
               "fe_s": fe_s, "ft_s": ft_s, "t0": t0, "T": T, "sent_at": None}

        def proxy_phase1() -> None:
            # 2. routes to all peers (small payload, all NICs)
            self.route_send.view(np.int32)[:] = counts
            route_dsts = [ScatterDst(len=E * 4, src=0,
                                     dst=(p.d_routes, self.rank * E * 4))
                          for p in self.ports]

            # 3. speculative private-buffer tokens (first t_priv per dest).
            # Gather-into-snapshot fast path: ONE vectorised fancy-index
            # gather per destination produces the contiguous payload that
            # IS the submission snapshot — no staging copy, no second
            # snapshot copy.
            tb = cfg.token_bytes
            priv_dsts = []
            for r in range(N):
                take = np.nonzero(dest == r)[0][:cfg.t_priv]
                if take.size == 0:
                    continue
                priv_dsts.append(PayloadDst(
                    payload=tokens[ft_s[take]].reshape(-1),
                    dst=(self.ports[r].d_priv, self.rank * cfg.t_priv * tb)))
            # routes + private tokens ride ONE WrBatch (one proxy handoff);
            # each keeps its own imm so completion accounting is unchanged
            xerr = (lambda reason: self._fail(ctx, "dispatch.p1", reason,
                                              on_error))
            with traced_phase(self.fabric, "moe.dispatch.p1"):
                self.engine.submit_scatters([
                    (self.h_route_send, route_dsts, route_imm, None, xerr),
                    (None, priv_dsts, tok_imm, None, xerr),
                ])

        tr = self.fabric.tracer
        if tr is not None:
            tr.compute_span(f"rank{self.rank} gpu", "kernel_launch",
                            t0, t0 + KERNEL_LAUNCH_US, phase="moe.dispatch")
        self.fabric.loop.schedule(KERNEL_LAUNCH_US, proxy_phase1)

        # 4. wait for ALL routes, then ship every receiver its residual
        # tokens as ONE contiguous WRITE into its per-source shared region
        def on_routes() -> None:
            tr = self.fabric.tracer
            if tr is not None:
                now = self.fabric.now
                tr.compute_span(f"rank{self.rank} proxy", "route_proc",
                                now, now + ROUTE_PROC_US,
                                phase="moe.dispatch")
            self.fabric.loop.schedule(ROUTE_PROC_US, lambda: process_routes())

        def process_routes() -> None:
            all_counts = self.routes_buf.view(np.int32).reshape(N, E)
            ctx["all_counts"] = all_counts.copy()
            tb = cfg.token_bytes
            shared_dsts = []
            for r in range(N):
                rest = np.nonzero(dest == r)[0][cfg.t_priv:]
                if rest.size == 0:
                    continue
                # `rest` is expert-sorted; the receiver reconstructs the
                # (expert, source-order) sub-layout from the routes alone.
                shared_dsts.append(PayloadDst(
                    payload=tokens[ft_s[rest]].reshape(-1),
                    dst=(self.ports[r].d_shared,
                         self.rank * cfg.src_region_tokens * tb)))
            if shared_dsts:
                with traced_phase(self.fabric, "moe.dispatch.p2"):
                    self.engine.submit_scatters(
                        [(None, shared_dsts, tok_imm,
                          lambda: ctx.__setitem__("sent_at", self.fabric.now),
                          lambda reason: self._fail(ctx, "dispatch.p2",
                                                    reason, on_error))])
            else:
                ctx["sent_at"] = self.fabric.now

            # receiver completion: expected #token WRITEs to me — at most
            # TWO per source (one private, one shared), derived from the
            # exchanged routes alone.
            e0 = self.rank * cfg.e_local
            my_counts = all_counts[:, e0:e0 + cfg.e_local]
            per_src = my_counts.sum(1)
            n_writes = int((np.minimum(per_src, cfg.t_priv) > 0).sum()) + \
                int((per_src > cfg.t_priv).sum())
            ctx["my_counts"] = my_counts.copy()

            def tokens_done() -> None:
                self.stats["dispatch_us"] = self.fabric.now - t0
                on_complete()

            self.engine.expect_imm_count(tok_imm, n_writes, tokens_done)

        self.engine.expect_imm_count(route_imm, N, on_routes)
        return ctx

    # -- receiver shuffle --------------------------------------------------------
    def _recv_layout(self, my_counts: np.ndarray):
        """Route-derived receive layout: per (source, local expert), how many
        rows sit in the private region vs the shared region, and where."""
        cfg = self.cfg
        my = my_counts.astype(np.int64)                    # (N, e_local)
        cum = np.cumsum(my, axis=1)
        before = cum - my                                  # prefix per (s, e)
        n_priv = np.clip(cfg.t_priv - before, 0, my)       # private rows
        n_resid = my - n_priv                              # shared rows
        resid_before = np.cumsum(n_resid, axis=1) - n_resid
        return before, n_priv, n_resid, resid_before

    def gather_expert_tokens(self, ctx: Dict) -> List[np.ndarray]:
        """Shuffle received bytes into per-local-expert dense slabs (the
        paper's receiver half feeding the Grouped GEMM): a route-derived
        permutation over the receive rows, executed as ONE fancy-index
        gather (``kernels.ops.moe_pack_host`` — Pallas on TPU, numpy ref
        fallback on CPU)."""
        from ..kernels.host import moe_pack_host
        cfg = self.cfg
        tb = cfg.token_bytes
        N = cfg.n_ranks
        my = ctx["my_counts"].astype(np.int64)             # (N, e_local)
        before, n_priv, n_resid, resid_before = self._recv_layout(my)
        srt = cfg.src_region_tokens
        src_ids = np.arange(N, dtype=np.int64)
        perms, sizes = [], []
        for e_loc in range(cfg.e_local):
            # rows for (s, e): private prefix then shared residuals, sources
            # ascending — exactly the order the senders packed them in
            starts = np.stack([
                src_ids * cfg.t_priv + before[:, e_loc],
                self._n_priv_rows + src_ids * srt + resid_before[:, e_loc],
            ], axis=1)                                     # (N, 2)
            cnts = np.stack([n_priv[:, e_loc], n_resid[:, e_loc]], axis=1)
            perms.append(multi_arange(starts, cnts))
            sizes.append(int(my[:, e_loc].sum()))
        perm = np.concatenate(perms) if perms else np.empty(0, np.int64)
        rows = self.recv_buf.reshape(-1, tb)
        packed = moe_pack_host(rows, perm)
        splits = np.cumsum(sizes)[:-1]
        return [np.ascontiguousarray(s) for s in np.split(packed, splits)]

    # -- combine ----------------------------------------------------------------
    def combine(self, ctx: Dict, expert_out: List[np.ndarray],
                on_complete: Callable[[], None],
                on_error: Optional[Callable[["DispatchError"], None]] = None
                ) -> None:
        """Send processed tokens back to their sources: ONE zero-copy
        scatter (a single WrBatch enqueue, one WRITE per source).  Fault
        handling mirrors :meth:`dispatch` — retry-budget exhaustion aborts
        the round and raises / reports a :class:`DispatchError`."""
        from ..kernels.host import moe_pack_host
        cfg = self.cfg
        tb = cfg.token_bytes
        N = cfg.n_ranks
        all_counts = ctx["all_counts"]
        t0 = self.fabric.now
        comb_imm = COMB_IMM + (self.round << 8)
        e0 = self.rank * cfg.e_local
        my = all_counts[:, e0:e0 + cfg.e_local].astype(np.int64)   # (N, e_local)

        # Re-permute expert outputs to source-major order with ONE gather:
        # row (s, e) blocks live at slab_off[e] + rows of source s in slab e.
        stacked = (np.concatenate(expert_out) if len(expert_out) > 1
                   else expert_out[0])
        slab_off = np.concatenate([[0], np.cumsum(my.sum(0))])[:-1]  # per e
        col_before = np.cumsum(my, axis=0) - my            # source prefix in slab
        starts = slab_off[None, :] + col_before            # (N, e_local)
        perm = multi_arange(starts, my)                    # source-major
        packed = moe_pack_host(stacked.reshape(-1, tb) if stacked.size
                               else stacked.reshape(0, tb), perm)

        # per-source destination offset: my segment of s's comb_buf starts
        # after all lower-ranked experts' counts from s (routes-derived)
        per_src = my.sum(1)
        lo = np.concatenate([[0], np.cumsum(per_src)])[:-1]
        before_tok = all_counts[:, :e0].sum(1).astype(np.int64)
        dsts = [PayloadDst(payload=packed[lo[s]:lo[s] + per_src[s]].reshape(-1),
                           dst=(self.ports[s].d_comb, int(before_tok[s]) * tb))
                for s in range(N) if per_src[s] > 0]

        def proxy_send() -> None:
            with traced_phase(self.fabric, "moe.combine"):
                self.engine.submit_scatters(
                    [(None, dsts, comb_imm, None,
                      lambda reason: self._fail(ctx, "combine", reason,
                                                on_error))])

        tr = self.fabric.tracer
        if tr is not None:
            tr.compute_span(f"rank{self.rank} gpu", "combine_launch",
                            t0, t0 + KERNEL_LAUNCH_US * 0.5,
                            phase="moe.combine")
        self.fabric.loop.schedule(KERNEL_LAUNCH_US * 0.5, proxy_send)

        # source side: expect one write from each rank hosting my tokens
        my_dest = ctx["fe_s"] // cfg.e_local
        expect = int(np.unique(my_dest).size)

        def done() -> None:
            self.stats["combine_us"] = self.fabric.now - t0
            on_complete()

        self.engine.expect_imm_count(comb_imm, expect, done)

    def combine_result(self, ctx: Dict, gates: np.ndarray,
                       dtype=np.float32) -> np.ndarray:
        """Un-permute the combine buffer and reduce with gates (fp32):
        a route-derived segment reduction via ``kernels.ops
        .moe_combine_host`` — O(top_k) vector ops, no per-token Python."""
        from ..kernels.host import moe_combine_host
        cfg = self.cfg
        tb = cfg.token_bytes
        T, R = ctx["T"], cfg.top_k
        # comb_buf rows are in (expert, own token order) — i.e. sorted
        # assignment order.  inv[t, k] = packed row of assignment (t, k).
        inv = np.empty(T * R, np.int64)
        inv[ctx["order"]] = np.arange(T * R)
        inv = inv.reshape(T, R)
        # accumulate experts in ascending order so fp32 summation order
        # matches the dense oracle bit-for-bit
        sort_k = np.argsort(ctx["eids"], axis=1, kind="stable")
        inv_sorted = np.take_along_axis(inv, sort_k, axis=1)
        eids_sorted = np.take_along_axis(ctx["eids"], sort_k, axis=1)
        gk = gates[np.arange(T)[:, None], eids_sorted].astype(np.float32)
        elems = tb // dtype().itemsize
        rows = self.comb_buf.view(dtype).reshape(-1, elems)[:T * R]
        return moe_combine_host(rows, inv_sorted, gk)
