"""Host-proxy MoE dispatch/combine over the TransferEngine (paper §6).

Protocol per rank and MoE layer invocation:

  dispatch:
    1. count tokens per expert (GPU kernel; modeled as KERNEL_LAUNCH_US)
    2. scatter ROUTES — the full (E,) per-expert counts — to every peer
    3. speculatively scatter the first T_priv tokens per destination into
       private per-source buffers (hides route latency — Fig. 11 ablation)
    4. once all peers' routes arrive (ImmCounter), every rank knows every
       (source, expert) block offset in the contiguous shared buffer;
       scatter the REMAINING tokens at exact offsets
    5. receiver completion = ImmCounter over token writes; shuffle into the
       (E_local, capacity) grouped-GEMM layout
    => <=2 WRITEs per inter-node peer, as in the paper.

  combine:
    expert outputs are returned with a SINGLE scatter per source (routing
    info is reused; block layout is deterministic), then each source
    un-permutes and reduces with its gates in fp32.

Payload bytes move for real; tests validate the packed layout and the
combined output against a dense oracle.  Same-node peers ride NVLink.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import (Fabric, MrDesc, NetAddr, PayloadDst, ScatterDst,
                    TransferEngine)

KERNEL_LAUNCH_US = 15.0      # launch -> first transfer (paper §6.2)
ROUTE_PROC_US = 20.0         # host-side route processing before the second
                             # round of transfers ("tens of microseconds",
                             # §6.2) — the latency the private buffers hide
ROUTE_IMM = 0x520
TOK_IMM = 0x521
COMB_IMM = 0x522
BARRIER_IMM = 0x523


@dataclass
class MoEConfig:
    n_ranks: int
    n_experts: int             # global
    top_k: int
    max_tokens: int            # T per rank
    token_bytes: int           # payload bytes per token (e.g. 7168 fp8)
    t_priv: int = 32           # private-buffer tokens per (src, dst) pair

    @property
    def e_local(self) -> int:
        return self.n_experts // self.n_ranks

    @property
    def recv_cap(self) -> int:
        # paper bound (§6.1): N * T * max(R, E/N) tokens can land on a rank
        return self.n_ranks * self.max_tokens * max(self.top_k, self.e_local)


class MoEEndpoint:
    """One expert-parallel rank: buffers + proxy logic."""

    def __init__(self, fabric: Fabric, cfg: MoEConfig, rank: int,
                 engine: TransferEngine):
        self.fabric = fabric
        self.cfg = cfg
        self.rank = rank
        self.engine = engine
        tb, N, T = cfg.token_bytes, cfg.n_ranks, cfg.max_tokens
        cap = N * T * max(cfg.top_k, cfg.e_local)
        # registered buffers
        self.routes_buf = np.zeros(N * cfg.n_experts * 4, np.uint8)
        self.priv_buf = np.zeros(N * cfg.t_priv * tb, np.uint8)
        self.shared_buf = np.zeros(cap * tb, np.uint8)
        self.comb_buf = np.zeros(T * cfg.top_k * tb, np.uint8)
        self.h_routes, self.d_routes = engine.reg_mr(self.routes_buf)
        self.h_priv, self.d_priv = engine.reg_mr(self.priv_buf)
        self.h_shared, self.d_shared = engine.reg_mr(self.shared_buf)
        self.h_comb, self.d_comb = engine.reg_mr(self.comb_buf)
        # send staging (combine may return up to recv_cap tokens)
        self.send_buf = np.zeros(cfg.recv_cap * tb + N * cfg.n_experts * 4, np.uint8)
        self.h_send, self.d_send = engine.reg_mr(self.send_buf)
        self.peers: List["MoEEndpoint"] = []
        self.stats: Dict[str, float] = {}
        self.round = 0          # per-layer round: scopes imm values

    # -- wiring ------------------------------------------------------------
    def connect(self, peers: List["MoEEndpoint"]) -> None:
        self.peers = peers

    # -- dispatch ------------------------------------------------------------
    def dispatch(self, tokens: np.ndarray, eids: np.ndarray,
                 on_complete: Callable[[], None]) -> Dict:
        """tokens: (T, token_bytes) uint8; eids: (T, top_k) int32 global ids.

        Returns a context dict used later by combine; ``on_complete`` fires
        when this rank has received ALL tokens routed to its local experts
        (and can run its grouped GEMM)."""
        cfg = self.cfg
        N, E, R = cfg.n_ranks, cfg.n_experts, cfg.top_k
        T = tokens.shape[0]
        t0 = self.fabric.now
        self.round += 1
        route_imm = ROUTE_IMM + (self.round << 8)
        tok_imm = TOK_IMM + (self.round << 8)

        # 1. per-expert counts
        counts = np.bincount(eids.reshape(-1), minlength=E).astype(np.int32)

        # flat assignment list in (dest_rank, expert, token) order
        fe = eids.reshape(-1)
        ft = np.repeat(np.arange(T), R)
        order = np.lexsort((ft, fe))            # stable by expert then token
        fe_s, ft_s = fe[order], ft[order]
        dest = fe_s // cfg.e_local

        ctx = {"counts": counts, "fe_s": fe_s, "ft_s": ft_s, "t0": t0,
               "T": T, "sent_at": None}
        self._last_ctx = ctx

        def proxy_phase1() -> None:
            # 2. routes to all peers (small payload, all NICs)
            off = 0
            rb = self.send_buf[-N * E * 4:]
            rb.view(np.int32)[:E] = counts
            route_dsts = []
            for p in self.peers:
                route_dsts.append(ScatterDst(
                    len=E * 4, src=len(self.send_buf) - N * E * 4,
                    dst=(p.d_routes, self.rank * E * 4)))

            # 3. speculative private-buffer tokens (first t_priv per dest).
            # Gather-into-snapshot fast path: ONE vectorised fancy-index
            # gather per destination produces the contiguous payload that
            # IS the submission snapshot — no per-row copies into send_buf
            # and no second snapshot copy (zero-copy like the rest of the
            # batch path).
            tb = cfg.token_bytes
            priv_dsts, priv_meta = [], {}
            for r in range(N):
                rows = np.nonzero(dest == r)[0]
                take = rows[:cfg.t_priv]
                priv_meta[r] = take
                if take.size == 0:
                    continue
                priv_dsts.append(PayloadDst(
                    payload=tokens[ft_s[take]].reshape(-1),
                    dst=(self.peers[r].d_priv, self.rank * cfg.t_priv * tb)))
            # routes + private tokens ride ONE WrBatch (one proxy handoff);
            # each keeps its own imm so completion accounting is unchanged
            self.engine.submit_scatters([
                (self.h_send, route_dsts, route_imm, None),
                (None, priv_dsts, tok_imm, None),
            ])
            ctx["priv_meta"] = priv_meta

        self.fabric.loop.schedule(KERNEL_LAUNCH_US, proxy_phase1)

        # 4. wait for ALL routes, then send remaining tokens at exact offsets
        def on_routes() -> None:
            self.fabric.loop.schedule(ROUTE_PROC_US, lambda: process_routes())

        def process_routes() -> None:
            all_counts = self.routes_buf.view(np.int32).reshape(N, E)
            ctx["all_counts"] = all_counts.copy()
            tb = cfg.token_bytes
            shared_dsts = []
            for r in range(N):
                rows = np.nonzero(dest == r)[0]
                rest = rows[cfg.t_priv:]
                if rest.size == 0:
                    continue
                # offset of MY block for expert e at receiver r:
                #   sum_{e' local-before e} total(e') + sum_{s'<me} cnt[s'][e]
                # Gather-into-snapshot: one vectorised gather per receiver;
                # per-expert payloads are zero-copy row slices of it.
                gathered = tokens[ft_s[rest]]
                # tokens in `rest` are expert-sorted; split per expert
                split_start = 0
                for e in np.unique(fe_s[rest]):
                    blk = rest[fe_s[rest] == e]
                    e_loc = e % cfg.e_local
                    e0 = r * cfg.e_local
                    tot_before = int(all_counts[:, e0:e].sum()) if e > e0 else 0
                    src_before = int(all_counts[:self.rank, e].sum())
                    # skip this source's private tokens of expert e
                    n_priv_e = int((fe_s[ctx["priv_meta"][r]] == e).sum())
                    dst_tok = tot_before + src_before + n_priv_e
                    shared_dsts.append(PayloadDst(
                        payload=gathered[split_start:split_start + blk.size]
                        .reshape(-1),
                        dst=(self.peers[r].d_shared, dst_tok * tb)))
                    split_start += blk.size
            if shared_dsts:
                self.engine.submit_scatters(
                    [(None, shared_dsts, tok_imm,
                      lambda: ctx.__setitem__("sent_at", self.fabric.now))])
            else:
                ctx["sent_at"] = self.fabric.now

            # receiver completion: expected #token WRITEs to me.  Private
            # writes are one per source; shared writes are one per
            # (source, expert) pair with residual tokens after the private
            # prefix — all derivable from the exchanged routes.
            my_counts = all_counts[:, self.rank * cfg.e_local:
                                   (self.rank + 1) * cfg.e_local]
            per_src = my_counts.sum(1)
            n_writes = int((per_src > 0).sum())
            for s in range(N):
                cum = 0
                for e_loc in range(cfg.e_local):
                    cnt = int(my_counts[s, e_loc])
                    priv = max(0, min(cfg.t_priv - cum, cnt))
                    if cnt - priv > 0:
                        n_writes += 1
                    cum += cnt
            ctx["my_counts"] = my_counts.copy()

            def tokens_done() -> None:
                self.stats["dispatch_us"] = self.fabric.now - t0
                on_complete()

            self.engine.expect_imm_count(tok_imm, n_writes, tokens_done)

        self.engine.expect_imm_count(route_imm, N, on_routes)
        return ctx

    # -- receiver shuffle --------------------------------------------------------
    def gather_expert_tokens(self, ctx: Dict) -> List[np.ndarray]:
        """Shuffle received bytes into per-local-expert dense slabs
        (the paper's receiver half feeding the Grouped GEMM)."""
        cfg = self.cfg
        tb = cfg.token_bytes
        N = cfg.n_ranks
        all_counts = ctx["all_counts"]
        out = []
        for e_loc in range(cfg.e_local):
            e = self.rank * cfg.e_local + e_loc
            rows = []
            e0 = self.rank * cfg.e_local
            tot_before = int(all_counts[:, e0:e].sum()) if e > e0 else 0
            src_before = 0
            for s in range(N):
                cnt = int(all_counts[s, e])
                if cnt == 0:
                    continue
                # how many of source s's tokens for ME (all local experts)
                # went into its private buffer, and of those, expert e's?
                peer_ctx = self.peers[s]._last_ctx
                take = peer_ctx["priv_meta"][self.rank]
                fe_s = peer_ctx["fe_s"]
                n_priv_e = int((fe_s[take] == e).sum())
                # private rows for (s, e): position of e within take
                sel = np.nonzero(fe_s[take] == e)[0]
                for i in sel:
                    lo = (s * cfg.t_priv + i) * tb
                    rows.append(self.priv_buf[lo:lo + tb])
                # shared rows
                dst_tok = tot_before + src_before + n_priv_e
                for i in range(cnt - n_priv_e):
                    lo = (dst_tok + i) * tb
                    rows.append(self.shared_buf[lo:lo + tb])
                src_before += cnt
            out.append(np.stack(rows) if rows else
                       np.zeros((0, tb), np.uint8))
        return out

    # -- combine ----------------------------------------------------------------
    def combine(self, ctx: Dict, expert_out: List[np.ndarray],
                on_complete: Callable[[], None]) -> None:
        """Send processed tokens back to their sources: ONE scatter."""
        cfg = self.cfg
        tb = cfg.token_bytes
        N = cfg.n_ranks
        all_counts = ctx["all_counts"]
        t0 = self.fabric.now
        comb_imm = COMB_IMM + (self.round << 8)

        # stage: per source, concat its tokens across my local experts in
        # (expert, source-order) layout — deterministic for the source too
        send_off = 0
        dsts = []
        for s in range(N):
            src_rows = []
            for e_loc in range(cfg.e_local):
                e = self.rank * cfg.e_local + e_loc
                cnt = int(all_counts[s, e])
                if cnt == 0:
                    continue
                before = int(all_counts[:s, e].sum())
                src_rows.append(expert_out[e_loc][before:before + cnt])
            if not src_rows:
                continue
            blob = np.concatenate(src_rows).reshape(-1)
            self.send_buf[send_off:send_off + blob.size] = blob
            # destination offset: source's comb_buf is laid out by
            # (expert, its own token order) across ALL experts; my segment
            # starts after all lower-ranked experts' counts from s
            e0 = self.rank * cfg.e_local
            before_tok = int(all_counts[s, :e0].sum())
            dsts.append(ScatterDst(len=blob.size, src=send_off,
                                   dst=(self.peers[s].d_comb, before_tok * tb)))
            send_off += blob.size

        def proxy_send() -> None:
            if dsts:
                self.engine.submit_scatter(self.h_send, dsts, imm=comb_imm)

        self.fabric.loop.schedule(KERNEL_LAUNCH_US * 0.5, proxy_send)

        # source side: expect one write from each rank hosting my tokens
        my_dest = ctx["fe_s"] // cfg.e_local
        expect = int(np.unique(my_dest).size)

        def done() -> None:
            self.stats["combine_us"] = self.fabric.now - t0
            on_complete()

        self.engine.expect_imm_count(comb_imm, expect, done)

    def combine_result(self, ctx: Dict, gates: np.ndarray,
                       dtype=np.float32) -> np.ndarray:
        """Un-permute the combine buffer and reduce with gates (fp32)."""
        cfg = self.cfg
        tb = cfg.token_bytes
        T, R = ctx["T"], cfg.top_k
        fe_s, ft_s = ctx["fe_s"], ctx["ft_s"]
        # combine buffer layout: blocks ordered by expert id, within block
        # this rank's tokens in (expert-sorted flat) order
        counts = ctx["counts"]
        starts = np.zeros(cfg.n_experts, np.int64)
        starts[1:] = np.cumsum(counts)[:-1]
        elems = tb // dtype().itemsize
        buf = self.comb_buf.view(dtype).reshape(-1, elems)
        y = np.zeros((T, elems), np.float32)
        cursor = starts.copy()
        for i in range(fe_s.size):
            e, t = fe_s[i], ft_s[i]
            row = buf[cursor[e]]
            y[t] += row.astype(np.float32) * gates[t, e]   # gates: (T, E) dense
            cursor[e] += 1
        return y
