from .dispatch import (DispatchError, MoEConfig, MoEEndpoint, PeerPorts,
                       multi_arange)
from .driver import make_endpoints, oracle, run_moe_layer

__all__ = ["MoEConfig", "MoEEndpoint", "PeerPorts", "multi_arange",
           "make_endpoints", "run_moe_layer", "oracle", "DispatchError"]
