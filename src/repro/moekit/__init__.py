from .dispatch import MoEConfig, MoEEndpoint
from .driver import make_endpoints, oracle, run_moe_layer

__all__ = ["MoEConfig", "MoEEndpoint", "make_endpoints", "run_moe_layer", "oracle"]
