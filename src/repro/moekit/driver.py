"""Orchestration + oracle for the host-proxy MoE kernels."""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import Fabric
from .dispatch import MoEConfig, MoEEndpoint


def make_endpoints(fabric: Fabric, cfg: MoEConfig, *, nic: str = "cx7",
                   gpus_per_node: int = 8, nvlink: bool = False,
                   nics: Optional[List[str]] = None) -> List[MoEEndpoint]:
    """One engine per EP rank, grouped ``gpus_per_node`` ranks to a node.

    ``nvlink=True`` registers ranks of one node under a shared physical
    host, so same-node dispatch/combine payloads ride the NVLink fast path
    (paper §6) while cross-node traffic keeps the NIC.  ``nics`` optionally
    gives a per-rank NIC preset list (Holmes-style mixed clusters); it
    overrides ``nic``.  The default (``nvlink=False``, uniform ``nic``) is
    bit-identical to the pre-heterogeneous-fabric behaviour."""
    eps = []
    for r in range(cfg.n_ranks):
        node = f"node{r // gpus_per_node}"
        rank_nic = nics[r] if nics is not None else nic
        eng = fabric.add_engine(f"{node}-r{r}", nic=rank_nic,
                                host=node if nvlink else None,
                                nvlink=nvlink)
        eps.append(MoEEndpoint(fabric, cfg, r, eng))
    # endpoints exchange ONLY serializable ports (rank + MrDescs): all
    # placement offsets must be derived from the routes on the wire
    ports = [e.port() for e in eps]
    for e in eps:
        e.connect(ports)
    return eps


def run_moe_layer(fabric: Fabric, eps: List[MoEEndpoint],
                  tokens: List[np.ndarray], eids: List[np.ndarray],
                  gates: List[np.ndarray],
                  expert_fn: Callable[[int, np.ndarray], np.ndarray],
                  dtype=np.float32) -> Tuple[List[np.ndarray], Dict]:
    """One dispatch -> expert -> combine round across all ranks.

    tokens[r]: (T, elems) dtype; eids[r]: (T, top_k); gates[r]: (T, E) dense.
    expert_fn(global_expert_id, slab (n, elems)) -> (n, elems).
    Returns (combined outputs per rank, stats).
    """
    from ..obs import traced_window

    cfg = eps[0].cfg
    N = cfg.n_ranks
    ctxs: List[Dict] = [None] * N
    done = {"disp": 0, "comb": 0}

    def start_combine(r: int) -> None:
        ep = eps[r]
        slabs = ep.gather_expert_tokens(ctxs[r])
        outs = []
        elems = cfg.token_bytes // dtype().itemsize
        for e_loc, slab in enumerate(slabs):
            e = r * cfg.e_local + e_loc
            x = slab.view(dtype).reshape(slab.shape[0], elems)
            y = expert_fn(e, x).astype(dtype)
            outs.append(y.view(np.uint8).reshape(y.shape[0], cfg.token_bytes))
        ep.combine(ctxs[r], outs,
                   lambda: done.__setitem__("comb", done["comb"] + 1))

    with traced_window(fabric, "moe.layer"):
        for r, ep in enumerate(eps):
            tok_bytes = tokens[r].astype(dtype).view(np.uint8).reshape(
                tokens[r].shape[0], -1)
            ctxs[r] = ep.dispatch(tok_bytes, eids[r],
                                  lambda r=r: (done.__setitem__("disp", done["disp"] + 1),
                                               start_combine(r)))
        fabric.run()
    if fabric.tracer is not None:
        fabric.tracer.sample_gauges()
    assert done["disp"] == N and done["comb"] == N, (done, N)

    results = [eps[r].combine_result(ctxs[r], gates[r], dtype=dtype)
               for r in range(N)]
    stats = {
        "dispatch_us": [e.stats.get("dispatch_us", 0.0) for e in eps],
        "combine_us": [e.stats.get("combine_us", 0.0) for e in eps],
    }
    return results, stats


def oracle(tokens: List[np.ndarray], eids: List[np.ndarray],
           gates: List[np.ndarray], expert_fn, n_experts: int
           ) -> List[np.ndarray]:
    """Dense reference: y[t] = sum_e gates[t,e] * f_e(x[t])."""
    out = []
    for r in range(len(tokens)):
        x = tokens[r].astype(np.float32)
        y = np.zeros_like(x)
        for e in range(n_experts):
            w = gates[r][:, e:e + 1]
            if (w != 0).any():
                y += w * expert_fn(e, x)
        out.append(y)
    return out
