"""repro.kvlayout: KV-state schemas + the transfer-plan compiler.

Opens disaggregated serving to every cache architecture the model stack
can produce: uniform k/v, gemma3-style local/global pattern splits, vlm
cross layers, SSM/hybrid state, and first-k-dense head layers.  See
``schema.py`` (what the cache *is*) and ``plan.py`` (how it moves).
"""

from .plan import TransferPlan, compile_plan, fill_cache, stage_cache
from .schema import (DECODE_MARGIN, KvComponent, KvSchema, handoff_max_len,
                     schema_from_config)

__all__ = [
    "KvSchema", "KvComponent", "schema_from_config",
    "TransferPlan", "compile_plan", "stage_cache", "fill_cache",
    "handoff_max_len", "DECODE_MARGIN",
]
