"""TransferPlan: compile a KvSchema into batched scatter groups.

The compiler runs once per (schema, seq_len) — *before* any request touches
the hot path — and emits:

* a **canonical slot order** for the handoff: components in schema order,
  stack entries in layer order, pages ("chunks") in token order.  Both ends
  allocate pool pages in this order, so a flat page-id list in the
  DispatchReq fully describes the destination page table;
* a **trigger index**: for every model layer, the (component, slot) writes
  that become transferable when that layer's compute completes — this is
  what the Prefiller's UvmWatcher spans consume;
* an **ImmCounter expectation map**: one immediate per component
  (``base_imm + component_index``) with its total WRITE count, so the
  receiver can arm all counters before the first byte lands.

The hot path then degenerates to :meth:`TransferPlan.submit_span`: ONE
``submit_scatters`` call — one ``WrBatch``, one event-loop enqueue — per
completed layer span, regardless of how many components/pages the span
covers (§3.4 WR templating; arXiv 2605.00686 plan-ahead).

``stage_cache`` / ``fill_cache`` bridge the model's cache pytree and pool
slots on the two ends; they are byte-exact inverses over the valid extent
of every component.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ScatterDst
from .schema import KvSchema, handoff_max_len


class TransferPlan:
    """Precompiled scatter layout for one (schema, seq_len)."""

    def __init__(self, schema: KvSchema, seq_len: int):
        self.schema = schema
        self.seq_len = seq_len
        self.max_len = handoff_max_len(seq_len)
        self.slot_bytes = schema.slot_bytes
        pt = schema.page_tokens
        # writes that unlock when model layer t completes: (comp_idx, slot)
        self.by_trigger: List[List[Tuple[int, int]]] = \
            [[] for _ in range(schema.n_layers)]
        self.comp_chunks: List[int] = []    # pages per stack layer, per comp
        self.comp_page_len: List[int] = []  # WRITE length, per comp
        self._slots: Dict[Tuple[int, int, int], int] = {}
        n = 0
        for ci, comp in enumerate(schema.components):
            chunks = comp.chunks(seq_len, self.max_len, pt)
            self.comp_chunks.append(chunks)
            self.comp_page_len.append(comp.page_len(pt))
            for s in range(comp.n_stack):
                trig = comp.layers[s]
                for c in range(chunks):
                    self._slots[(ci, s, c)] = n
                    self.by_trigger[trig].append((ci, n))
                    n += 1
        self.n_slots = n          # pool pages per side, canonical order
        self.total_writes = n     # one WRITE per page

    # -- introspection -------------------------------------------------------
    @property
    def n_imms(self) -> int:
        """Distinct immediates used (one per component; callers may claim
        one more for the tail write)."""
        return len(self.schema.components)

    def slot(self, comp_idx: int, stack: int, chunk: int) -> int:
        return self._slots[(comp_idx, stack, chunk)]

    def expected_counts(self) -> List[Tuple[int, int]]:
        """Receiver expectation map: (imm offset, WRITE count) per
        component.  Arm each as ``expect_imm_count(base_imm + off, count)``."""
        return [(ci, comp.n_stack * self.comp_chunks[ci])
                for ci, comp in enumerate(self.schema.components)
                if comp.n_stack * self.comp_chunks[ci] > 0]

    def span_writes(self, lo: int, hi: int) -> List[Tuple[int, int]]:
        """(comp_idx, slot) writes unlocked by model layers [lo, hi)."""
        out: List[Tuple[int, int]] = []
        for t in range(lo, hi):
            out.extend(self.by_trigger[t])
        return out

    # -- hot path ------------------------------------------------------------
    def submit_span(self, engine, src_handle, src_pages: Sequence[int],
                    dst_desc, dst_pages: Sequence[int], base_imm: int,
                    lo: int, hi: int,
                    on_sent: Optional[Callable[[int], None]] = None,
                    on_error: Optional[Callable[[str], None]] = None,
                    fence_epoch: Optional[int] = None) -> int:
        """WRITE everything unlocked by layers [lo, hi): ONE WrBatch.

        ``src_pages``/``dst_pages`` are the two pools' page ids in canonical
        slot order.  Each component rides its own immediate
        (``base_imm + comp_idx``); ``on_sent(n)`` fires once per component
        group with its write count when that group has sender completions.
        ``on_error(reason)`` (fault injection) fires when a component
        group's WRITEs exhaust their retry budget — at most once per group;
        the caller dedups across groups.  ``fence_epoch`` stamps every
        WRITE with the sender's view epoch for the receiver's epoch fence
        (zombie-writer guard); None posts unstamped.  Returns the number of
        WRITEs templated."""
        stride = self.slot_bytes
        per_comp: Dict[int, List[ScatterDst]] = {}
        for ci, slot in self.span_writes(lo, hi):
            per_comp.setdefault(ci, []).append(ScatterDst(
                len=self.comp_page_len[ci],
                src=src_pages[slot] * stride,
                dst=(dst_desc, dst_pages[slot] * stride)))
        if not per_comp:
            return 0
        groups = []
        for ci in sorted(per_comp):
            dsts = per_comp[ci]
            cb = ((lambda n=len(dsts): on_sent(n))
                  if on_sent is not None else None)
            groups.append((src_handle, dsts, base_imm + ci, cb, on_error,
                           fence_epoch))
        engine.submit_scatters(groups)
        return sum(len(d) for d in per_comp.values())


def compile_plan(src_schema: KvSchema, dst_schema: KvSchema,
                 seq_len: int) -> TransferPlan:
    """Validate src/dst compatibility and compile the plan.

    Programmatic entry point for hand-wired setups and tests.  The serving
    stack performs the same ``KvSchema.mismatch`` check twice on its own:
    the Scheduler refuses mismatched pairings at routing time, and the
    Prefiller re-validates the schema carried in each ``DispatchReq``
    before the first WRITE."""
    reason = src_schema.mismatch(dst_schema)
    if reason is not None:
        raise ValueError(f"incompatible KvSchemas: {reason}")
    return TransferPlan(src_schema, seq_len)


# ---------------------------------------------------------------------------
# cache <-> pool staging (both directions are schema-generic)
# ---------------------------------------------------------------------------

def _comp_np(cache: Dict[str, object], comp) -> np.ndarray:
    arr = np.asarray(cache[comp.name])
    return arr.astype(np.dtype(comp.dtype), copy=False)


def stage_cache(plan: TransferPlan, pool, pages: Sequence[int],
                cache: Dict[str, object]) -> None:
    """Write a freshly computed cache pytree into pool slots (src side)."""
    schema = plan.schema
    pt = schema.page_tokens
    for ci, comp in enumerate(schema.components):
        arr = _comp_np(cache, comp)
        for s in range(comp.n_stack):
            layer = arr[s, 0]
            if comp.kind == "blob":
                data = np.ascontiguousarray(layer).reshape(-1).view(np.uint8)
                pool.write_slot(pages[plan.slot(ci, s, 0)], data)
                continue
            t_all = comp.tokens(plan.seq_len, plan.max_len)
            for c in range(plan.comp_chunks[ci]):
                lo, hi = c * pt, min(t_all, (c + 1) * pt)
                data = (np.ascontiguousarray(layer[lo:hi])
                        .reshape(-1).view(np.uint8))
                pool.write_slot(pages[plan.slot(ci, s, c)], data)


def fill_cache(plan: TransferPlan, pool, pages: Sequence[int],
               cache: Dict[str, object]) -> Dict[str, np.ndarray]:
    """Read pool slots back into cache arrays (dst side).

    ``cache`` supplies the target shapes (an ``init_cache`` pytree built
    with ``handoff_max_len(seq_len)``); returns ``{name: np.ndarray}`` for
    every schema component, leaving non-schema entries untouched."""
    schema = plan.schema
    pt = schema.page_tokens
    out: Dict[str, np.ndarray] = {}
    for ci, comp in enumerate(schema.components):
        base = np.array(_comp_np(cache, comp))      # writable copy
        dtype = np.dtype(comp.dtype)
        for s in range(comp.n_stack):
            if comp.kind == "blob":
                raw = pool.read_slot(pages[plan.slot(ci, s, 0)],
                                     comp.blob_bytes)
                base[s, 0] = raw.view(dtype).reshape(base.shape[2:])
                continue
            t_all = comp.tokens(plan.seq_len, plan.max_len)
            rest = base.shape[3:]
            for c in range(plan.comp_chunks[ci]):
                lo, hi = c * pt, min(t_all, (c + 1) * pt)
                raw = pool.read_slot(pages[plan.slot(ci, s, c)],
                                     (hi - lo) * comp.token_bytes)
                base[s, 0, lo:hi] = raw.view(dtype).reshape((hi - lo,) + rest)
        out[comp.name] = base
    return out
