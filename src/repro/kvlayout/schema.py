"""KvSchema: a declarative description of a model's reduced-cache state.

The §4 KvCache protocol moves "the cache" — but what the cache *is* differs
per architecture: a uniform ``(L, S, K, Dh)`` k/v stack (stablelm, granite,
qwen3-moe, musicgen), local+special ring/full stacks for pattern archs
(gemma3 ``lk/lv/sk/sv``, llama-vision self/cross), per-layer SSM state
blobs (mamba2 ``conv``/``ssd``), hybrid SSM + shared-attention rings
(zamba2 ``ak/av``), or a head of dense layers in front of the scanned stack
(deepseek ``k0/v0``).  The seed serving stack hard-coded the first shape
and guarded the rest out via ``disagg_unsupported_reason``.

A :class:`KvSchema` names each cache array as a *component* with:

* ``name``     — the cache-dict key the model stack produces/consumes;
* ``layers``   — the model layer ids whose compute produces each stack
  entry (this is what maps UvmWatcher layer progress to transferable
  state);
* ``dtype``    — numpy dtype string of the wire bytes;
* ``kind``     — the component's extent semantics:
    - ``token``:  one row per *prompt token* (paged over ``page_tokens``);
    - ``ring``:   a ring buffer of ``min(max_len, window)`` token slots,
                  transferred whole (slot occupancy is positional);
    - ``fixed``:  a fixed number of token rows independent of the prompt
                  (vlm cross-attention K/V over the vision sequence);
    - ``blob``:   one fixed-size byte blob per stack layer (SSM conv/ssd
                  state — per-sequence, not per-token);
* page geometry — ``token_bytes``/``blob_bytes`` plus the schema-wide
  ``page_tokens``, from which every WRITE length is derived.

Schemas are derived from ``ModelConfig`` (mirroring ``models.init_cache``
exactly), are serialisable over the ctrl wire (JOIN advertises them; the
Scheduler refuses to pair peers whose schemas differ), and are the input
to the transfer-plan compiler in :mod:`repro.kvlayout.plan`.

All layout decisions live here, at *schema* time — the transfer hot path
never inspects an architecture again (arXiv 2605.00686's plan-ahead
principle; paper §3.4 WR templating).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# Decode headroom baked into the handoff cache: both ends of a transfer
# derive cache geometry from the SAME max_len so ring slot assignment
# (slot = pos % W) and padding agree bit-for-bit.
DECODE_MARGIN = 64

KINDS = ("token", "ring", "fixed", "blob")


def handoff_max_len(seq_len: int) -> int:
    """Canonical cache length for a disaggregated handoff of ``seq_len``."""
    return seq_len + DECODE_MARGIN


@dataclass(frozen=True)
class KvComponent:
    """One named array of the reduced cache (see module docstring)."""

    name: str
    kind: str
    layers: Tuple[int, ...]        # producing model layer per stack entry
    dtype: str                     # numpy dtype str (e.g. "<f4")
    token_bytes: int = 0           # bytes/token/stack-layer (token|ring|fixed)
    window: int = 0                # ring capacity cap (ring; 0 = max_len)
    fixed_tokens: int = 0          # token rows (fixed)
    blob_bytes: int = 0            # bytes/stack-layer (blob)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown component kind {self.kind!r}")

    @property
    def n_stack(self) -> int:
        return len(self.layers)

    def tokens(self, seq_len: int, max_len: int) -> int:
        """Token rows moved per stack layer (0 for blob components)."""
        if self.kind == "token":
            return seq_len
        if self.kind == "ring":
            return min(max_len, self.window) if self.window else max_len
        if self.kind == "fixed":
            return self.fixed_tokens
        return 0

    def layer_bytes(self, seq_len: int, max_len: int) -> int:
        """Payload bytes per stack layer."""
        if self.kind == "blob":
            return self.blob_bytes
        return self.tokens(seq_len, max_len) * self.token_bytes

    def page_len(self, page_tokens: int) -> int:
        """Bytes of one WRITE (page) of this component."""
        if self.kind == "blob":
            return self.blob_bytes
        return page_tokens * self.token_bytes

    def chunks(self, seq_len: int, max_len: int, page_tokens: int) -> int:
        """Pages per stack layer for a ``seq_len`` handoff."""
        if self.kind == "blob":
            return 1
        t = self.tokens(seq_len, max_len)
        return -(-t // page_tokens)


@dataclass(frozen=True)
class KvSchema:
    """The complete cache-state schema of one architecture."""

    arch: str
    n_layers: int
    page_tokens: int
    components: Tuple[KvComponent, ...]

    def component(self, name: str) -> KvComponent:
        for c in self.components:
            if c.name == name:
                return c
        raise KeyError(name)

    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.components)

    @property
    def slot_bytes(self) -> int:
        """Uniform pool-slot size: every component's page fits in a slot,
        so one shared page allocator serves all components."""
        return max(c.page_len(self.page_tokens) for c in self.components)

    def total_bytes(self, seq_len: int) -> int:
        ml = handoff_max_len(seq_len)
        return sum(c.n_stack * c.layer_bytes(seq_len, ml)
                   for c in self.components)

    # -- wire form (carried in the ctrl JOIN / VIEW-UPDATE) -----------------
    def to_wire(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "n_layers": self.n_layers,
            "page_tokens": self.page_tokens,
            "components": [{
                "name": c.name, "kind": c.kind, "layers": list(c.layers),
                "dtype": c.dtype, "token_bytes": c.token_bytes,
                "window": c.window, "fixed_tokens": c.fixed_tokens,
                "blob_bytes": c.blob_bytes,
            } for c in self.components],
        }

    @staticmethod
    def from_wire(d: Dict[str, Any]) -> "KvSchema":
        return KvSchema(
            arch=d["arch"], n_layers=int(d["n_layers"]),
            page_tokens=int(d["page_tokens"]),
            components=tuple(KvComponent(
                name=c["name"], kind=c["kind"],
                layers=tuple(int(x) for x in c["layers"]), dtype=c["dtype"],
                token_bytes=int(c["token_bytes"]), window=int(c["window"]),
                fixed_tokens=int(c["fixed_tokens"]),
                blob_bytes=int(c["blob_bytes"]))
                for c in d["components"]),
        )

    def mismatch(self, other: Optional["KvSchema"]) -> Optional[str]:
        """Why a transfer between ``self`` (src) and ``other`` (dst) cannot
        be compiled (None = compatible).  Checked by the Scheduler at
        routing time, so incompatible pairs fail before any WRITE."""
        if other is None:
            return "peer advertises no KvSchema"
        if self.page_tokens != other.page_tokens:
            return (f"page_tokens differ ({self.page_tokens} vs "
                    f"{other.page_tokens})")
        if self.components != other.components:
            return (f"component sets differ ({self.names()} vs "
                    f"{other.names()})")
        return None


# ---------------------------------------------------------------------------
# derivation from ModelConfig (must mirror models.init_cache / prefill)
# ---------------------------------------------------------------------------

def _pattern_period(cfg) -> int:
    """Pattern period, identical to ``models.model._pattern``."""
    if cfg.family in ("ssm", "hybrid") or cfg.first_k_dense:
        return 0
    if cfg.global_every:
        return cfg.global_every
    if cfg.cross_every:
        return cfg.cross_every
    return 0


def schema_from_config(cfg, page_tokens: int = 16) -> KvSchema:
    """Derive the KvSchema of ``cfg``'s reduced cache.

    Every family in ``repro.models`` maps onto token/ring/fixed/blob
    components; the ``layers`` tuples are the model layer ids whose compute
    completes each stack entry, which is what lets the Prefiller's
    UvmWatcher trigger per-span transfers for ANY cache shape.
    """
    dt = np.dtype(cfg.param_dtype).str
    f4 = np.dtype(np.float32).str
    itemsize = np.dtype(cfg.param_dtype).itemsize
    comps: List[KvComponent] = []

    if cfg.family in ("ssm", "hybrid"):
        from ..models.ssm import conv_dim
        all_layers = tuple(range(cfg.n_layers))
        conv_bytes = (cfg.ssm_dconv - 1) * conv_dim(cfg) * itemsize
        ssd_bytes = (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state
                     * np.dtype(np.float32).itemsize)
        comps.append(KvComponent("conv", "blob", all_layers, dt,
                                 blob_bytes=conv_bytes))
        comps.append(KvComponent("ssd", "blob", all_layers, f4,
                                 blob_bytes=ssd_bytes))
        if cfg.family == "hybrid":
            # the shared attention block's ring cache: one stack entry per
            # group, produced after the group's last mamba layer
            g = cfg.attn_every
            n_groups = cfg.n_layers // g
            ak_layers = tuple((i + 1) * g - 1 for i in range(n_groups))
            tb = cfg.n_kv_heads * cfg.head_dim * itemsize
            for name in ("ak", "av"):
                comps.append(KvComponent(name, "ring", ak_layers, dt,
                                         token_bytes=tb, window=cfg.window))
        return KvSchema(cfg.name, cfg.n_layers, page_tokens, tuple(comps))

    tb = cfg.n_kv_heads * cfg.head_dim * itemsize
    if _pattern_period(cfg):
        kinds = cfg.layer_kinds()
        loc = tuple(i for i, k in enumerate(kinds) if k in ("local", "attn"))
        spe = tuple(i for i, k in enumerate(kinds) if k in ("global", "cross"))
        if cfg.global_every:
            # gemma3: local layers ring over the window; globals full-length
            for name in ("lk", "lv"):
                comps.append(KvComponent(name, "ring", loc, dt,
                                         token_bytes=tb, window=cfg.window))
            for name in ("sk", "sv"):
                comps.append(KvComponent(name, "token", spe, dt,
                                         token_bytes=tb))
        else:
            # vlm: self layers full-length; cross layers hold vision K/V
            for name in ("lk", "lv"):
                comps.append(KvComponent(name, "token", loc, dt,
                                         token_bytes=tb))
            for name in ("sk", "sv"):
                comps.append(KvComponent(name, "fixed", spe, dt,
                                         token_bytes=tb,
                                         fixed_tokens=cfg.vision_seq))
        return KvSchema(cfg.name, cfg.n_layers, page_tokens, tuple(comps))

    # attention families with a uniform scanned stack (+ optional dense head)
    k0 = cfg.first_k_dense
    if k0:
        head = tuple(range(k0))
        for name in ("k0", "v0"):
            comps.append(KvComponent(name, "token", head, dt, token_bytes=tb))
    body = tuple(range(k0, cfg.n_layers))
    for name in ("k", "v"):
        comps.append(KvComponent(name, "token", body, dt, token_bytes=tb))
    return KvSchema(cfg.name, cfg.n_layers, page_tokens, tuple(comps))
