"""AdamW with decoupled weight decay and gradient clipping (pure pytree)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
