from .adamw import AdamWConfig, AdamWState, adamw_update, global_norm, init_adamw
from .schedule import cosine_with_warmup

__all__ = ["AdamWConfig", "AdamWState", "init_adamw", "adamw_update",
           "global_norm", "cosine_with_warmup"]
