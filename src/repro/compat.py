"""Version-compatibility shims over the moving jax API surface.

The repo targets the modern jax API — ``jax.make_mesh(..., axis_types=...)``
with ``jax.sharding.AxisType``, and ``jax.shard_map(..., check_vma=...)``.
Older jax (0.4.x, as shipped in some containers) has neither: ``AxisType``
is absent from ``jax.sharding``, ``make_mesh`` takes no ``axis_types``, and
``shard_map`` lives in ``jax.experimental.shard_map`` with a ``check_rep``
kwarg instead of ``check_vma``.

Every mesh construction and shard_map call in the repo goes through this
module so a jax upgrade/downgrade never breaks imports.  The ``HAS_*``
flags let tests assert which path is active.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # jax >= 0.5
    HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x
    AxisType = None
    HAS_AXIS_TYPE = False

try:
    _shard_map_new = jax.shard_map  # jax >= 0.6
    HAS_JAX_SHARD_MAP = True
except AttributeError:  # jax 0.4.x/0.5.x: experimental, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_old
    HAS_JAX_SHARD_MAP = False


def make_mesh(axis_shapes, axis_names, *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    kwargs = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map``; on old jax, ``check_vma`` maps to ``check_rep``."""
    if HAS_JAX_SHARD_MAP:
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
