"""Render the §Roofline table from the dry-run JSON artifacts."""

import json
import pathlib
import sys

OUT = pathlib.Path(__file__).resolve().parent / "out" / "dryrun"


def rows(mesh="pod16x16"):
    out = []
    for f in sorted(OUT.glob(f"*_{mesh}*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            continue
        if d.get("moe_mode") not in (None, "a2a") or "_v" in f.stem.split(mesh)[-1]:
            pass
        out.append(d)
    return out


def fmt(mesh="pod16x16", variant=None):
    print(f"| arch | shape | t_compute s | t_memory s | t_collective s | "
          f"dominant | useful | peak mem/dev GB |")
    print("|---|---|---|---|---|---|---|---|")
    seen = set()
    for d in rows(mesh):
        tag = (d["arch"], d["shape"])
        if tag in seen:
            continue
        seen.add(tag)
        pm = d.get("memory_analysis", {})
        mem = (pm.get("argument_size_in_bytes", 0) + pm.get("temp_size_in_bytes", 0)
               + pm.get("output_size_in_bytes", 0) - pm.get("alias_size_in_bytes", 0))
        print(f"| {d['arch']} | {d['shape']} | {d['t_compute']:.3g} | "
              f"{d['t_memory']:.3g} | {d['t_collective']:.3g} | {d['dominant']} | "
              f"{d['useful_flops_ratio']:.2f} | {mem / 2**30:.2f} |")


if __name__ == "__main__":
    fmt(*(sys.argv[1:] or ["pod16x16"]))
