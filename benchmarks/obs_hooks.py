"""Shared ``BENCH_TRACE=1`` glue for the benchmark modules.

Each bench picks ONE representative run to trace (tracing every sweep point
would multiply artifact size for no extra signal).  The tracer is attached
before any engine is built and — by the obs-package invariant — changes no
simulated timing: traced rows are bit-identical to untraced ones, which is
asserted by ``tests/test_obs.py``.

On ``finish_trace`` the bench gets back the flat metrics dict (merged into
its ``BENCH_*.json`` under ``"metrics"``) and a Perfetto-loadable Chrome
trace lands in the bench output dir.

``attach_health`` wires the **always-on** monitoring pair (HealthMonitor +
FlightRecorder) into a bench fabric — the same invariant applies (timing
bit-identical, pinned by ``tests/test_health.py``), so the golden rows do
not move.  Clean rows then assert ``assert_no_flags``: the deviation
detector's clean-fabric false-positive rate is zero by construction, and
the bench-smoke CI job proves it on every run.
"""

from __future__ import annotations

import os
from typing import Optional

TRACE = os.environ.get("BENCH_TRACE") == "1"


def maybe_tracer(fab):
    """Attach a Tracer to ``fab`` when BENCH_TRACE=1 (else return None)."""
    if not TRACE:
        return None
    from repro.obs import Tracer
    return Tracer(fab)


def attach_health(fab):
    """Attach the always-on HealthMonitor + FlightRecorder to ``fab``.

    Returns the monitor.  Dumps (only written on failure paths) land in
    ``$FLIGHT_DUMP_DIR`` or ``./flight-dumps`` — CI uploads that dir as an
    artifact when a bench job fails.
    """
    from repro.obs import FlightRecorder, HealthMonitor
    mon = HealthMonitor(fab)
    FlightRecorder(fab)
    return mon


def assert_no_flags(monitor, name: str) -> None:
    """Zero-health-flags gate for clean (un-degraded) bench rows."""
    if monitor is None or not monitor.flags:
        return
    lines = "; ".join(f"{f['src']}>{f['dst']} ratio={f['ratio']:.2f}"
                      for f in monitor.flags)
    raise AssertionError(
        f"{name}: health monitor flagged {len(monitor.flags)} channel(s) "
        f"on a clean fabric — {lines}")


def finish_trace(tracer, out_dir: str, name: str) -> Optional[dict]:
    """Export the Chrome trace + return the flat metrics dict (or None)."""
    if tracer is None:
        return None
    from repro.obs import export_chrome_trace
    tracer.sample_gauges()
    os.makedirs(out_dir, exist_ok=True)
    n = export_chrome_trace(tracer, os.path.join(out_dir, name))
    print(f"# trace: {name} ({n} events)")
    return tracer.finalize()
