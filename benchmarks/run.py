"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.
``python -m benchmarks.run [p2p|kvcache|rlweights|moe|ablation ...]``
runs a subset (default: all).
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_ablation, bench_kvcache, bench_moe, bench_p2p,
                   bench_rlweights)
    modules = {
        "p2p": bench_p2p,              # Table 2 / Fig. 8
        "kvcache": bench_kvcache,      # Table 3 / Table 4
        "rlweights": bench_rlweights,  # Table 5
        "moe": bench_moe,              # Fig. 9/10 / Table 6
        "ablation": bench_ablation,    # Fig. 11 / Table 8/9
    }
    wanted = sys.argv[1:] or list(modules)
    rows = []

    def report(name: str, us, derived: str = "") -> None:
        rows.append((name, us, derived))
        print(f"{name},{0.0 if us is None else float(us):.3f},{derived}")

    for key in wanted:
        mod = modules[key]
        t0 = time.time()
        print(f"# == {key}: {mod.__doc__.splitlines()[0]} ==")
        mod.run(report)
        print(f"# {key} done in {time.time() - t0:.1f}s")
    print(f"# total: {len(rows)} measurements")


if __name__ == "__main__":
    main()
