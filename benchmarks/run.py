"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes the same rows to
``benchmarks/out/<module>.csv`` (one file per module — published as CI
artifacts by the bench-smoke job).

``python -m benchmarks.run [p2p|kvcache|rlweights|moe|ablation|scaling ...]``
runs a subset (default: all).
"""

from __future__ import annotations

import csv
import os
import sys
import time

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))


def main() -> None:
    from . import (bench_ablation, bench_chaos, bench_kvcache, bench_moe,
                   bench_p2p, bench_rlweights, bench_scaling)
    modules = {
        "p2p": bench_p2p,              # Table 2 / Fig. 8
        "kvcache": bench_kvcache,      # Table 3 / Table 4
        "rlweights": bench_rlweights,  # Table 5
        "moe": bench_moe,              # Fig. 9/10 / Table 6
        "ablation": bench_ablation,    # Fig. 11 / Table 8/9
        "scaling": bench_scaling,      # §4 dynamic scaling timeline
        "chaos": bench_chaos,          # fault injection (run last: appends
                                       # rows to rlweights/scaling JSONs)
    }
    wanted = sys.argv[1:] or list(modules)
    os.makedirs(OUT_DIR, exist_ok=True)
    total = 0

    for key in wanted:
        mod = modules[key]
        rows = []

        def report(name: str, us, derived: str = "") -> None:
            rows.append((name, 0.0 if us is None else float(us), derived))
            print(f"{name},{0.0 if us is None else float(us):.3f},{derived}")

        t0 = time.time()
        print(f"# == {key}: {mod.__doc__.splitlines()[0]} ==")
        mod.run(report)
        print(f"# {key} done in {time.time() - t0:.1f}s")
        path = os.path.join(OUT_DIR, f"{key}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["name", "value", "derived"])
            for name, us, derived in rows:
                w.writerow([name, f"{us:.3f}", derived])
        total += len(rows)
    print(f"# total: {total} measurements -> {OUT_DIR}")


if __name__ == "__main__":
    main()
