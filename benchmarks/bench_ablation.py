"""Fig. 11 + Fig. 12 + Table 8/9 ablations.

* Fig. 11: private-buffer size sweep — p50 dispatch latency vs t_priv.
* Table 9: posting time for all WRITEs of a scatter vs EP degree.
* Table 8: event breakdown from submit_scatter to last posted WRITE.
"""

from __future__ import annotations

import numpy as np

from repro.core import Fabric, ScatterDst
from .bench_moe import TOKEN_BYTES, TOP_K, E_TOTAL, bench_dispatch_combine

PAPER_T9 = {  # p50 us for posting all scatter WRITEs
    "efa": {8: 3.081, 16: 6.536, 32: 13.374, 64: 27.886},
    "cx7": {8: 0.842, 16: 1.926, 32: 4.140, 64: 8.502},
}


def bench_posting(nic: str, ep: int, iters: int = 50):
    """Time from scatter post start to last WRITE posted (Table 9).

    Returns ``(p50_us, batch_stats)`` — the engine's per-batch submission
    counters (WRs per enqueue, bytes per batch) ride along so the ablation
    table can show how well WR templating amortises the enqueue."""
    fab = Fabric(seed=0)
    src = fab.add_engine("src", nic=nic)
    peers = [fab.add_engine(f"p{i}", nic=nic) for i in range(ep - 1)]
    buf = np.zeros((ep - 1) * 1024, np.uint8)
    h, _ = src.reg_mr(buf)
    descs = []
    for p in peers:
        b = np.zeros(1024, np.uint8)
        _, d = p.reg_mr(b)
        descs.append(d)
    from repro.core.netsim import ENQUEUE_US
    samples = []
    for it in range(iters):
        group = src.groups[0]
        t0 = max(fab.now, group._post_busy_until)
        dsts = [ScatterDst(len=1024, src=1024 * i, dst=(descs[i], 0))
                for i in range(ep - 1)]
        src.submit_scatter(h, dsts)
        fab.run()
        # Table 9 window: first WRITE posted -> last WRITE posted
        # (the app->worker enqueue is Table 8's separate row)
        samples.append(group._post_busy_until - t0 - ENQUEUE_US)
    return float(np.percentile(samples, 50)), src.batch_stats.as_dict()


def bench_private_buffer(nic: str = "cx7", ep: int = 64) -> dict:
    """Fig. 11: p50 decode dispatch latency vs private-buffer tokens.

    EP64 decode (paper geometry): 128 tokens x top-8 / 64 ranks ~= 16
    expected tokens per destination, so the paper's 24-32-token knee is the
    point where the private buffers absorb essentially all tokens."""
    out = {}
    for t_priv in (1, 8, 16, 24, 32, 48):
        r = bench_dispatch_combine(ep, 128, nic, t_priv=t_priv, rounds=2)
        out[t_priv] = r["dispatch_us"]
    return out


def run(report) -> None:
    for nic in ("efa", "cx7"):
        for ep in (8, 16, 32, 64):
            us, bstats = bench_posting(nic, ep)
            paper = PAPER_T9[nic][ep]
            report(f"post_scatter_{nic}_ep{ep}", us,
                   f"us p50 post-all-WRITEs (paper {paper}; "
                   f"err {100 * (us - paper) / paper:+.0f}%)")
            report(f"batch_wrs_{nic}_ep{ep}", bstats["wrs_per_enqueue"],
                   f"WRs/enqueue over {bstats['batches']} batches "
                   f"({bstats['bytes_per_batch']:.0f} B/batch)")
    for nic in ("cx7", "efa"):
        sweep = bench_private_buffer(nic)
        best = min(sweep.values())
        knee = next((k for k, v in sorted(sweep.items())
                     if v <= 1.05 * best), None)
        detail = {k: round(v) for k, v in sweep.items()}
        report(f"priv_buffer_knee_{nic}", knee,
               f"tokens to reach within 5% of best dispatch latency "
               f"(paper: ~24-32); sweep {detail}")
