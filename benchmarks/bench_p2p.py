"""Table 2 / Fig. 8: point-to-point WRITE throughput vs message size.

Single WRITEs are issued serially (submit -> completion -> next), paged
WRITEs are pipelined, matching the paper's methodology (ib_write_bw /
fi_rma_bw counterparts).  Paper-measured values ride along so the report
shows the calibration error of the fabric model.
"""

from __future__ import annotations

import numpy as np

from repro.core import Fabric, Pages

# paper Table 2 (Gbps, op/s)
PAPER_SINGLE = {"efa": {65536: 16, 262144: 54, 1048576: 145, 33554432: 336},
                "cx7": {65536: 44, 262144: 116, 1048576: 245, 33554432: 378}}
PAPER_PAGED = {"efa": {1024: (17, 2.11e6), 8192: (138, 2.10e6),
                       16384: (274, 2.08e6), 65536: (364, 0.69e6)},
               "cx7": {1024: (91, 11.10e6), 8192: (320, 4.89e6),
                       16384: (367, 2.80e6), 65536: (370, 0.71e6)}}


def bench_single(nic: str, size: int, iters: int = 8) -> float:
    """Serial single-write throughput (Gbps)."""
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    src = np.zeros(size, np.uint8)
    dst = np.zeros(size, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    t0 = fab.now
    state = {"n": 0}

    def issue() -> None:
        if state["n"] < iters:
            state["n"] += 1
            a.submit_single_write(size, None, (hs, 0), (dd, 0), on_done=issue)

    issue()
    t = fab.run() - t0
    return size * iters * 8e-3 / t          # Gbps (us domain)


def bench_paged(nic: str, page: int, n_pages: int = 4096):
    """Pipelined paged-write throughput (Gbps, op/s)."""
    fab = Fabric(seed=0)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    src = np.zeros(max(n_pages * page, 1), np.uint8)
    dst = np.zeros(max(n_pages * page, 1), np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = tuple(range(n_pages))
    t0 = fab.now
    a.submit_paged_writes(page, 1, (hs, Pages(idx, page)), (dd, Pages(idx, page)))
    t = fab.run() - t0
    return n_pages * page * 8e-3 / t, n_pages / (t * 1e-6)


def run(report) -> None:
    for nic in ("efa", "cx7"):
        for size, paper in PAPER_SINGLE[nic].items():
            gbps = bench_single(nic, size)
            report(f"p2p_single_{nic}_{size >> 10}KiB", gbps,
                   f"Gbps (paper {paper}; err {100 * (gbps - paper) / paper:+.0f}%)")
        for page, (paper_gbps, paper_ops) in PAPER_PAGED[nic].items():
            gbps, ops = bench_paged(nic, page)
            report(f"p2p_paged_{nic}_{page >> 10 or 1}KiB", gbps,
                   f"Gbps {ops / 1e6:.2f}Mop/s (paper {paper_gbps} Gbps "
                   f"{paper_ops / 1e6:.2f}M; err {100 * (gbps - paper_gbps) / paper_gbps:+.0f}%)")
