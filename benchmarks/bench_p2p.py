"""Table 2 / Fig. 8: point-to-point WRITE throughput vs message size.

Single WRITEs are issued serially (submit -> completion -> next), paged
WRITEs are pipelined, matching the paper's methodology (ib_write_bw /
fi_rma_bw counterparts).  Paper-measured values ride along so the report
shows the calibration error of the fabric model.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import Fabric, Pages

from .obs_hooks import (TRACE, assert_no_flags, attach_health,
                        finish_trace, maybe_tracer)

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

# paper Table 2 (Gbps, op/s)
PAPER_SINGLE = {"efa": {65536: 16, 262144: 54, 1048576: 145, 33554432: 336},
                "cx7": {65536: 44, 262144: 116, 1048576: 245, 33554432: 378}}
PAPER_PAGED = {"efa": {1024: (17, 2.11e6), 8192: (138, 2.10e6),
                       16384: (274, 2.08e6), 65536: (364, 0.69e6)},
               "cx7": {1024: (91, 11.10e6), 8192: (320, 4.89e6),
                       16384: (367, 2.80e6), 65536: (370, 0.71e6)}}


def bench_single(nic: str, size: int, iters: int = 8) -> float:
    """Serial single-write throughput (Gbps)."""
    fab = Fabric(seed=0)
    monitor = attach_health(fab)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    src = np.zeros(size, np.uint8)
    dst = np.zeros(size, np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    t0 = fab.now
    state = {"n": 0}

    def issue() -> None:
        if state["n"] < iters:
            state["n"] += 1
            a.submit_single_write(size, None, (hs, 0), (dd, 0), on_done=issue)

    issue()
    t = fab.run() - t0
    assert_no_flags(monitor, f"bench_single({nic}, {size})")
    return size * iters * 8e-3 / t          # Gbps (us domain)


def bench_paged(nic: str, page: int, n_pages: int = 4096, trace_path=None,
                metrics_out=None):
    """Pipelined paged-write throughput (Gbps, op/s)."""
    fab = Fabric(seed=0)
    tracer = maybe_tracer(fab) if trace_path else None
    monitor = attach_health(fab)
    a = fab.add_engine("a", nic=nic)
    b = fab.add_engine("b", nic=nic)
    src = np.zeros(max(n_pages * page, 1), np.uint8)
    dst = np.zeros(max(n_pages * page, 1), np.uint8)
    hs, _ = a.reg_mr(src)
    _, dd = b.reg_mr(dst)
    idx = tuple(range(n_pages))
    t0 = fab.now
    a.submit_paged_writes(page, 1, (hs, Pages(idx, page)), (dd, Pages(idx, page)))
    t = fab.run() - t0
    assert_no_flags(monitor, f"bench_paged({nic}, {page})")
    if tracer is not None and metrics_out is not None:
        metrics_out["metrics"] = finish_trace(tracer, OUT_DIR, trace_path)
    return n_pages * page * 8e-3 / t, n_pages / (t * 1e-6)


def run(report) -> None:
    rows = {}
    tr_out = {}
    for nic in ("efa", "cx7"):
        for size, paper in PAPER_SINGLE[nic].items():
            gbps = bench_single(nic, size)
            rows[f"p2p_single_{nic}_{size >> 10}KiB"] = {
                "gbps": gbps, "paper_gbps": paper,
                "err_pct": 100 * (gbps - paper) / paper}
            report(f"p2p_single_{nic}_{size >> 10}KiB", gbps,
                   f"Gbps (paper {paper}; err {100 * (gbps - paper) / paper:+.0f}%)")
        for page, (paper_gbps, paper_ops) in PAPER_PAGED[nic].items():
            # the 8 KiB CX7 paged run is the canonical traced row
            tp = ("trace_p2p.json"
                  if TRACE and nic == "cx7" and page == 8192 else None)
            gbps, ops = bench_paged(nic, page, trace_path=tp,
                                    metrics_out=tr_out)
            rows[f"p2p_paged_{nic}_{page >> 10 or 1}KiB"] = {
                "gbps": gbps, "mops": ops / 1e6, "paper_gbps": paper_gbps,
                "paper_mops": paper_ops / 1e6,
                "err_pct": 100 * (gbps - paper_gbps) / paper_gbps}
            report(f"p2p_paged_{nic}_{page >> 10 or 1}KiB", gbps,
                   f"Gbps {ops / 1e6:.2f}Mop/s (paper {paper_gbps} Gbps "
                   f"{paper_ops / 1e6:.2f}M; err {100 * (gbps - paper_gbps) / paper_gbps:+.0f}%)")

    os.makedirs(OUT_DIR, exist_ok=True)
    doc = {
        "bench": "p2p",
        "config": {"single_iters": 8, "paged_n_pages": 4096,
                   "single_sizes": sorted(PAPER_SINGLE["efa"]),
                   "paged_pages": sorted(PAPER_PAGED["efa"])},
        "rows": rows,
    }
    if tr_out.get("metrics") is not None:
        doc["metrics"] = tr_out["metrics"]
    with open(os.path.join(OUT_DIR, "BENCH_p2p.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
