"""Chaos rows: data-plane fault injection under the real protocols.

Three scenarios, every byte real (verified bit-exact), every fault drawn
from the seeded :class:`repro.core.FaultPlan` so the rows replay
deterministically:

  rl_loss     — the staged RL weight update commits exactly-once under
                0% / 2% / 10% WR loss on one train->infer pair; rows show
                the retry tax on total time.
  rl_abort    — a mixed CX7->EFA pair degraded to 0.25x bandwidth drops
                every WR with a starved retry budget: the update aborts
                (commit withheld on every rank, staging released, audit
                clean) and, after the fault clears, the next update_id
                commits on the same cluster.
  kv_failover — a serving fleet (real reduced-stablelm compute) loses
                every KV handoff from one prefiller; requests re-route via
                XferFail escalation and all complete, with the TTFT
                overhead vs the clean fleet reported.
  ctrl_churn  — membership-churn storm (join + drain + crash + partition/
                re-join) under a seeded ctrl-SEND loss sweep with the
                reliability layer on (CtrlRetryPolicy everywhere): rows
                report partition->rejoin recovery time plus the exact
                booleans ``zero_leaked_pages`` / ``exactly_once_adoption``
                the perf gate matches.  Loss is injected only on
                peer<->ctrl and sched<->decoder pairs — the decoder->
                prefiller DispatchReq is the data-plane handshake, whose
                loss is the *data* fault model (kv_failover), not a
                retryable ctrl RPC.

Appends the rows to ``BENCH_rlweights.json`` / ``BENCH_scaling.json``
(run AFTER those modules: ``python -m benchmarks.run ... rlweights
scaling chaos``) so the perf-gate and trajectory tooling see chaos next
to the clean numbers.

Env knobs:
  BENCH_CHAOS_SMOKE=1   shrink the failover arrival train for CI
"""

from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

SMOKE = os.environ.get("BENCH_CHAOS_SMOKE", "") not in ("", "0")

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

LOSS_RATES = (0.0, 0.02, 0.10)
N_FAILOVER_REQS = 3 if SMOKE else 6

CHURN_LOSS_RATES = (0.0, 0.05, 0.10)
N_CHURN_REQS = 2 if SMOKE else 4          # per wave; two waves


def _rl_setup(nic: str = "cx7", infer_nic=None, seed: int = 11):
    from repro.rlweights import ParamMeta, compute_routing, make_cluster
    params = [ParamMeta(f"w{i}", (512, 128), 2) for i in range(6)]
    routes, sizes = compute_routing(params, 2, 2, infer_tp=1,
                                    quant_ratio=1.0)
    cl = make_cluster(2, 2, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic=nic, seed=seed,
                      infer_nic=infer_nic)
    return cl, routes


def rl_loss_sweep() -> Dict[str, Dict]:
    """Real-byte staged update vs WR loss rate on one train->infer pair."""
    from repro.core import FaultPlan
    from repro.rlweights import p2p_transfer, verify_contents
    rows: Dict[str, Dict] = {}
    for rate in LOSS_RATES:
        cl, routes = _rl_setup()
        plan = FaultPlan(cl.fabric, seed=2, timeout_us=400.0,
                         max_retries=16, backoff_us=25.0)
        if rate > 0.0:
            plan.inject("train0", "infer0", drop_prob=rate)
        stats = p2p_transfer(cl, routes, chunk_bytes=4096)
        rows[f"loss_{int(rate * 100)}pct"] = {
            "total_us": stats["total_us"],
            "committed": bool(stats["committed"]),
            "verified": bool(verify_contents(cl, routes)),
            "drops": plan.stats["drops"],
            "retries": plan.stats["retries"],
            "exhausted": plan.stats["exhausted"],
        }
    return rows


def rl_abort_recovery() -> Dict[str, Dict]:
    """Abort on a degraded mixed-NIC pair, then recover on the next update."""
    from repro.core import FaultPlan
    from repro.rlweights import p2p_transfer, verify_contents
    cl, routes = _rl_setup(nic="cx7", infer_nic="efa")
    cl.fabric.degrade_pair("train0", "infer0", bw_scale=0.25)
    plan = FaultPlan(cl.fabric, seed=3, timeout_us=300.0, max_retries=1,
                     backoff_us=20.0)
    plan.inject("train0", "infer0", drop_prob=1.0)
    t0 = cl.fabric.now
    stats = p2p_transfer(cl, routes, chunk_bytes=4096)
    abort = {
        "aborted": bool(stats["aborted"]),
        "committed": bool(stats["committed"]),
        "commits": sum(stats["commits"]),
        "abort_detect_us": cl.fabric.now - t0,
        "exhausted": plan.stats["exhausted"],
    }
    plan.clear()
    t1 = cl.fabric.now
    stats2 = p2p_transfer(cl, routes, chunk_bytes=4096, update_id=1)
    recovery = {
        "committed": bool(stats2["committed"]),
        "verified": bool(verify_contents(cl, routes)),
        "recovery_us": cl.fabric.now - t1,
    }
    return {"abort": abort, "recovery": recovery}


def kv_failover(faulty: bool) -> Dict[str, float]:
    """Serving fleet under total KV loss from one prefiller (or clean)."""
    import jax

    from repro.configs import get_config
    from repro.core import Fabric, FaultPlan
    from repro.ctrl import ControlPlane
    from repro.models import init_params
    from repro.serving import Decoder, Prefiller, Scheduler

    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=9)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=256)
    for p in ("p0", "p1"):
        Prefiller(fab, p, cfg, params, nic="efa", ctrl=ctrl,
                  max_renewals=256)
    Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=256)
    sched = Scheduler(fab, ctrl)
    if faulty:
        plan = FaultPlan(fab, seed=5, timeout_us=10_000.0, max_retries=1,
                         backoff_us=50.0)
        plan.inject("p0", "d0", drop_prob=1.0)
    rng = np.random.default_rng(4)
    rids = []

    def submit_all() -> None:
        # after membership settles, so round-robin spreads across BOTH
        # prefillers and the lossy one actually takes traffic
        rids.extend(sched.submit(rng.integers(0, cfg.vocab, size=24 + 2 * i),
                                 n_decode=2) for i in range(N_FAILOVER_REQS))

    t_submit = 1_000.0
    fab.loop.schedule(t_submit, submit_all)
    fab.run()
    done = [sched.completed[r] for r in rids if r in sched.completed]
    # ttft_us is per-attempt (decoder-side); end-to-end submit->done is the
    # number that shows the failover cost (timeout + re-route + re-prefill)
    e2es = [d["done_us"] - t_submit for d in done]
    return {
        "n_reqs": len(rids),
        "n_completed": len(done),
        "n_rerouted": len(sched.rerouted),
        "n_failed": len(sched.failed),
        "mean_ttft_us": float(np.mean([d["ttft_us"] for d in done]))
        if done else 0.0,
        "mean_e2e_us": float(np.mean(e2es)) if e2es else 0.0,
        "total_us": fab.now,
    }


def ctrl_churn(loss: float, cfg, params) -> Dict[str, object]:
    """Membership-churn storm under ``loss``-rate ctrl-SEND faults.

    Timeline (virtual us): requests at 1000 and 2200; d1 joins at 1500 and
    p2 at 2000; p1 is drained at 2500; d1 crashes at 3000; p0 is fully
    partitioned from the control plane at 6000 and healed at 24000 — its
    lease lapses, the scheduler re-routes with an epoch fence (late zombie
    WRITEs from p0 are rejected at d0), renew-retry exhaustion triggers the
    auto re-JOIN, and the fleet converges.
    """
    from repro.core import Fabric, FaultPlan
    from repro.ctrl import ControlPlane, CtrlRetryPolicy
    from repro.serving import Decoder, Prefiller, Scheduler

    fab = Fabric(seed=13)
    pol = CtrlRetryPolicy()
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=320, retry=pol)
    p0 = Prefiller(fab, "p0", cfg, params, nic="efa", ctrl=ctrl,
                   max_renewals=320, ctrl_retry=pol)
    p1 = Prefiller(fab, "p1", cfg, params, nic="efa", ctrl=ctrl,
                   max_renewals=320, ctrl_retry=pol)
    d0 = Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl,
                 max_renewals=320, ctrl_retry=pol)
    sched = Scheduler(fab, ctrl, retry=pol)
    plan = FaultPlan(fab, seed=17, timeout_us=5_000.0, max_retries=4,
                     backoff_us=50.0)

    def baseline(src: str, dst: str) -> None:
        if loss > 0.0:
            plan.inject_ctrl(src, dst, drop_prob=loss, dup_prob=loss / 2,
                             delay_prob=loss / 2, delay_us=300.0)
        else:
            plan.clear(src, dst)

    ctrl_pairs = [(n, "ctrl") for n in ("p0", "p1", "p2", "d0", "d1")]
    ctrl_pairs += [(b, a) for (a, b) in ctrl_pairs]
    ctrl_pairs += [("sched", "d0"), ("d0", "sched"), ("ctrl", "sched")]
    if loss > 0.0:
        for src, dst in ctrl_pairs:
            baseline(src, dst)

    rids: list = []
    rng = np.random.default_rng(6)
    late: Dict[str, object] = {}

    def submit_wave() -> None:
        rids.extend(sched.submit(rng.integers(0, cfg.vocab, size=24 + 2 * i),
                                 n_decode=2) for i in range(N_CHURN_REQS))

    fab.loop.schedule(1_000.0, submit_wave)
    fab.loop.schedule(1_500.0, lambda: late.update(d1=Decoder(
        fab, "d1", cfg, params, nic="efa", ctrl=ctrl, max_renewals=320,
        ctrl_retry=pol)))
    fab.loop.schedule(2_000.0, lambda: late.update(p2=Prefiller(
        fab, "p2", cfg, params, nic="efa", ctrl=ctrl, max_renewals=320,
        ctrl_retry=pol)))
    fab.loop.schedule(2_200.0, submit_wave)
    fab.loop.schedule(2_500.0, lambda: ctrl.drain("p1"))
    fab.loop.schedule(3_000.0, lambda: late["d1"].crash())

    def partition() -> None:
        plan.inject_ctrl("p0", "ctrl", drop_prob=1.0)
        plan.inject_ctrl("ctrl", "p0", drop_prob=1.0)

    def heal() -> None:
        baseline("p0", "ctrl")
        baseline("ctrl", "p0")

    fab.loop.schedule(6_000.0, partition)
    fab.loop.schedule(24_000.0, heal)

    # fixed-cadence membership probe (event count independent of faults):
    # times p0's removal from and return to the scheduler's view
    seen = {"t_removed": None, "t_rejoined": None}

    def probe() -> None:
        ids = set(sched.view.ids())
        if seen["t_removed"] is None:
            if "p0" not in ids:
                seen["t_removed"] = fab.now
        elif seen["t_rejoined"] is None and "p0" in ids:
            seen["t_rejoined"] = fab.now

    for k in range(160):
        fab.loop.schedule(6_000.0 + 250.0 * k, probe)

    fab.run()
    done = [sched.completed[r] for r in rids if r in sched.completed]
    # d1 crashed mid-run: its pool is dead memory, not a leak.  Every
    # *live* peer must have released every page.
    live_pools = [p0.pool, p1.pool, late["p2"].pool, d0.pool]
    zero_leaked = all(len(p._free) == p.n_pages for p in live_pools)
    exactly_once = (len(done) == len(rids)
                    and not (set(sched.completed) & set(sched.failed))
                    and len(sched.routing_log)
                    == len(set(sched.routing_log)))
    recovery = (seen["t_rejoined"] - seen["t_removed"]
                if seen["t_removed"] is not None
                and seen["t_rejoined"] is not None else -1.0)
    return {
        "n_reqs": len(rids),
        "n_completed": len(done),
        "n_rerouted": len(sched.rerouted),
        "n_failed": len(sched.failed),
        "recovery_us": float(recovery),
        "zero_leaked_pages": bool(zero_leaked),
        "exactly_once_adoption": bool(exactly_once),
        "ctrl_drops": plan.ctrl_stats["drops"],
        "ctrl_dups": plan.ctrl_stats["dups"],
        "ctrl_delays": plan.ctrl_stats["delays"],
        "submit_resends": sched.submit_resends,
        "dup_dropped": ctrl.stats["dup_dropped"],
        "rejoins": p0.client.rejoins,
        "replayed_dones": d0.replayed_dones,
        "total_us": fab.now,
    }


def _append_rows(fname: str, rows: Dict[str, Dict]) -> None:
    """Merge chaos rows into an existing BENCH_*.json (same formatting)."""
    path = os.path.join(OUT_DIR, fname)
    if not os.path.exists(path):
        return
    with open(path) as f:
        doc = json.load(f)
    doc.setdefault("rows", {}).update(rows)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def run(report) -> None:
    loss = rl_loss_sweep()
    for key, row in loss.items():
        report(f"chaos_rl_{key}", row["total_us"],
               f"us total; committed={row['committed']} "
               f"verified={row['verified']} ({row['drops']} drops, "
               f"{row['retries']} retries, {row['exhausted']} exhausted)")
    base = loss["loss_0pct"]["total_us"]
    worst = loss[f"loss_{int(LOSS_RATES[-1] * 100)}pct"]["total_us"]
    report("chaos_rl_retry_tax", worst / base,
           f"x slowdown at {LOSS_RATES[-1]:.0%} loss vs clean "
           f"(exactly-once commit held at every rate)")

    ar = rl_abort_recovery()
    report("chaos_rl_abort", ar["abort"]["abort_detect_us"],
           f"us to abort on dead 0.25x CX7->EFA pair; "
           f"commits={ar['abort']['commits']} (withheld on all ranks), "
           f"aborted={ar['abort']['aborted']}")
    report("chaos_rl_recovery_us", ar["recovery"]["recovery_us"],
           f"us for the next update_id on the healed cluster; "
           f"committed={ar['recovery']['committed']} "
           f"verified={ar['recovery']['verified']}")

    clean = kv_failover(faulty=False)
    chaos = kv_failover(faulty=True)
    report("chaos_kv_failover", chaos["mean_e2e_us"],
           f"us mean submit->done with every p0->d0 handoff lost "
           f"({chaos['n_completed']}/{chaos['n_reqs']} completed, "
           f"{chaos['n_rerouted']} rerouted, {chaos['n_failed']} failed "
           f"terminally) vs {clean['mean_e2e_us']:.0f}us clean")

    _append_rows("BENCH_rlweights.json", {
        **{f"chaos_{k}": v for k, v in loss.items()},
        "chaos_abort": ar["abort"],
        "chaos_recovery": ar["recovery"],
    })
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    churn_rows: Dict[str, Dict] = {}
    for rate in CHURN_LOSS_RATES:
        row = ctrl_churn(rate, cfg, params)
        key = f"chaos_ctrl_churn_{int(rate * 100)}pct"
        churn_rows[key] = row
        report(key, row["recovery_us"],
               f"us p0 partition->rejoin recovery at {rate:.0%} ctrl loss; "
               f"zero_leaked_pages={row['zero_leaked_pages']} "
               f"exactly_once={row['exactly_once_adoption']} "
               f"({row['n_completed']}/{row['n_reqs']} done, "
               f"{row['ctrl_drops']} ctrl drops, "
               f"{row['submit_resends']} submit resends, "
               f"rejoins={row['rejoins']})")

    _append_rows("BENCH_scaling.json", {
        "chaos_kv_failover": chaos,
        "chaos_kv_failover_clean_baseline": clean,
        **churn_rows,
    })
