"""Chaos rows: data-plane fault injection under the real protocols.

Three scenarios, every byte real (verified bit-exact), every fault drawn
from the seeded :class:`repro.core.FaultPlan` so the rows replay
deterministically:

  rl_loss     — the staged RL weight update commits exactly-once under
                0% / 2% / 10% WR loss on one train->infer pair; rows show
                the retry tax on total time.
  rl_abort    — a mixed CX7->EFA pair degraded to 0.25x bandwidth drops
                every WR with a starved retry budget: the update aborts
                (commit withheld on every rank, staging released, audit
                clean) and, after the fault clears, the next update_id
                commits on the same cluster.
  kv_failover — a serving fleet (real reduced-stablelm compute) loses
                every KV handoff from one prefiller; requests re-route via
                XferFail escalation and all complete, with the TTFT
                overhead vs the clean fleet reported.

Appends the rows to ``BENCH_rlweights.json`` / ``BENCH_scaling.json``
(run AFTER those modules: ``python -m benchmarks.run ... rlweights
scaling chaos``) so the perf-gate and trajectory tooling see chaos next
to the clean numbers.

Env knobs:
  BENCH_CHAOS_SMOKE=1   shrink the failover arrival train for CI
"""

from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

SMOKE = os.environ.get("BENCH_CHAOS_SMOKE", "") not in ("", "0")

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

LOSS_RATES = (0.0, 0.02, 0.10)
N_FAILOVER_REQS = 3 if SMOKE else 6


def _rl_setup(nic: str = "cx7", infer_nic=None, seed: int = 11):
    from repro.rlweights import ParamMeta, compute_routing, make_cluster
    params = [ParamMeta(f"w{i}", (512, 128), 2) for i in range(6)]
    routes, sizes = compute_routing(params, 2, 2, infer_tp=1,
                                    quant_ratio=1.0)
    cl = make_cluster(2, 2, max(sizes["train"].values()),
                      max(sizes["infer"].values()), nic=nic, seed=seed,
                      infer_nic=infer_nic)
    return cl, routes


def rl_loss_sweep() -> Dict[str, Dict]:
    """Real-byte staged update vs WR loss rate on one train->infer pair."""
    from repro.core import FaultPlan
    from repro.rlweights import p2p_transfer, verify_contents
    rows: Dict[str, Dict] = {}
    for rate in LOSS_RATES:
        cl, routes = _rl_setup()
        plan = FaultPlan(cl.fabric, seed=2, timeout_us=400.0,
                         max_retries=16, backoff_us=25.0)
        if rate > 0.0:
            plan.inject("train0", "infer0", drop_prob=rate)
        stats = p2p_transfer(cl, routes, chunk_bytes=4096)
        rows[f"loss_{int(rate * 100)}pct"] = {
            "total_us": stats["total_us"],
            "committed": bool(stats["committed"]),
            "verified": bool(verify_contents(cl, routes)),
            "drops": plan.stats["drops"],
            "retries": plan.stats["retries"],
            "exhausted": plan.stats["exhausted"],
        }
    return rows


def rl_abort_recovery() -> Dict[str, Dict]:
    """Abort on a degraded mixed-NIC pair, then recover on the next update."""
    from repro.core import FaultPlan
    from repro.rlweights import p2p_transfer, verify_contents
    cl, routes = _rl_setup(nic="cx7", infer_nic="efa")
    cl.fabric.degrade_pair("train0", "infer0", bw_scale=0.25)
    plan = FaultPlan(cl.fabric, seed=3, timeout_us=300.0, max_retries=1,
                     backoff_us=20.0)
    plan.inject("train0", "infer0", drop_prob=1.0)
    t0 = cl.fabric.now
    stats = p2p_transfer(cl, routes, chunk_bytes=4096)
    abort = {
        "aborted": bool(stats["aborted"]),
        "committed": bool(stats["committed"]),
        "commits": sum(stats["commits"]),
        "abort_detect_us": cl.fabric.now - t0,
        "exhausted": plan.stats["exhausted"],
    }
    plan.clear()
    t1 = cl.fabric.now
    stats2 = p2p_transfer(cl, routes, chunk_bytes=4096, update_id=1)
    recovery = {
        "committed": bool(stats2["committed"]),
        "verified": bool(verify_contents(cl, routes)),
        "recovery_us": cl.fabric.now - t1,
    }
    return {"abort": abort, "recovery": recovery}


def kv_failover(faulty: bool) -> Dict[str, float]:
    """Serving fleet under total KV loss from one prefiller (or clean)."""
    import jax

    from repro.configs import get_config
    from repro.core import Fabric, FaultPlan
    from repro.ctrl import ControlPlane
    from repro.models import init_params
    from repro.serving import Decoder, Prefiller, Scheduler

    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=9)
    ctrl = ControlPlane(fab, nic="efa", max_sweeps=256)
    for p in ("p0", "p1"):
        Prefiller(fab, p, cfg, params, nic="efa", ctrl=ctrl,
                  max_renewals=256)
    Decoder(fab, "d0", cfg, params, nic="efa", ctrl=ctrl, max_renewals=256)
    sched = Scheduler(fab, ctrl)
    if faulty:
        plan = FaultPlan(fab, seed=5, timeout_us=10_000.0, max_retries=1,
                         backoff_us=50.0)
        plan.inject("p0", "d0", drop_prob=1.0)
    rng = np.random.default_rng(4)
    rids = []

    def submit_all() -> None:
        # after membership settles, so round-robin spreads across BOTH
        # prefillers and the lossy one actually takes traffic
        rids.extend(sched.submit(rng.integers(0, cfg.vocab, size=24 + 2 * i),
                                 n_decode=2) for i in range(N_FAILOVER_REQS))

    t_submit = 1_000.0
    fab.loop.schedule(t_submit, submit_all)
    fab.run()
    done = [sched.completed[r] for r in rids if r in sched.completed]
    # ttft_us is per-attempt (decoder-side); end-to-end submit->done is the
    # number that shows the failover cost (timeout + re-route + re-prefill)
    e2es = [d["done_us"] - t_submit for d in done]
    return {
        "n_reqs": len(rids),
        "n_completed": len(done),
        "n_rerouted": len(sched.rerouted),
        "n_failed": len(sched.failed),
        "mean_ttft_us": float(np.mean([d["ttft_us"] for d in done]))
        if done else 0.0,
        "mean_e2e_us": float(np.mean(e2es)) if e2es else 0.0,
        "total_us": fab.now,
    }


def _append_rows(fname: str, rows: Dict[str, Dict]) -> None:
    """Merge chaos rows into an existing BENCH_*.json (same formatting)."""
    path = os.path.join(OUT_DIR, fname)
    if not os.path.exists(path):
        return
    with open(path) as f:
        doc = json.load(f)
    doc.setdefault("rows", {}).update(rows)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def run(report) -> None:
    loss = rl_loss_sweep()
    for key, row in loss.items():
        report(f"chaos_rl_{key}", row["total_us"],
               f"us total; committed={row['committed']} "
               f"verified={row['verified']} ({row['drops']} drops, "
               f"{row['retries']} retries, {row['exhausted']} exhausted)")
    base = loss["loss_0pct"]["total_us"]
    worst = loss[f"loss_{int(LOSS_RATES[-1] * 100)}pct"]["total_us"]
    report("chaos_rl_retry_tax", worst / base,
           f"x slowdown at {LOSS_RATES[-1]:.0%} loss vs clean "
           f"(exactly-once commit held at every rate)")

    ar = rl_abort_recovery()
    report("chaos_rl_abort", ar["abort"]["abort_detect_us"],
           f"us to abort on dead 0.25x CX7->EFA pair; "
           f"commits={ar['abort']['commits']} (withheld on all ranks), "
           f"aborted={ar['abort']['aborted']}")
    report("chaos_rl_recovery_us", ar["recovery"]["recovery_us"],
           f"us for the next update_id on the healed cluster; "
           f"committed={ar['recovery']['committed']} "
           f"verified={ar['recovery']['verified']}")

    clean = kv_failover(faulty=False)
    chaos = kv_failover(faulty=True)
    report("chaos_kv_failover", chaos["mean_e2e_us"],
           f"us mean submit->done with every p0->d0 handoff lost "
           f"({chaos['n_completed']}/{chaos['n_reqs']} completed, "
           f"{chaos['n_rerouted']} rerouted, {chaos['n_failed']} failed "
           f"terminally) vs {clean['mean_e2e_us']:.0f}us clean")

    _append_rows("BENCH_rlweights.json", {
        **{f"chaos_{k}": v for k, v in loss.items()},
        "chaos_abort": ar["abort"],
        "chaos_recovery": ar["recovery"],
    })
    _append_rows("BENCH_scaling.json", {
        "chaos_kv_failover": chaos,
        "chaos_kv_failover_clean_baseline": clean,
    })
