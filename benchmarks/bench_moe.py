"""Fig. 9/10 + Table 6: MoE dispatch/combine latency and derived decode speed.

DeepSeek-V3/R1 microbenchmark geometry (§7.4.3): 7168-byte fp8 tokens +
56 fp32 scales dispatched to 8 random experts; decode batch 128; prefill
chunk 4096.  EP in {8, 16, 32, 64}, 8 GPUs/node, EFA and CX-7.

A DeepEP-style baseline rides along: ordered-RC per-token writes (no
private/contiguous two-phase, more packets, no route exchange needed
because RC ordering carries implicit structure) — modeled as one WRITE per
token with the same fabric.

Emits ``BENCH_moe.json`` (config + paper Fig. 9/10 targets + per-row
stats, including the per-peer WR budget actually used) into the bench
output dir for perf-trajectory tracking across PRs.

Env knobs:
  BENCH_MOE_SMOKE=1   reduced scale for the CI bench-smoke job
"""

from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from repro.core import Fabric, ScatterDst
from repro.moekit import MoEConfig, make_endpoints

from .obs_hooks import (TRACE, assert_no_flags, attach_health,
                        finish_trace, maybe_tracer)

TOKEN_BYTES = 7168 + 56 * 4       # fp8 payload + fp32 scales
TOP_K = 8
E_TOTAL = 256                      # DeepSeek-V3 routed experts (EP<=64 -> >=4/rank)

SMOKE = os.environ.get("BENCH_MOE_SMOKE") == "1"
EP_SWEEP = (8, 16) if SMOKE else (8, 16, 32, 64)
DECODE_ROUNDS = 1 if SMOKE else 3

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

# paper Fig. 9 anchors (us, EP64 decode, approximate bar heights)
PAPER_EP64 = {"cx7": {"dispatch": 163.0, "combine": 318.0},
              "efa": {"dispatch": 212.0, "combine": 413.0}}


def _inputs(cfg: MoEConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    tokens, eids = [], []
    for r in range(cfg.n_ranks):
        tokens.append(rng.integers(0, 255, (cfg.max_tokens, cfg.token_bytes),
                                   dtype=np.uint8))
        eids.append(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.max_tokens)]).astype(np.int32))
    return tokens, eids


def bench_dispatch_combine(ep: int, batch: int, nic: str,
                           t_priv: int = 32, rounds: int = 3,
                           nvlink: bool = False,
                           nics=None, trace_path=None) -> Dict[str, float]:
    cfg = MoEConfig(n_ranks=ep, n_experts=max(E_TOTAL, ep), top_k=TOP_K,
                    max_tokens=batch, token_bytes=TOKEN_BYTES, t_priv=t_priv)
    fab = Fabric(seed=1)
    tracer = maybe_tracer(fab) if trace_path else None
    monitor = attach_health(fab)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=8,
                         nvlink=nvlink, nics=nics)
    disp, comb = [], []
    disp_wr_peer = 0.0
    for rnd in range(rounds):
        tokens, eids = _inputs(cfg, seed=rnd)
        ctxs: Dict[int, Dict] = {}
        start = [e.engine.batch_stats.snapshot_by_dst() for e in eps]
        disp_wrs = {"max": 0}

        def make_cb(r):
            def cb():
                # dispatch has fully posted for rank r here (its combine
                # has not) — snapshot the dispatch-phase per-peer WR
                # budget: <= 1 route + 2 data WRITEs per peer (invariant)
                now = eps[r].engine.batch_stats.snapshot_by_dst()
                disp_wrs["max"] = max(disp_wrs["max"], max(
                    (now.get(a, 0) - start[r].get(a, 0) for a in now),
                    default=0))
                # combine echoes the received tokens straight back
                slabs = eps[r].gather_expert_tokens(ctxs[r])
                eps[r].combine(ctxs[r], slabs, lambda: None)
            return cb

        for r in range(ep):
            ctxs[r] = eps[r].dispatch(tokens[r], eids[r], make_cb(r))
        fab.run()
        disp.append(np.median([e.stats["dispatch_us"] for e in eps]))
        comb.append(np.median([e.stats["combine_us"] for e in eps]))
        disp_wr_peer = max(disp_wr_peer, disp_wrs["max"])
    assert_no_flags(monitor, f"bench_dispatch_combine(ep={ep}, {nic})")
    out = {"dispatch_us": float(np.median(disp)),
           "combine_us": float(np.median(comb)),
           "dispatch_wr_per_peer": float(disp_wr_peer),
           "enqueues": int(sum(e.engine.batch_stats.batches for e in eps)),
           "wrs": int(sum(e.engine.batch_stats.wrs for e in eps))}
    if tracer is not None:
        out["trace_metrics"] = finish_trace(tracer, OUT_DIR, trace_path)
    return out


def bench_deepep_style(ep: int, batch: int, nic: str = "cx7") -> Dict[str, float]:
    """Ordered-RC per-token WRITEs (DeepEP's strategy, §6.4): lower latency
    to first transfer, more per-token work and packets."""
    cfg = MoEConfig(n_ranks=ep, n_experts=max(E_TOTAL, ep), top_k=TOP_K,
                    max_tokens=batch, token_bytes=TOKEN_BYTES)
    fab = Fabric(seed=2)
    monitor = attach_health(fab)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=8)
    tokens, eids = _inputs(cfg)
    done = []
    t0 = fab.now
    GPU_PER_TOKEN_US = 0.1      # SM-driven per-token issue cost
    for r in range(ep):
        e = eps[r]
        # per-token staging region (the bulk path needs none: PayloadDst)
        sbuf = np.zeros(cfg.max_tokens * cfg.token_bytes, np.uint8)
        h_send, _ = e.engine.reg_mr(sbuf)
        fe = eids[r].reshape(-1)
        ft = np.repeat(np.arange(cfg.max_tokens), cfg.top_k)
        dest = fe // cfg.e_local
        # one WRITE per token copy, issued progressively (no route exchange)
        for i in np.argsort(dest, kind="stable"):
            d = int(dest[i])
            sd = ScatterDst(len=cfg.token_bytes, src=int(ft[i]) * cfg.token_bytes,
                            dst=(eps[d].d_shared, int(i) * cfg.token_bytes))
            fab.loop.schedule(i * GPU_PER_TOKEN_US,
                              lambda e=e, sd=sd, h=h_send: e.engine.submit_scatter(
                                  h, [sd], imm=0x99))
    # receiver: every rank expects its incoming token count
    for r in range(ep):
        incoming = sum(int(((eids[s] // cfg.e_local) == r).sum())
                       for s in range(ep))
        eps[r].engine.expect_imm_count(0x99, incoming,
                                       lambda: done.append(fab.now))
    t = fab.run()
    assert_no_flags(monitor, f"bench_deepep_style(ep={ep}, {nic})")
    return {"dispatch_us": (np.median(done) - t0) if done else t}


def run(report) -> None:
    summary: Dict[str, Dict] = {}
    trace_metrics = None
    # EP32 cx7 decode is the canonical traced row (EP16 in smoke sweeps)
    trace_ep = 32 if 32 in EP_SWEEP else EP_SWEEP[-1]

    def keep(name: str, row: Dict, value_key: str = "dispatch_us") -> None:
        summary[name] = {k: v for k, v in row.items()
                         if isinstance(v, (int, float, bool))}

    for nic in ("cx7", "efa"):
        for ep in EP_SWEEP:
            tp = ("trace_moe.json"
                  if TRACE and nic == "cx7" and ep == trace_ep else None)
            r = bench_dispatch_combine(ep, 128, nic, rounds=DECODE_ROUNDS,
                                       trace_path=tp)
            if tp and r.get("trace_metrics"):
                trace_metrics = r["trace_metrics"]
            keep(f"moe_decode_ep{ep}_{nic}", r)
            note = ""
            if ep == 64:
                p = PAPER_EP64[nic]
                note = (f" (paper ~{p['dispatch']:.0f}/{p['combine']:.0f}us)")
            report(f"moe_decode_ep{ep}_{nic}_dispatch", r["dispatch_us"],
                   f"us dispatch; combine {r['combine_us']:.0f}us; "
                   f"{r['dispatch_wr_per_peer']:.0f} dispatch WRs/peer "
                   f"(<=1 route + 2 data){note}")
    # DeepEP-style ordered-RC baseline at EP32 decode
    dep = 16 if SMOKE else 32
    d = bench_deepep_style(dep, 128, "cx7")
    ours = bench_dispatch_combine(dep, 128, "cx7", rounds=DECODE_ROUNDS)
    keep(f"moe_deepep_style_ep{dep}", d)
    report(f"moe_deepep_style_ep{dep}", d["dispatch_us"],
           f"us per-token-RC dispatch vs ours {ours['dispatch_us']:.0f}us "
           f"(bulk transfers win at scale)")
    # prefill-sized chunk (Fig. 10): 4096 tokens
    pre = bench_dispatch_combine(16, 4096 // 16, "cx7", rounds=1)
    keep("moe_prefill_ep16_cx7", pre)
    report("moe_prefill_ep16_cx7", pre["dispatch_us"],
           f"us dispatch (256 tok/rank chunk); combine {pre['combine_us']:.0f}us")
    # NVLink intra-node rows (paper §6: same-node payloads ride NVLink while
    # the NIC keeps cross-node traffic) — same geometry as the Fig. 9 rows
    for nic in ("cx7", "efa"):
        for ep in EP_SWEEP:
            base = summary[f"moe_decode_ep{ep}_{nic}"]
            # same round count as the all-NIC rows so the medians compare
            r = bench_dispatch_combine(ep, 128, nic, rounds=DECODE_ROUNDS,
                                       nvlink=True)
            keep(f"moe_decode_ep{ep}_{nic}_nvl", r)
            report(f"moe_decode_ep{ep}_{nic}_nvl", r["dispatch_us"],
                   f"us dispatch w/ NVLink intra-node; combine "
                   f"{r['combine_us']:.0f}us; all-NIC row "
                   f"{base['dispatch_us']:.0f}us dispatch")
    # Holmes-style mixed cluster: node0 ranks on CX7, node1 ranks on EFA,
    # NVLink inside each node; cross-node pairs ride the derived x:cx7+efa200
    # preset (bottleneck bw, summed latency, SRD jitter survives)
    mep = 16
    mixed = bench_dispatch_combine(
        mep, 128, "cx7", rounds=1, nvlink=True,
        nics=["cx7"] * 8 + ["efa"] * (mep - 8))
    keep(f"moe_decode_ep{mep}_mixed_cx7_efa", mixed)
    report(f"moe_decode_ep{mep}_mixed_cx7_efa", mixed["dispatch_us"],
           f"us dispatch, mixed CX7+EFA nodes w/ NVLink; combine "
           f"{mixed['combine_us']:.0f}us (cross-cluster pairs on derived "
           f"x:cx7+efa200 cost model)")
    if not SMOKE:
        bench_dual_batch_overlap(report, summary)

    os.makedirs(OUT_DIR, exist_ok=True)
    doc = {
        "bench": "moe",
        "smoke": SMOKE,
        "config": {"token_bytes": TOKEN_BYTES, "top_k": TOP_K,
                   "n_experts": E_TOTAL, "decode_batch": 128,
                   "prefill_chunk": 4096, "ep_sweep": list(EP_SWEEP),
                   "rounds": DECODE_ROUNDS, "t_priv": 32},
        "paper_us_ep64": PAPER_EP64,
        "rows": summary,
    }
    if trace_metrics is not None:
        doc["metrics"] = trace_metrics
    with open(os.path.join(OUT_DIR, "BENCH_moe.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


# DeepSeek-V3-class decode compute per token per MoE layer (us) — attention
# + shared expert + grouped GEMM at EP=DP=64 (derived from the paper's ~32
# tok/s end-to-end at batch 128 over 61 layers).
COMPUTE_US_PER_TOKEN = 7.0


def bench_dual_batch_overlap(report, summary=None) -> None:
    """Table 7 analog: dual-batch overlap pipelines one half-batch's compute
    with the other's dispatch/combine.  Effective per-layer time:
      no overlap: t_comp(B) + t_comm(B)
      dual-batch: t_comp(B/2) + t_comm(B/2) + max(t_comp(B/2), t_comm(B/2))
    Low-latency kernels gain modestly at large B; a high-latency
    implementation (pplx-style, modeled as 8x our comm latency) DEGRADES —
    the paper's conclusion that dispatch latency still matters even in
    throughput regimes."""
    for batch in (128, 64, 32):
        r_full = bench_dispatch_combine(64, batch, "efa", rounds=2)
        r_half = bench_dispatch_combine(64, batch // 2, "efa", rounds=2)
        comm_f = r_full["dispatch_us"] + r_full["combine_us"]
        comm_h = r_half["dispatch_us"] + r_half["combine_us"]
        comp_f = COMPUTE_US_PER_TOKEN * batch
        comp_h = comp_f / 2
        t_no = comp_f + comm_f
        t_dual = comp_h + comm_h + max(comp_h, comm_h)
        ours = t_no / t_dual
        # high-latency implementation: same compute, 8x comm
        t_no_hl = comp_f + 8 * comm_f
        t_dual_hl = comp_h + 8 * comm_h + max(comp_h, 8 * comm_h)
        theirs = t_no_hl / t_dual_hl
        if summary is not None:
            summary[f"dual_batch_overlap_b{batch}"] = {
                "dual_us": t_dual, "no_overlap_us": t_no,
                "gain_ours": ours, "gain_8x_comm": theirs}
        report(f"dual_batch_overlap_b{batch}", t_dual,
               f"us/layer dual-batch vs {t_no:.0f} no-overlap "
               f"(gain {ours:.2f}x ours; {theirs:.2f}x at 8x comm latency; "
               f"paper: modest gains for ours, degradation for pplx)")
