"""Fig. 9/10 + Table 6: MoE dispatch/combine latency and derived decode speed.

DeepSeek-V3/R1 microbenchmark geometry (§7.4.3): 7168-byte fp8 tokens +
56 fp32 scales dispatched to 8 random experts; decode batch 128; prefill
chunk 4096.  EP in {8, 16, 32, 64}, 8 GPUs/node, EFA and CX-7.

A DeepEP-style baseline rides along: ordered-RC per-token writes (no
private/contiguous two-phase, more packets, no route exchange needed
because RC ordering carries implicit structure) — modeled as one WRITE per
token with the same fabric.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import Fabric, ScatterDst
from repro.moekit import MoEConfig, MoEEndpoint, make_endpoints

TOKEN_BYTES = 7168 + 56 * 4       # fp8 payload + fp32 scales
TOP_K = 8
E_TOTAL = 256                      # DeepSeek-V3 routed experts (EP<=64 -> >=4/rank)


def _inputs(cfg: MoEConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    tokens, eids = [], []
    for r in range(cfg.n_ranks):
        tokens.append(rng.integers(0, 255, (cfg.max_tokens, cfg.token_bytes),
                                   dtype=np.uint8))
        eids.append(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.max_tokens)]).astype(np.int32))
    return tokens, eids


def bench_dispatch_combine(ep: int, batch: int, nic: str,
                           t_priv: int = 32, rounds: int = 3) -> Dict[str, float]:
    cfg = MoEConfig(n_ranks=ep, n_experts=max(E_TOTAL, ep), top_k=TOP_K,
                    max_tokens=batch, token_bytes=TOKEN_BYTES, t_priv=t_priv)
    fab = Fabric(seed=1)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=8)
    disp, comb = [], []
    for rnd in range(rounds):
        tokens, eids = _inputs(cfg, seed=rnd)
        state = {"d": 0}

        def make_cb(r):
            def cb():
                state["d"] += 1
                # combine echoes the received tokens straight back
                ctx = eps[r]._last_ctx
                slabs = eps[r].gather_expert_tokens(ctx)
                eps[r].combine(ctx, slabs, lambda: None)
            return cb

        for r in range(ep):
            eps[r].dispatch(tokens[r], eids[r], make_cb(r))
        fab.run()
        disp.append(np.median([e.stats["dispatch_us"] for e in eps]))
        comb.append(np.median([e.stats["combine_us"] for e in eps]))
    return {"dispatch_us": float(np.median(disp)),
            "combine_us": float(np.median(comb))}


def bench_deepep_style(ep: int, batch: int, nic: str = "cx7") -> Dict[str, float]:
    """Ordered-RC per-token WRITEs (DeepEP's strategy, §6.4): lower latency
    to first transfer, more per-token work and packets."""
    cfg = MoEConfig(n_ranks=ep, n_experts=max(E_TOTAL, ep), top_k=TOP_K,
                    max_tokens=batch, token_bytes=TOKEN_BYTES)
    fab = Fabric(seed=2)
    eps = make_endpoints(fab, cfg, nic=nic, gpus_per_node=8)
    tokens, eids = _inputs(cfg)
    done = []
    t0 = fab.now
    GPU_PER_TOKEN_US = 0.1      # SM-driven per-token issue cost
    for r in range(ep):
        e = eps[r]
        fe = eids[r].reshape(-1)
        ft = np.repeat(np.arange(cfg.max_tokens), cfg.top_k)
        dest = fe // cfg.e_local
        # one WRITE per token copy, issued progressively (no route exchange)
        for i in np.argsort(dest, kind="stable"):
            d = int(dest[i])
            sd = ScatterDst(len=cfg.token_bytes, src=int(ft[i]) * cfg.token_bytes,
                            dst=(eps[d].d_shared, int(i) * cfg.token_bytes))
            fab.loop.schedule(i * GPU_PER_TOKEN_US,
                              lambda e=e, sd=sd: e.engine.submit_scatter(
                                  e.h_send, [sd], imm=0x99))
    # receiver: every rank expects its incoming token count
    for r in range(ep):
        incoming = sum(int(((eids[s] // cfg.e_local) == r).sum())
                       for s in range(ep))
        eps[r].engine.expect_imm_count(0x99, incoming,
                                       lambda: done.append(fab.now))
    t = fab.run()
    return {"dispatch_us": (np.median(done) - t0) if done else t}


# paper Fig. 9 anchors (us, EP64 decode, approximate bar heights)
PAPER_EP64 = {"cx7": {"dispatch": 163.0, "combine": 318.0},
              "efa": {"dispatch": 212.0, "combine": 413.0}}


def run(report) -> None:
    for nic in ("cx7", "efa"):
        for ep in (8, 16, 32, 64):
            r = bench_dispatch_combine(ep, 128, nic)
            note = ""
            if ep == 64:
                p = PAPER_EP64[nic]
                note = (f" (paper ~{p['dispatch']:.0f}/{p['combine']:.0f}us)")
            report(f"moe_decode_ep{ep}_{nic}_dispatch", r["dispatch_us"],
                   f"us dispatch; combine {r['combine_us']:.0f}us{note}")
    # DeepEP-style ordered-RC baseline at EP32 decode
    d = bench_deepep_style(32, 128, "cx7")
    ours = bench_dispatch_combine(32, 128, "cx7")
    report("moe_deepep_style_ep32", d["dispatch_us"],
           f"us per-token-RC dispatch vs ours {ours['dispatch_us']:.0f}us "
           f"(bulk transfers win at scale)")
    # prefill-sized chunk (Fig. 10): 4096 tokens
    pre = bench_dispatch_combine(16, 4096 // 16, "cx7", rounds=1)
    report("moe_prefill_ep16_cx7", pre["dispatch_us"],
           f"us dispatch (256 tok/rank chunk); combine {pre['combine_us']:.0f}us")
    bench_dual_batch_overlap(report)


# DeepSeek-V3-class decode compute per token per MoE layer (us) — attention
# + shared expert + grouped GEMM at EP=DP=64 (derived from the paper's ~32
# tok/s end-to-end at batch 128 over 61 layers).
COMPUTE_US_PER_TOKEN = 7.0


def bench_dual_batch_overlap(report) -> None:
    """Table 7 analog: dual-batch overlap pipelines one half-batch's compute
    with the other's dispatch/combine.  Effective per-layer time:
      no overlap: t_comp(B) + t_comm(B)
      dual-batch: t_comp(B/2) + t_comm(B/2) + max(t_comp(B/2), t_comm(B/2))
    Low-latency kernels gain modestly at large B; a high-latency
    implementation (pplx-style, modeled as 8x our comm latency) DEGRADES —
    the paper's conclusion that dispatch latency still matters even in
    throughput regimes."""
    for batch in (128, 64, 32):
        r_full = bench_dispatch_combine(64, batch, "efa", rounds=2)
        r_half = bench_dispatch_combine(64, batch // 2, "efa", rounds=2)
        comm_f = r_full["dispatch_us"] + r_full["combine_us"]
        comm_h = r_half["dispatch_us"] + r_half["combine_us"]
        comp_f = COMPUTE_US_PER_TOKEN * batch
        comp_h = comp_f / 2
        t_no = comp_f + comm_f
        t_dual = comp_h + comm_h + max(comp_h, comm_h)
        ours = t_no / t_dual
        # high-latency implementation: same compute, 8x comm
        t_no_hl = comp_f + 8 * comm_f
        t_dual_hl = comp_h + 8 * comm_h + max(comp_h, 8 * comm_h)
        theirs = t_no_hl / t_dual_hl
        report(f"dual_batch_overlap_b{batch}", t_dual,
               f"us/layer dual-batch vs {t_no:.0f} no-overlap "
               f"(gain {ours:.2f}x ours; {theirs:.2f}x at 8x comm latency; "
               f"paper: modest gains for ours, degradation for pplx)")
