"""Elastic prefill scaling over the control plane (§4 "dynamic scaling").

ONE simulated timeline, four acts, all routing through PeerRegistry epoch
views (the scheduler holds no static peer list):

  A  overload   — a single prefiller takes an arrival train faster than its
                  service rate; queue depth and TTFT climb.
  B  scale-up   — the Autoscaler sees the depth and spawns a second
                  prefiller, which JOINs the control plane (epoch bump) and
                  absorbs traffic; TTFT recovers.
  C  scale-down — once idle, the Autoscaler drains the least-loaded
                  prefiller: in-flight work finishes, every KV page is
                  freed, the peer LEAVEs.  Zero leaked pages is asserted.
  D  failover   — the surviving prefiller crashes mid-burst (stops renewing
                  its lease); lease expiry marks it dead, in-flight requests
                  are cancelled at their decoders and re-queued, the
                  Autoscaler spawns a replacement, and every post-failure
                  request completes.

``BENCH_SCALING_SMOKE=1`` shrinks the arrival trains for the CI smoke job.
Model compute is real (reduced stablelm); all times are virtual us.
"""

from __future__ import annotations

import json
import os

import numpy as np

from .obs_hooks import assert_no_flags, attach_health, finish_trace, maybe_tracer

SMOKE = os.environ.get("BENCH_SCALING_SMOKE", "") not in ("", "0")

OUT_DIR = os.environ.get(
    "BENCH_OUT", os.path.join(os.path.dirname(__file__), "out"))

GAP_US = 60.0            # arrival spacing (service time is ~100 us/req)
LAYER_US = 50.0
# TTFT SLO for the tracker: between the scaled p95 (~164 us) and the
# overloaded p95 (~332 us), so the overload/failover phases breach and the
# scaled phase recovers — the closed loop the SloTracker rows demonstrate
TTFT_SLO_US = 250.0


def run_timeline(n_a: int, n_b: int, n_d: int, *, prompt_len: int = 24,
                 n_decode: int = 2, nic: str = "efa", seed: int = 7) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core import Fabric
    from repro.ctrl import Autoscaler, ControlPlane, ScalingPolicy
    from repro.models import init_params
    from repro.serving import Decoder, Prefiller, Scheduler, SloTracker

    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fab = Fabric(seed=seed)
    # traces the whole elastic timeline (ctrl instants + autoscale decisions)
    tracer = maybe_tracer(fab)
    monitor = attach_health(fab)
    ctrl = ControlPlane(fab, nic=nic, lease_us=600.0, sweep_us=200.0,
                        max_sweeps=150)
    prefillers = []

    def spawn(i: int) -> None:
        prefillers.append(Prefiller(
            fab, f"p{i}", cfg, params, nic=nic, ctrl=ctrl,
            layer_compute_us=LAYER_US, renew_us=200.0, max_renewals=150))

    spawn(0)
    decoders = [Decoder(fab, f"d{i}", cfg, params, nic=nic, ctrl=ctrl,
                        renew_us=200.0, max_renewals=150) for i in range(2)]
    slo = SloTracker(fab, ttft_slo_us=TTFT_SLO_US)
    sched = Scheduler(fab, ctrl, slo=slo)
    scaler = Autoscaler(
        ctrl, sched, spawn,
        policy=ScalingPolicy(queue_high=3, idle_ticks_down=3,
                             min_prefillers=1, max_prefillers=4,
                             cooldown_us=600.0),
        tick_us=150.0, max_ticks=150, next_index=1)

    rng = np.random.default_rng(seed)
    phases: dict = {}

    def arrivals(t0: float, n: int, phase: str) -> None:
        rids: list = []
        phases[phase] = rids
        for i in range(n):
            ids = rng.integers(0, cfg.vocab, size=prompt_len)
            fab.loop.schedule_at(t0 + i * GAP_US, lambda ids=ids: rids.append(
                sched.submit(ids, n_decode=n_decode)))

    t_b = n_a * GAP_US + 360.0
    t_d = t_b + n_b * GAP_US + 1800.0   # leaves an idle window for scale-down
    arrivals(0.0, n_a, "A")
    arrivals(t_b, n_b, "B")
    arrivals(t_d, n_d, "D")
    # crash every live prefiller shortly into phase D: leases lapse, the
    # control plane declares them dead, and the autoscaler must replace them
    fab.loop.schedule_at(t_d + 100.0, lambda: [
        p.crash() for p in prefillers
        if p.alive and p.client is not None and not p.client.left])
    fab.run()

    # -- acceptance checks (the §4 dynamic-scaling contract) ----------------
    n_total = n_a + n_b + n_d
    assert len(sched.completed) == n_total, \
        f"{len(sched.completed)}/{n_total} requests completed"
    ups = [d for d in scaler.decisions if d[1] == "up"]
    downs = [d for d in scaler.decisions if d[1] == "down"]
    assert ups, "autoscaler never scaled up"
    assert downs, "autoscaler never scaled down"
    # a joined-mid-run peer served traffic
    joined = {f"p{i}" for i in range(1, len(prefillers))}
    served_by = {r["prefiller"] for r in sched.completed.values()}
    assert served_by & joined, f"no joined peer served traffic ({served_by})"
    # drained peers left cleanly with zero leaked KV pages
    drained = [p for p in prefillers if p.client.left and p.alive]
    assert drained, "no peer completed a drain"
    for p in drained:
        assert p.inflight == 0 and len(p.pool._free) == p.pool.n_pages, \
            f"{p.client.peer_id} leaked pages through its drain"
    # crash failover: post-failure requests were re-routed and completed
    assert sched.rerouted, "crash did not force any re-route"
    crashed = {p.client.peer_id for p in prefillers if not p.alive}
    for rid in phases["D"]:
        assert sched.completed[rid]["prefiller"] not in crashed
    # decoders end clean: all pages + tail slots back
    for d in decoders:
        assert len(d.pool._free) == d.pool.n_pages
        assert len(d._tail_free) == 16 and not d._pending
    # every route went through an epoch view, and epochs only moved forward
    assert len(sched.routing_log) >= n_total
    assert sched.view_epochs == sorted(sched.view_epochs)
    assert len(set(sched.view_epochs)) == len(sched.view_epochs)

    def ttft(rids):
        return np.asarray([sched.completed[r]["ttft_us"] for r in rids])

    def tput(rids, t0):
        done = max(sched.completed[r]["done_us"] for r in rids)
        return len(rids) / max(done - t0, 1e-9) * 1e3   # req per virtual ms

    # ctrl-plane traffic on a clean fabric must never trip the deviation
    # detector (the always-on monitor rides along the whole elastic timeline)
    assert_no_flags(monitor, "bench_scaling")

    return {
        "phases": phases, "sched": sched, "scaler": scaler, "ctrl": ctrl,
        "slo": slo, "ttft": ttft, "tput": tput, "t_b": t_b, "t_d": t_d,
        "n_prefillers": len(prefillers),
        "metrics": finish_trace(tracer, OUT_DIR, "trace_scaling.json"),
    }


def run(report) -> None:
    n_a, n_b, n_d = (6, 6, 4) if SMOKE else (10, 10, 6)
    r = run_timeline(n_a, n_b, n_d)
    sched, scaler, ttft, tput = r["sched"], r["scaler"], r["ttft"], r["tput"]
    ph = r["phases"]
    rows = {}

    def emit(name, value, derived="", **extra):
        rows[name] = {"value": float(value), **extra}
        report(name, value, derived)

    a, b, d = ttft(ph["A"]), ttft(ph["B"]), ttft(ph["D"])
    up_ts = [t for t, kind, _ in scaler.decisions if kind == "up"]
    down_ts = [t for t, kind, _ in scaler.decisions if kind == "down"]
    emit("scale_ttft_p50_overload", float(np.percentile(a, 50)),
         f"us (1 prefiller, {len(a)} reqs; p95 {np.percentile(a, 95):.0f})",
         p95=float(np.percentile(a, 95)))
    emit("scale_ttft_p50_scaled", float(np.percentile(b, 50)),
         f"us (after scale-up at t={up_ts[0]:.0f}; "
         f"p95 {np.percentile(b, 95):.0f})",
         p95=float(np.percentile(b, 95)))
    emit("scale_ttft_p50_failover", float(np.percentile(d, 50)),
         f"us (crash at t={r['t_d'] + 100:.0f}, {len(sched.rerouted)} "
         f"re-routed, all completed)",
         p95=float(np.percentile(d, 95)))
    emit("scale_tput_overload", tput(ph["A"], 0.0), "req/ms virtual")
    emit("scale_tput_scaled", tput(ph["B"], r["t_b"]), "req/ms virtual")
    emit("scale_epochs", float(sched.view_epochs[-1]),
         f"membership epochs seen by scheduler "
         f"(ups {len(up_ts)}, downs {len(down_ts)}, "
         f"{r['n_prefillers']} prefillers total)",
         ups=len(up_ts), downs=len(down_ts),
         n_prefillers=r["n_prefillers"])
    emit("scale_drain_leaked_pages", 0.0,
         "KV pages leaked through drained scale-down (asserted)")
    # SLO tracker rows: sliding-window percentiles as the autoscaler saw
    # them, plus how often the configured p95 SLO was crossed (overload
    # and failover phases breach; the scaled phase recovers)
    slo = r["slo"]
    s = slo.summary()
    emit("scale_slo_ttft_p95", s["ttft_p95_us"],
         f"us sliding-window p95 over the last {slo.window} TTFTs "
         f"(p50 {s['ttft_p50_us']:.0f}, p99 {s['ttft_p99_us']:.0f}, "
         f"{s['breaches']} breach(es) of the {TTFT_SLO_US:.0f}us SLO)",
         p50=s["ttft_p50_us"], p99=s["ttft_p99_us"],
         breaches=s["breaches"], slo_us=TTFT_SLO_US)
    emit("scale_slo_queue_p95", s["queue_p95"],
         f"queue-depth sliding-window p95 (p99 {s['queue_p99']:.0f}) — "
         f"the percentile signal the autoscaler scales on",
         p99=s["queue_p99"])
    assert s["breaches"] >= 1, \
        "overload/failover phases never breached the TTFT SLO"
    # scale-up must beat the overloaded tail; failover must still complete
    assert np.percentile(b, 95) < np.percentile(a, 95), \
        "scale-up did not improve tail TTFT"

    os.makedirs(OUT_DIR, exist_ok=True)
    doc = {
        "bench": "scaling",
        "smoke": SMOKE,
        "config": {"n_a": n_a, "n_b": n_b, "n_d": n_d,
                   "gap_us": GAP_US, "layer_us": LAYER_US,
                   "ttft_slo_us": TTFT_SLO_US},
        "rows": rows,
    }
    if r["metrics"] is not None:
        doc["metrics"] = r["metrics"]
    with open(os.path.join(OUT_DIR, "BENCH_scaling.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
