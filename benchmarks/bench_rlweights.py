"""Table 5: RL weight transfer at Kimi-K2 scale (1T params).

256 training GPUs (bf16, FSDP) -> 128 inference GPUs (fp8).  Uses synthetic
(timing-only) writes — 1 TB of payload is pointless to materialise — while
the schedule itself is the real planner output.  Baseline: rank0
gather+broadcast, the pattern of existing RL frameworks (paper: 10-100 s).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import Fabric
from repro.rlweights.planner import ParamMeta, compute_routing, schedule_stats

# pipeline stage rates calibrated to Table 5 (Kimi-K2, 256 ranks)
H2D_GBPS = 43.0        # 8 GB/rank in 184 ms
PREP_GBPS = 15.5       # full_tensor+fuse+quantise: 8 GB in ~520 ms
N_TRAIN, N_INFER = 256, 128
TOTAL_PARAMS = 1.04e12  # Kimi-K2


def _routes():
    # one flat MeshGroup-style param per layer (61 layers) — the schedule
    # granularity at which the paper's pipeline moves tensors
    n_params = 61
    per = int(TOTAL_PARAMS / n_params)
    params = [ParamMeta(f"w{i}", (per,), 2) for i in range(n_params)]
    return compute_routing(params, N_TRAIN, N_INFER, infer_tp=8,
                           quant_ratio=0.5)


def synthetic_cluster(n_train: int, n_infer: int, nic: str = "efa"):
    fab = Fabric(seed=0)
    te = [fab.add_engine(f"t{i}", nic=nic) for i in range(n_train)]
    ie = [fab.add_engine(f"i{i}", nic=nic) for i in range(n_infer)]
    descs = []
    for e in ie:
        buf = np.zeros(1, np.uint8)
        _, d = e.reg_mr(buf)
        descs.append(d)
    return fab, te, ie, descs


def p2p_synthetic(nic: str = "efa") -> Dict[str, float]:
    """Four-stage pipeline per (rank, param) task: H2D -> prepare -> RDMA.

    H2D/prepare touch each rank's FSDP shard ONCE per parameter; the
    prepared bytes are then WRITTEN to every TP replica (16x wire
    amplification — exactly why the paper needs full-cluster bisection)."""
    routes, sizes = _routes()
    fab, te, ie, descs = synthetic_cluster(N_TRAIN, N_INFER, nic)
    by_rank_param: Dict[int, Dict[str, List]] = {}
    for r in routes:
        by_rank_param.setdefault(r.train_rank, {}).setdefault(r.param, []).append(r)
    stats = {"h2d_ms": 0.0, "prep_ms": 0.0, "writes": 0}
    for rank, per_param in by_rank_param.items():
        t_h2d = t_prep = 0.0
        for pname, rs in per_param.items():
            n_rep = N_INFER // 8
            shard_in = 2 * sum(r.nbytes for r in rs) // n_rep   # bf16 shard
            t_h2d += (shard_in / H2D_GBPS) * 1e-3
            t_prep = max(t_prep, t_h2d) + (shard_in / PREP_GBPS) * 1e-3
            for r in rs:
                fab.loop.schedule(t_prep, lambda r=r, rank=rank:
                                  te[rank].submit_synthetic_write(
                                      r.nbytes, None, descs[r.infer_rank]))
                stats["writes"] += 1
        stats["h2d_ms"] = max(stats["h2d_ms"], t_h2d * 1e-3)
        stats["prep_ms"] = max(stats["prep_ms"], t_prep * 1e-3)
    t = fab.run()
    stats["total_ms"] = t * 1e-3
    stats.update(schedule_stats(routes, N_TRAIN, N_INFER))
    return stats


def rank0_synthetic(nic: str = "efa") -> Dict[str, float]:
    routes, sizes = _routes()
    fab, te, ie, descs = synthetic_cluster(N_TRAIN, N_INFER, nic)
    buf = np.zeros(1, np.uint8)
    _, d0 = te[0].reg_mr(buf)
    shard = int(TOTAL_PARAMS * 2 / N_TRAIN)
    for i in range(1, N_TRAIN):
        te[i].submit_synthetic_write(shard, None, d0)
    fab.run()
    t_gather = fab.now
    # rank0 broadcasts each inference rank's fp8 shard (TP=8, EP-style 1/16)
    out_bytes = int(TOTAL_PARAMS)  # fp8
    for r in range(N_INFER):
        te[0].submit_synthetic_write(out_bytes // 16, None, descs[r])
    t = fab.run()
    return {"gather_ms": t_gather * 1e-3, "total_ms": t * 1e-3}


def run(report) -> None:
    from repro.core.transport import Channel
    prev = Channel.MAX_CHUNKS
    Channel.MAX_CHUNKS = 2   # timing is chunk-count-invariant; cut event load
    try:
        _run_inner(report)
    finally:
        Channel.MAX_CHUNKS = prev


def _run_inner(report) -> None:
    p2p = p2p_synthetic()
    report("rl_p2p_total", p2p["total_ms"] * 1e3,
           f"us = {p2p['total_ms']:.0f}ms total (paper 1233ms), "
           f"h2d {p2p['h2d_ms']:.0f}ms (paper 184), "
           f"prep {p2p['prep_ms']:.0f}ms (paper 518+88), "
           f"{p2p['writes']} writes (paper 1144)")
    r0 = rank0_synthetic()
    report("rl_rank0_total", r0["total_ms"] * 1e3,
           f"us = {r0['total_ms'] / 1e3:.1f}s total (paper: 10-100s for "
           f"existing frameworks); p2p speedup "
           f"{r0['total_ms'] / p2p['total_ms']:.0f}x")
